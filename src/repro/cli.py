"""stevedore: the docker-shaped CLI over the Runtime (paper §3.2).

  PYTHONPATH=src python -m repro.cli build -t stable Imagefile
  PYTHONPATH=src python -m repro.cli images
  PYTHONPATH=src python -m repro.cli history stable
  PYTHONPATH=src python -m repro.cli run stable --platform local --steps 5
  PYTHONPATH=src python -m repro.cli ps
  PYTHONPATH=src python -m repro.cli tag <digest> prod

The paper's observation (§3.2) is that raw runtime CLIs are too low-level
for scientists, so projects ship a wrapper (`fenicsproject notebook ...`).
This is that wrapper: `run` wires the data pipeline, checkpoint store and
straggler monitor so one command reproduces the launch/train.py driver.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.core.runtime import Runtime


def cmd_build(rt: Runtime, args) -> int:
    text = Path(args.imagefile).read_text()
    image = rt.build(text, tag=args.tag)
    print(f"built {image.short_digest}" + (f" (tag: {args.tag})" if args.tag else ""))
    for digest, kind, summary in image.history():
        print(f"  {digest} {kind:12s} {summary}")
    return 0


def cmd_images(rt: Runtime, args) -> int:
    for rec in rt.images():
        tags = ",".join(rec["tags"]) or "<none>"
        print(f"{rec['digest']}  {tags}")
    return 0


def cmd_history(rt: Runtime, args) -> int:
    image = rt.pull(args.ref)
    for digest, kind, summary in image.history():
        print(f"{digest} {kind:12s} {summary}")
    return 0


def cmd_tag(rt: Runtime, args) -> int:
    rt.registry.tag(args.ref, args.tag)
    print(f"{args.tag} -> {rt.registry.resolve(args.tag)[:12]}")
    return 0


def cmd_ps(rt: Runtime, args) -> int:
    for rec in rt.ps():
        print(f"{rec['id'][:24]:26s} {rec['arch']:24s} "
              f"{rec.get('cell') or '-':12s} {rec['platform']:9s} "
              f"{rec.get('abi','')}")
    return 0


def cmd_run(rt: Runtime, args) -> int:
    from repro.launch.train import main as train_main
    argv = ["--image", args.ref, "--root", str(rt.root),
            "--steps", str(args.steps)]
    if args.platform:
        argv += ["--platform", args.platform]
    if args.resume:
        argv += ["--resume"]
    train_main(argv)
    return 0


def cmd_inspect(rt: Runtime, args) -> int:
    image = rt.pull(args.ref)
    print(json.dumps(image.config(), indent=2))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="stevedore")
    ap.add_argument("--root", default=".stevedore")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("build", help="build an image from an Imagefile")
    p.add_argument("imagefile")
    p.add_argument("-t", "--tag", default=None)

    sub.add_parser("images", help="list images")

    p = sub.add_parser("history", help="show image layers")
    p.add_argument("ref")

    p = sub.add_parser("inspect", help="show merged image config")
    p.add_argument("ref")

    p = sub.add_parser("tag", help="tag an image")
    p.add_argument("ref")
    p.add_argument("tag")

    sub.add_parser("ps", help="list containers (overlays)")

    p = sub.add_parser("run", help="run training from an image")
    p.add_argument("ref")
    p.add_argument("--platform", default=None)
    p.add_argument("--steps", type=int, default=10)
    p.add_argument("--resume", action="store_true")

    args = ap.parse_args(argv)
    rt = Runtime(args.root)
    return {
        "build": cmd_build, "images": cmd_images, "history": cmd_history,
        "tag": cmd_tag, "ps": cmd_ps, "run": cmd_run, "inspect": cmd_inspect,
    }[args.cmd](rt, args)


if __name__ == "__main__":
    sys.exit(main())
