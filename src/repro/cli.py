"""stevedore: the docker-shaped CLI over the Runtime (paper §3.2).

  PYTHONPATH=src python -m repro.cli build -t stable Imagefile
  PYTHONPATH=src python -m repro.cli images
  PYTHONPATH=src python -m repro.cli history stable
  PYTHONPATH=src python -m repro.cli run stable --platform local --steps 5
  PYTHONPATH=src python -m repro.cli serve stable --replicas 2 --slots 8
  PYTHONPATH=src python -m repro.cli ps
  PYTHONPATH=src python -m repro.cli tag <digest> prod

The paper's observation (§3.2) is that raw runtime CLIs are too low-level
for scientists, so projects ship a wrapper (`fenicsproject notebook ...`).
This is that wrapper: `run` wires the data pipeline, checkpoint store and
straggler monitor so one command reproduces the launch/train.py driver.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.core.runtime import Runtime


def _pid_alive(pid: int) -> bool:
    import os
    try:
        os.kill(int(pid), 0)
    except ProcessLookupError:
        return False
    except ValueError:
        return False
    except PermissionError:
        return True        # exists, owned by another user
    return True


def cmd_build(rt: Runtime, args) -> int:
    text = Path(args.imagefile).read_text()
    image = rt.build(text, tag=args.tag)
    print(f"built {image.short_digest}" + (f" (tag: {args.tag})" if args.tag else ""))
    for digest, kind, summary in image.history():
        print(f"  {digest} {kind:12s} {summary}")
    return 0


def cmd_images(rt: Runtime, args) -> int:
    for rec in rt.images():
        tags = ",".join(rec["tags"]) or "<none>"
        print(f"{rec['digest']}  {tags}")
    return 0


def cmd_history(rt: Runtime, args) -> int:
    image = rt.pull(args.ref)
    for digest, kind, summary in image.history():
        print(f"{digest} {kind:12s} {summary}")
    return 0


def cmd_tag(rt: Runtime, args) -> int:
    rt.registry.tag(args.ref, args.tag)
    print(f"{args.tag} -> {rt.registry.resolve(args.tag)[:12]}")
    return 0


def _snap_latency(snap: dict, name: str = "latency_ticks"):
    """('-', '-') when the snapshot holds no completed samples --
    nearest_rank's 0-for-empty must never render as a 0-tick latency."""
    from repro.orchestrator.obs.metrics import snapshot_percentile
    p50 = snapshot_percentile(snap, name, 50)
    p99 = snapshot_percentile(snap, name, 99)
    return (("-", "-") if p50 is None else (p50, p99))


def cmd_ps(rt: Runtime, args) -> int:
    for rec in rt.ps():
        print(f"{rec['id'][:24]:26s} {rec['arch']:24s} "
              f"{rec.get('cell') or '-':12s} {rec['platform']:9s} "
              f"{rec.get('abi','')}")
    pods_dir = rt.root / "pods"
    if pods_dir.exists():
        for p in sorted(pods_dir.glob("*.json")):
            try:
                pod = json.loads(p.read_text())
            except (OSError, json.JSONDecodeError):
                continue               # mid-write or corrupt; skip, not crash
            if not isinstance(pod, dict):
                continue
            phase = pod.get("phase", "-")
            pid = pod.get("pid")
            if pid is not None and not _pid_alive(pid):
                phase = "exited"        # stale snapshot of a dead process
            if pod.get("kind") == "router":
                # the fleet reads as one unit: one router line; member pods
                # follow as their own records (marked router=<id>)
                draining = len(pod.get("draining", []))
                # per-placement-policy spillover/rejection counters
                policy = "".join(
                    f" {pol}[spill={c.get('spillover', 0)}"
                    f",rej={c.get('rejected', 0)}"
                    f",shed={c.get('shed', 0)}]"
                    for pol, c in sorted(pod.get("by_policy", {}).items()))
                # fabric routers carry liveness: live member count vs
                # fleet size, plus eviction/re-route totals
                fab = pod.get("fabric") or {}
                fabric = (f" live={fab.get('live', 0)}"
                          f"/{len(pod.get('pods', []))}"
                          f" evicted={fab.get('evictions', 0)}"
                          f" rerouted={fab.get('reroutes', 0)}"
                          if fab else "")
                print(f"{pod.get('router', p.stem):26s} "
                      f"policy={pod.get('policy', '?')} "
                      f"pods={len(pod.get('pods', []))}{fabric} "
                      f"capacity={pod.get('capacity', 0)} "
                      f"free={pod.get('free_slots', 0)} "
                      f"pending={pod.get('pending', 0)} "
                      f"rejected={pod.get('rejected', 0)} "
                      f"shed={pod.get('shed', 0)} "
                      f"spilled={pod.get('spilled', 0)}{policy} "
                      f"draining={draining} {phase:8s}")
                continue
            reps = pod.get("replicas", [])
            active = sum(r.get("active", 0) for r in reps)
            prefills = sum(r.get("prefill_execs", 0) for r in reps)
            router = pod.get("router")
            # prefix page cache (paged pods with --prefix-cache): hit/miss
            # + resident shared pages, summed over replicas
            pcs = [r["prefix_cache"] for r in reps if r.get("prefix_cache")]
            # radix registry: node/depth shape plus the spill tier's
            # traffic (pages currently in host RAM, spill/restore count)
            depth = max((c.get("max_depth", 0) for c in pcs), default=0)
            prefix = (f" phits={sum(c['hits'] for c in pcs)}"
                      f"/{sum(c['misses'] for c in pcs)}"
                      f" shared={sum(c['shared_pages'] for c in pcs)}"
                      f" radix={sum(c.get('nodes', 0) for c in pcs)}n"
                      f":{depth}d"
                      f" spilled={sum(c.get('spilled_pages', 0) for c in pcs)}"
                      f" sp/rs={sum(c.get('spills', 0) for c in pcs)}"
                      f"/{sum(c.get('restores', 0) for c in pcs)}"
                      if pcs else "")
            wasted = sum(r.get("tokens_wasted", 0) for r in reps)
            preempts = sum(r.get("preemptions", 0) for r in reps)
            qos = (f" preempt={preempts}" if preempts else "") + (
                f" shed={pod['shed']}" if pod.get("shed") else "")
            # p50/p99 from the registry snapshot riding the state file;
            # '-' when no request ever completed (0 would read as instant)
            p50, p99 = _snap_latency(pod.get("metrics", {}))
            print(f"{pod.get('pod', p.stem):26s} "
                  f"image={pod.get('image', '?')} "
                  f"replicas={len(reps)} capacity={pod.get('capacity', 0)} "
                  f"free={pod.get('free_slots', 0)} "
                  f"active={active} prefills={prefills} "
                  f"rejected={pod.get('rejected', 0)} wasted={wasted}{qos} "
                  f"p50/p99={p50}/{p99}{prefix} {phase:8s} "
                  f"ref={pod.get('ref') or '-'}"
                  + (f" router={router}" if router else ""))
    return 0


def cmd_run(rt: Runtime, args) -> int:
    from repro.launch.train import main as train_main
    argv = ["--image", args.ref, "--root", str(rt.root),
            "--steps", str(args.steps)]
    if args.platform:
        argv += ["--platform", args.platform]
    if args.resume:
        argv += ["--resume"]
    train_main(argv)
    return 0


def cmd_serve(rt: Runtime, args) -> int:
    from repro.launch.serve import main as serve_main
    argv = ["--image", args.ref, "--root", str(rt.root),
            "--mode", args.mode,
            "--replicas", str(args.replicas), "--slots", str(args.slots),
            "--pods", str(args.pods), "--policy", args.policy,
            "--requests", str(args.requests), "--gen", str(args.gen),
            "--prompt-len", str(args.prompt_len), "--seed", str(args.seed),
            "--fairness-cap", str(args.fairness_cap),
            "--arrive-per-tick", str(args.arrive_per_tick)]
    if args.platform:
        argv += ["--platform", args.platform]
    if args.paged:
        argv += ["--paged"]
    if args.paged or args.prefix_cache:
        # --prefix-cache implies --paged downstream; the page size must
        # ride along either way or it silently falls back to the default
        argv += ["--page-size", str(args.page_size)]
    if args.prefix_cache:
        argv += ["--prefix-cache"]
    if args.shared_prefix:
        argv += ["--shared-prefix", str(args.shared_prefix)]
    if args.spill_pages:
        argv += ["--spill-pages", str(args.spill_pages)]
    if args.batch_every:
        argv += ["--batch-every", str(args.batch_every)]
    if args.deadline_ticks is not None:
        argv += ["--deadline-ticks", str(args.deadline_ticks)]
    if args.shed_queue_depth is not None:
        argv += ["--shed-queue-depth", str(args.shed_queue_depth)]
    if args.shed_ttft_p99 is not None:
        argv += ["--shed-ttft-p99", str(args.shed_ttft_p99)]
    if args.trace:
        argv += ["--trace", args.trace]
    if args.fabric != "none":
        argv += ["--fabric", args.fabric,
                 "--min-pods", str(args.min_pods),
                 "--heartbeat-every", str(args.heartbeat_every),
                 "--miss-limit", str(args.miss_limit)]
        if args.max_pods is not None:
            argv += ["--max-pods", str(args.max_pods)]
        if args.scale_up_tokens is not None:
            argv += ["--scale-up-tokens", str(args.scale_up_tokens)]
        if args.scale_idle_ticks is not None:
            argv += ["--scale-idle-ticks", str(args.scale_idle_ticks)]
    serve_main(argv)
    return 0


def cmd_top(rt: Runtime, args) -> int:
    """Live fleet dashboard rendered from the metrics snapshots riding the
    pod/router state files -- nothing is re-derived from raw counters."""
    import time
    from repro.orchestrator.obs.metrics import (snapshot_count,
                                                snapshot_exemplar,
                                                snapshot_percentile,
                                                snapshot_total)

    def pct(snap, name, p, scale=1.0):
        v = snapshot_percentile(snap, name, p)
        if v is None:
            return "-"
        return f"{v * scale:g}"

    def render() -> int:
        pods_dir = rt.root / "pods"
        files = sorted(pods_dir.glob("*.json")) if pods_dir.exists() else []
        print(f"{'NAME':26s} {'PHASE':8s} {'LIVE':>5s} "
              f"{'QUEUE':>5s} {'POOL':>9s} "
              f"{'PREFIX':>7s} {'SP/RS':>7s} {'WASTED':>6s} "
              f"{'PREEMPT':>7s} {'SHED':>5s} "
              f"{'TOKENS':>7s} "
              f"{'P50/P99':>9s} {'TTFT':>9s} {'ITL':>11s} {'P99-RID':>7s}")
        shown = 0
        for p in files:
            try:
                pod = json.loads(p.read_text())
            except (OSError, json.JSONDecodeError):
                continue
            if not isinstance(pod, dict) or "metrics" not in pod:
                continue
            is_router = pod.get("kind") == "router"
            name = pod.get("router" if is_router else "pod", p.stem)
            phase = pod.get("phase", "-")
            pid = pod.get("pid")
            if pid is not None and not _pid_alive(pid):
                phase = "exited"
            # fabric routers report member liveness (heartbeat view);
            # plain pods/routers have no probe, shown as '-'
            fab = pod.get("fabric") or {}
            live = (f"{fab.get('live', 0)}/{len(pod.get('pods', []))}"
                    if is_router and fab else "-")
            snap = pod["metrics"]
            queue = snapshot_total(snap, "queue_depth")
            in_use = snapshot_total(snap, "pool_in_use")
            pool_cap = sum(r.get("pool", {}).get("pages", 0)
                           for r in pod.get("replicas", []))
            pool = f"{in_use}/{pool_cap}" if pool_cap else "-"
            hits = snapshot_total(snap, "prefix_hits")
            misses = snapshot_total(snap, "prefix_misses")
            rate = (f"{hits / (hits + misses):.0%}" if hits + misses else "-")
            # spill-tier traffic: pages pushed to / pulled from host RAM
            spills = snapshot_total(snap, "pool_spills")
            restores = snapshot_total(snap, "pool_restores")
            sprs = f"{spills}/{restores}" if spills or restores else "-"
            lat = (f"{pct(snap, 'latency_ticks', 50)}"
                   f"/{pct(snap, 'latency_ticks', 99)}"
                   if snapshot_count(snap, "latency_ticks") else "-")
            ttft = (f"{pct(snap, 'ttft_ticks', 50)}"
                    f"/{pct(snap, 'ttft_ticks', 99)}"
                    if snapshot_count(snap, "ttft_ticks") else "-")
            # ITL is stored in milli-ticks; render in ticks/token
            itl = (f"{pct(snap, 'itl_milliticks', 50, 1e-3)}"
                   f"/{pct(snap, 'itl_milliticks', 99, 1e-3)}"
                   if snapshot_count(snap, "itl_milliticks") else "-")
            # the exemplar rid behind the latency p99: the concrete
            # request to pull out of the span trace when p99 spikes
            p99_rid = snapshot_exemplar(snap, "latency_ticks", 99)
            p99_rid = "-" if p99_rid is None else str(p99_rid)
            print(f"{name:26s} {phase:8s} {live:>5s} {queue:>5d} {pool:>9s} "
                  f"{rate:>7s} {sprs:>7s} "
                  f"{snapshot_total(snap, 'tokens_wasted'):>6d} "
                  f"{snapshot_total(snap, 'preemptions'):>7d} "
                  f"{snapshot_total(snap, 'requests_shed'):>5d} "
                  f"{snapshot_total(snap, 'tokens_out'):>7d} "
                  f"{lat:>9s} {ttft:>9s} {itl:>11s} {p99_rid:>7s}")
            shown += 1
        if not shown:
            print("(no pod state found -- run `serve` first)")
        return shown

    if not args.watch:
        render()
        return 0
    try:
        while True:
            print(f"\x1b[2J\x1b[Hrepro top  (every {args.watch:g}s, "
                  f"ctrl-c to exit)")
            render()
            time.sleep(args.watch)
    except KeyboardInterrupt:
        pass
    return 0


def cmd_inspect(rt: Runtime, args) -> int:
    image = rt.pull(args.ref)
    print(json.dumps(image.config(), indent=2))
    return 0


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    # `lint` forwards everything to repro.analysis's own argparse
    # (argparse.REMAINDER mis-parses leading flags in subparsers) and must
    # not construct a Runtime -- linting a bare checkout, e.g. in CI, may
    # not create .stevedore
    if argv[:1] == ["lint"]:
        from repro.analysis import main as lint_main
        return lint_main(argv[1:])
    ap = argparse.ArgumentParser(prog="stevedore")
    ap.add_argument("--root", default=".stevedore")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("build", help="build an image from an Imagefile")
    p.add_argument("imagefile")
    p.add_argument("-t", "--tag", default=None)

    sub.add_parser("images", help="list images")

    p = sub.add_parser("history", help="show image layers")
    p.add_argument("ref")

    p = sub.add_parser("inspect", help="show merged image config")
    p.add_argument("ref")

    p = sub.add_parser("tag", help="tag an image")
    p.add_argument("ref")
    p.add_argument("tag")

    sub.add_parser("ps", help="list containers (overlays)")

    p = sub.add_parser("run", help="run training from an image")
    p.add_argument("ref")
    p.add_argument("--platform", default=None)
    p.add_argument("--steps", type=int, default=10)
    p.add_argument("--resume", action="store_true")

    p = sub.add_parser("serve",
                       help="serve a Pod of replicas (continuous batching)")
    p.add_argument("ref")
    p.add_argument("--platform", default=None)
    p.add_argument("--mode", choices=("continuous", "static"),
                   default="continuous")
    p.add_argument("--replicas", type=int, default=2)
    p.add_argument("--pods", type=int, default=1,
                   help="pods behind a PodRouter (>1 = multi-pod fleet)")
    p.add_argument("--policy",
                   choices=("shortest-queue", "consistent-hash",
                            "prefix-hash"),
                   default="shortest-queue",
                   help="router placement policy (--pods > 1)")
    p.add_argument("--slots", type=int, default=8)
    p.add_argument("--requests", type=int, default=32)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--fairness-cap", type=int, default=8)
    p.add_argument("--arrive-per-tick", type=int, default=8)
    p.add_argument("--paged", action="store_true",
                   help="serve from a shared KV page pool (paged attention)")
    p.add_argument("--page-size", type=int, default=16)
    p.add_argument("--prefix-cache", action="store_true",
                   help="copy-on-write prefix page sharing (implies --paged)")
    p.add_argument("--spill-pages", type=int, default=0,
                   help="host-RAM spill tier for evicted prefix pages: "
                        "0 disables, -1 is unbounded, N caps the store "
                        "(needs --prefix-cache)")
    p.add_argument("--shared-prefix", type=int, default=0,
                   help="prepend an N-token shared system prompt to the "
                        "trace")
    p.add_argument("--batch-every", type=int, default=0,
                   help="tag every Nth request as batch QoS (sheddable + "
                        "preemptible); 0 = all interactive")
    p.add_argument("--deadline-ticks", type=int, default=None,
                   help="admission deadline (ticks) for batch requests")
    p.add_argument("--shed-queue-depth", type=int, default=None,
                   help="router overload threshold: shed batch traffic at "
                        "queue depth >= N")
    p.add_argument("--shed-ttft-p99", type=int, default=None,
                   help="router overload threshold: shed batch traffic at "
                        "ttft p99 >= N ticks")
    p.add_argument("--trace", default=None, metavar="PATH",
                   help="export request-lifecycle spans as Chrome "
                        "trace-event JSON (open in Perfetto)")
    p.add_argument("--fabric", choices=("none", "loopback", "proc"),
                   default="none",
                   help="serve over the cross-host fabric: framed message "
                        "transport in-process (loopback) or one OS "
                        "process per pod (proc)")
    p.add_argument("--min-pods", type=int, default=1,
                   help="elastic floor: heal back to N pods (--fabric)")
    p.add_argument("--max-pods", type=int, default=None,
                   help="elastic ceiling (--fabric); default --pods")
    p.add_argument("--heartbeat-every", type=int, default=4,
                   help="fabric liveness probe cadence in ticks")
    p.add_argument("--miss-limit", type=int, default=2,
                   help="consecutive missed probes before eviction")
    p.add_argument("--scale-up-tokens", type=int, default=None,
                   help="spawn a pod when outstanding tokens per live pod "
                        "exceed N (--fabric)")
    p.add_argument("--scale-idle-ticks", type=int, default=None,
                   help="drain+retire the newest pod after N idle ticks "
                        "(--fabric)")

    p = sub.add_parser("top",
                       help="live serving metrics (queue/pool/latency) "
                            "from the pod state files")
    p.add_argument("--watch", type=float, default=0, metavar="SECONDS",
                   help="refresh every N seconds until interrupted")

    # static analysis: all flags forwarded to repro.analysis (its own
    # argparse owns --strict/--rule/--baseline/--list-rules/--help)
    p = sub.add_parser("lint", add_help=False,
                       help="static analysis of the stack's contracts "
                            "(repro lint --strict src tests)")
    p.add_argument("lint_args", nargs=argparse.REMAINDER)

    args = ap.parse_args(argv)
    if args.cmd == "lint":        # reached via `--root X lint ...`
        from repro.analysis import main as lint_main
        return lint_main(args.lint_args)
    rt = Runtime(args.root)
    return {
        "build": cmd_build, "images": cmd_images, "history": cmd_history,
        "tag": cmd_tag, "ps": cmd_ps, "run": cmd_run, "serve": cmd_serve,
        "inspect": cmd_inspect, "top": cmd_top,
    }[args.cmd](rt, args)


if __name__ == "__main__":
    sys.exit(main())
