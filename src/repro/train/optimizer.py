"""AdamW with global-norm clipping and warmup-cosine schedule (from scratch;
no optax in this environment).

State = {m, v} f32 trees shaped like params, plus a scalar step. The ZeRO-1
trick lives entirely in *sharding*: Container shards m/v (and the update
computation) over the batch axes via the opt-state sharding rules, which
turns the gradient all-reduce into reduce-scatter + all-gather (see
core/abi.py).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * jnp.minimum(1.0, (step + 1.0) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(math.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def adamw_init(params, with_master: bool = False):
    """with_master: keep an f32 master copy in the optimizer (params may
    then live in bf16 for compute/FSDP-gather traffic -- standard mixed
    precision; the master shards like m/v, i.e. ZeRO-1-able)."""
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    st = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if with_master:
        st["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return st


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, metrics). If the state carries an f32
    ``master`` tree, updates apply to it and params are its cast."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** (step.astype(jnp.float32) + 1)
    c2 = 1.0 - b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, m, v, base):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh, vh = m / c1, v / c2
        b32 = base.astype(jnp.float32)
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * b32
        new_base = b32 - lr * delta
        return new_base.astype(p.dtype), m, v, new_base

    masters = state.get("master")
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_b = jax.tree.leaves(masters) if masters is not None else flat_p
    out = [upd(p, g, m, v, b) for p, g, m, v, b in
           zip(flat_p, flat_g, flat_m, flat_v, flat_b)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    if masters is not None:
        new_state["master"] = jax.tree.unflatten(tdef, [o[3] for o in out])
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
