"""Train-step builders: implicit (pjit/GSPMD) and explicit (shard_map) paths.

Two ABI-compatible step builders (the whole point of core/abi.py):

* ``implicit``  -- plain jit: the SPMD partitioner inserts gradient
  collectives. The ``generic`` ABI uses this with replicated optimizer
  states (flat fp32 all-reduce: the "container MPICH"). ZeRO-1 (part of
  the ``host`` ABI) is also expressed here purely through *optimizer-state
  shardings*: m/v shard over batch axes, so XLA rewrites the gradient
  all-reduce into reduce-scatter + (param) all-gather.

* ``explicit``  -- shard_map manual over the batch axes, ``auto`` over the
  model axis: gradients are synced by ``abi.grad_sync`` (bf16 wire dtype,
  hierarchical pod-then-ICI reduction). TP stays with GSPMD inside the
  auto axis. This is the "Cray MPI" path.

Both produce bit-compatible *interfaces*: (params, opt_state, batch, rng) ->
(params, opt_state, metrics). Swapping never touches model code.

Gradient accumulation: ``microbatches > 1`` scans over batch slices,
accumulating f32 grads (bytes on the wire unchanged, peak activation
memory divided by the microbatch count).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.compat import shard_map as _shard_map
from repro.core.abi import CollectiveABI
from repro.dist.mesh import batch_axes
from repro.dist.sharding import ShardingRules, constrain
from repro.train.compression import powersgd_sync
from repro.models.config import ModelConfig
from repro.models.layers import padded_vocab
from repro.models.transformer import Model
from repro.train.optimizer import OptConfig, adamw_update

AUX_LOSS_WEIGHT = 0.01


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab_size: int,
                  mask: jax.Array | None = None):
    """logits: (B,S,Vp) with physical padding beyond vocab_size; labels (B,S).

    Padded vocab columns are masked to -inf so the partition function is
    exact w.r.t. the canonical vocabulary."""
    vp = logits.shape[-1]
    lg = logits.astype(jnp.float32)
    if vp != vocab_size:
        col = jnp.arange(vp) >= vocab_size
        lg = jnp.where(col[None, None, :], -1e30, lg)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    return nll.mean()


@dataclass
class TrainStepBuilder:
    model: Model
    mesh: Mesh
    rules: ShardingRules
    abi: CollectiveABI
    opt: OptConfig
    microbatches: int = 1

    # -- loss ------------------------------------------------------------
    def _loss(self, params, batch):
        cfg = self.model.cfg
        fe = batch.get("frontend_embeds")
        logits, aux = self.model.forward(params, batch["tokens"],
                                         frontend_embeds=fe)
        labels = batch["labels"]
        if fe is not None:
            # frontend prefix carries no LM loss; labels cover token positions
            logits = logits[:, fe.shape[1]:]
        loss = cross_entropy(logits, labels, cfg.vocab_size,
                             batch.get("loss_mask"))
        return loss + AUX_LOSS_WEIGHT * aux, (loss, aux)

    def _grads(self, params, batch):
        """(possibly microbatched) value-and-grad; returns f32 grad tree."""
        if self.microbatches == 1:
            (_, (loss, aux)), grads = jax.value_and_grad(
                self._loss, has_aux=True)(params, batch)
            return grads, loss, aux

        n = self.microbatches

        def slice_mb(x, i):
            mb = x.shape[0] // n
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

        def body(carry, i):
            gacc, lacc, aacc = carry
            mb = jax.tree.map(lambda x: slice_mb(x, i), batch)
            (_, (loss, aux)), g = jax.value_and_grad(
                self._loss, has_aux=True)(params, mb)
            gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gacc, g)
            return (gacc, lacc + loss, aacc + aux), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g, loss, aux), _ = jax.lax.scan(
            body, (g0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            jnp.arange(n))
        g = jax.tree.map(lambda x: x / n, g)
        return g, loss / n, aux / n

    # -- implicit (pjit) path ------------------------------------------------
    def build_implicit(self) -> Callable:
        def step(params, opt_state, batch):
            grads, loss, aux = self._grads(params, batch)
            new_params, new_state, om = adamw_update(params, grads, opt_state,
                                                     self.opt)
            metrics = {"loss": loss, "aux_loss": aux, **om}
            return new_params, new_state, metrics

        return step

    # -- explicit (shard_map) path --------------------------------------------
    def build_explicit(self) -> Callable:
        """Manual over the batch axes, auto over model.

        NOTE: params are replicated across the manual axes inside the region,
        so this path composes with TP but NOT with FSDP/ZeRO param sharding --
        it is the right shape for models whose (params+opt)/TP fits HBM
        (the paper's Fig.3-style runs); large models take the implicit ZeRO-1
        path instead (see build()).
        """
        import copy

        from repro.dist.sharding import safe_spec

        baxes = batch_axes(self.mesh)
        manual = set(baxes)
        bspec = P(baxes if len(baxes) > 1 else baxes[0])

        # model clone whose sharding constraints never mention manual axes
        mesh, rules = self.mesh, self.rules
        excl = tuple(manual)

        def local_constrain(x, logical):
            spec = safe_spec(x.shape, logical, mesh, rules, exclude_axes=excl)
            return jax.lax.with_sharding_constraint(
                x, jax.sharding.NamedSharding(mesh, spec))

        local_model = copy.copy(self.model)
        local_model.constrain = local_constrain
        if getattr(local_model, "moe_mesh", None) is not None:
            # inner EP shard_map may only be manual over the (still-auto)
            # model axis; data is already manual out here
            local_model.moe_batch_axes = ()
        local_self = copy.copy(self)
        local_self.model = local_model

        use_psgd = self.abi.options.get("compression") == "powersgd"
        rank = int(self.abi.options.get("rank", 16))

        def local_step(params, opt_state, batch):
            comm = opt_state.get("comm")
            opt_core = {k: v for k, v in opt_state.items() if k != "comm"}
            grads, loss, aux = local_self._grads(params, batch)
            if use_psgd and comm is not None:
                # comm leaves carry a leading per-shard axis (size 1 locally:
                # the error buffer is PER-REPLICA state, unlike params)
                comm_local = {
                    "q": jax.tree.map(lambda a: a[0], comm["q"]),
                    "err": jax.tree.map(lambda a: a[0], comm["err"]),
                    "rank": rank,
                }
                grads, comm_local = powersgd_sync(grads, comm_local, baxes,
                                                  rank)
                comm = {
                    "q": jax.tree.map(lambda a: a[None], comm_local["q"]),
                    "err": jax.tree.map(lambda a: a[None], comm_local["err"]),
                }
            else:
                # the ABI swap point: wire dtype + topology live here
                grads = self.abi.grad_sync(grads, baxes)
            loss = jax.lax.pmean(loss, tuple(baxes))
            aux = jax.lax.pmean(aux, tuple(baxes))
            new_params, new_state, om = adamw_update(params, grads, opt_core,
                                                     self.opt)
            if comm is not None:
                new_state["comm"] = comm
            metrics = {"loss": loss, "aux_loss": aux, **om}
            return new_params, new_state, metrics

        rep = P()  # params/opt replicated over the manual (batch) axes
        shard0 = P(baxes if len(baxes) > 1 else baxes[0])

        def ospec_for(opt_state):
            def spec(path_is_comm, tree):
                return jax.tree.map(
                    lambda _: shard0 if path_is_comm else rep, tree)
            out = {k: spec(k == "comm", v) for k, v in opt_state.items()}
            return out

        def step(params, opt_state, batch):
            pspec = jax.tree.map(lambda _: rep, params)
            ospec = ospec_for(opt_state)
            bspec_tree = jax.tree.map(lambda _: bspec, batch)
            mspec = {"loss": rep, "aux_loss": rep, "grad_norm": rep, "lr": rep}
            return _shard_map(
                local_step, mesh=self.mesh,
                in_specs=(pspec, ospec, bspec_tree),
                out_specs=(pspec, ospec, mspec),
                check_vma=False,
                axis_names=manual,
            )(params, opt_state, batch)

        return step

    def build(self) -> Callable:
        """ABI -> step-path binding.

        generic        -> implicit (flat fp32 AR, replicated opt)
        host (default) -> implicit + ZeRO-1 (RS+AG via opt-state shardings;
                          composes with FSDP for the big models)
        host mode=explicit -> shard_map path: bf16 wire + hierarchical
                          pod-aware reductions (small/medium models whose
                          params fit replicated across the batch axes)
        """
        if self.abi.options.get("mode") == "explicit":
            return self.build_explicit()
        return self.build_implicit()
