"""PowerSGD gradient compression with error feedback [Vogels et al. 2019,
arXiv:1905.13727] -- the beyond-paper entry in the collective ABI.

Rank-r compression of each >=2D gradient: G (m,n) ~= P Q^T with P (m,r),
Q (n,r). One power-iteration step per training step:

    P   = G @ Q_prev          ; pmean(P)  ; P = orth(P)
    Q   = G^T @ P             ; pmean(Q)
    Ghat= P @ Q^T             ; error e += G - Ghat   (fed back next step)

Wire per tensor: r(m+n) floats instead of m*n -- e.g. a (8192, 22016) MLP
gradient at rank 16 moves 0.48 MB instead of 721 MB (1500x). The error
buffer makes the scheme unbiased over time (residual is retransmitted),
which is why it trains: lossy-but-compensated, the same contract as the
bf16 wire option, one more notch down the fidelity/bandwidth curve.

This composes with the paper's ABI story: the image's collectives layer
says ``COLLECTIVES host mode=explicit compression=powersgd rank=16`` and
neither the model nor the optimizer changes.

Small tensors (1D norms/biases, or m*n <= 4*r*(m+n)) sync uncompressed --
compression would cost more than it saves.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _as_matrix(g):
    """Collapse a >=2D tensor to (leading, rest)."""
    if g.ndim == 2:
        return g
    return g.reshape(g.shape[0], -1)


def _compressible(g, rank: int) -> bool:
    if g.ndim < 2:
        return False
    m = g.shape[0]
    n = int(g.size // m)
    return m >= rank and n >= rank and g.size > 4 * rank * (m + n)


def powersgd_init(params, rank: int, key=None):
    """Per-leaf state: Q (n,r) random orthonormal-ish, error f32 buffer."""
    key = key if key is not None else jax.random.key(17)
    leaves, treedef = jax.tree.flatten(params)
    qs, errs = [], []
    for i, p in enumerate(leaves):
        if _compressible(p, rank):
            g2 = _as_matrix(p)
            q = jax.random.normal(jax.random.fold_in(key, i),
                                  (g2.shape[1], rank), jnp.float32)
            q, _ = jnp.linalg.qr(q)
            qs.append(q)
            errs.append(jnp.zeros(p.shape, jnp.float32))
        else:
            qs.append(None)
            errs.append(None)
    none_leaf = lambda t: jax.tree.unflatten(treedef, t)
    return {"q": none_leaf(qs), "err": none_leaf(errs), "rank": rank}


def _is_state_leaf(x):
    return x is None or isinstance(x, jax.Array) or hasattr(x, "shape")


def powersgd_sync(grads, state, batch_axes, rank: int):
    """Cross-replica mean of grads with rank-r compression + error feedback.

    Called inside shard_map (manual over ``batch_axes``). Returns
    (synced_grads, new_state)."""
    g_leaves, treedef = jax.tree.flatten(grads)
    q_leaves = treedef.flatten_up_to(state["q"])
    e_leaves = treedef.flatten_up_to(state["err"])

    out_g, out_q, out_e = [], [], []
    for g, q, e in zip(g_leaves, q_leaves, e_leaves):
        if q is None:
            out_g.append(jax.lax.pmean(g.astype(jnp.float32),
                                       tuple(batch_axes)).astype(g.dtype))
            out_q.append(None)
            out_e.append(None)
            continue
        g32 = g.astype(jnp.float32) + e
        g2 = _as_matrix(g32)
        p = g2 @ q                                          # (m, r)
        p = jax.lax.pmean(p, tuple(batch_axes))             # wire: m*r
        p, _ = jnp.linalg.qr(p)                             # orthonormalize
        qn = g2.T @ p                                       # (n, r)
        qn = jax.lax.pmean(qn, tuple(batch_axes))           # wire: n*r
        ghat = (p @ qn.T).reshape(g.shape)
        out_g.append(ghat.astype(g.dtype))
        out_q.append(qn)                                    # warm-start next step
        out_e.append(g32 - ghat)                            # error feedback
    unf = lambda t: jax.tree.unflatten(treedef, t)
    return unf(out_g), {"q": unf(out_q), "err": unf(out_e), "rank": rank}
