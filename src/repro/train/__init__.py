from repro.train.optimizer import OptConfig, adamw_init, adamw_update, lr_at
from repro.train.train_step import TrainStepBuilder, cross_entropy

__all__ = ["OptConfig", "adamw_init", "adamw_update", "lr_at",
           "TrainStepBuilder", "cross_entropy"]
