"""Version compatibility for the shard_map API.

Callers use the modern keyword form ``shard_map(f, mesh=..., in_specs=...,
out_specs=..., axis_names={...}, check_vma=False)``. On older jax (which
ships ``jax.experimental.shard_map`` with ``auto=``/``check_rep=``) the
arguments are translated: ``auto`` is the complement of ``axis_names``.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    auto = (frozenset(mesh.axis_names) - frozenset(axis_names)
            if axis_names is not None else frozenset())
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma, auto=auto)
