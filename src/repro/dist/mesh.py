"""Platform meshes: the MESH image layer resolved to devices.

``local``   -- every visible device on the data axis (dev laptops, CI, and
               the 1-CPU test environment);
``pod``     -- one 256-chip pod: 16-way data x 16-way model;
``multipod``-- two pods: pod x data x model = 2 x 16 x 16 (the dry-run's
               512-host-device mesh).

Batch ("replica") axes are ordered slow-to-fast as ("pod", "data"): pod is
the outermost / highest-latency dimension, which is what the hierarchical
grad reductions in core/abi.py rely on.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

# platform name -> (axis names, mesh shape). A shape of None means "all
# visible devices on the data axis" (resolved at mesh-construction time, so
# importing this module never touches jax device state).
PLATFORMS: dict[str, dict] = {
    "local": {"axes": ("data", "model"), "shape": None},
    "pod": {"axes": ("data", "model"), "shape": (16, 16)},
    "multipod": {"axes": ("pod", "data", "model"), "shape": (2, 16, 16)},
}


def make_platform_mesh(platform: str = "local") -> Mesh:
    """Resolve a platform name into a concrete device mesh."""
    try:
        spec = PLATFORMS[platform]
    except KeyError:
        raise ValueError(
            f"unknown platform {platform!r}; expected one of {sorted(PLATFORMS)}"
        ) from None
    axes = spec["axes"]
    shape = spec["shape"] or (jax.device_count(), 1)
    if hasattr(jax.sharding, "AxisType"):  # jax >= 0.5
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """The replica (data-parallel) axes of ``mesh``, ordered slow-to-fast."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
