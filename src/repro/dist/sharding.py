"""Logical-axis sharding rules: model-code axis names -> mesh PartitionSpecs.

Model modules annotate tensors with *logical* axis names ("batch", "embed",
"mlp", ...). A ``ShardingRules`` table maps each name to zero or more mesh
axes; ``mesh_axes`` / ``safe_spec`` translate a logical tuple into a
``PartitionSpec`` with two safety guarantees:

  * an axis absent from the mesh is silently dropped (the same rules drive
    the 2-axis local mesh and the 3-axis multipod mesh);
  * one mesh axis never shards two dims of the same tensor (first logical
    dim to claim it wins);

and, for ``safe_spec`` (which also sees the shape):

  * a dim is never sharded by more mesh axes than divide it evenly
    (a greedy prefix of the rule's axes is kept, preserving collective
    layout order).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rule = str | tuple[str, ...] | None

# The canonical table. Batch-like dims shard over the replica axes
# ("pod","data", slow-to-fast -- see dist.mesh.batch_axes); tensor-parallel
# dims over "model". "embed" stays replicated unless FSDP turns it on.
_DEFAULT_RULES: dict[str, Rule] = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "capacity": ("pod", "data"),
    "rnn": "model",
    "layers": None,
}


@dataclass(frozen=True)
class ShardingRules:
    """Immutable logical-axis -> mesh-axis table."""

    rules: Mapping[str, Rule]

    @classmethod
    def default(cls, fsdp: bool = False,
                seq_parallel: bool = False) -> "ShardingRules":
        r = dict(_DEFAULT_RULES)
        if fsdp:
            # param "embed" dims shard over the replica axes (weight FSDP;
            # ZeRO-1 applies the same rule to optimizer state only).
            r["embed"] = ("pod", "data")
        if seq_parallel:
            # activations' sequence dim shards over the model axis between
            # attention/MLP regions (constraints are best-effort: safe_spec
            # drops it wherever seq does not divide).
            r["seq"] = "model"
        return cls(r)

    def with_(self, **updates: Rule) -> "ShardingRules":
        r = dict(self.rules)
        for k, v in updates.items():
            r[k] = tuple(v) if isinstance(v, list) else v
        return ShardingRules(r)

    def mesh_axes(self, logical: Sequence[str | None], mesh: Mesh,
                  exclude_axes: Sequence[str] = ()) -> P:
        """Translate logical axis names into a PartitionSpec for ``mesh``.

        Mesh axes already claimed (or listed in ``exclude_axes`` -- e.g. the
        manual axes of an enclosing shard_map) are never reused.
        """
        used: set[str] = set(exclude_axes)
        entries: list[Rule] = []
        for name in logical:
            rule = self.rules.get(name) if name is not None else None
            if rule is None:
                entries.append(None)
            elif isinstance(rule, str):
                if rule in mesh.axis_names and rule not in used:
                    used.add(rule)
                    entries.append(rule)
                else:
                    entries.append(None)
            else:
                ax = tuple(a for a in rule
                           if a in mesh.axis_names and a not in used)
                used.update(ax)
                entries.append(ax if ax else None)
        return P(*entries)


def _axes_of(entry: Rule) -> tuple[str, ...]:
    if entry is None:
        return ()
    return (entry,) if isinstance(entry, str) else tuple(entry)


def safe_spec(shape: Sequence[int], logical: Sequence[str | None], mesh: Mesh,
              rules: ShardingRules, exclude_axes: Sequence[str] = ()) -> P:
    """A PartitionSpec for ``shape`` that is guaranteed divisible.

    Per dim, a greedy prefix of the rule's mesh axes is kept while the
    cumulative axis product divides the dim; order is preserved so the
    collective layout never flips between callers.
    """
    spec = rules.mesh_axes(logical, mesh, exclude_axes=exclude_axes)
    entries: list[Rule] = []
    for dim, entry in zip(shape, spec):
        axes = _axes_of(entry)
        kept: list[str] = []
        prod = 1
        for a in axes:
            if dim % (prod * mesh.shape[a]) == 0:
                kept.append(a)
                prod *= mesh.shape[a]
            else:
                break
        if not kept:
            entries.append(None)
        elif isinstance(entry, str):
            entries.append(kept[0])
        else:
            entries.append(tuple(kept))
    return P(*entries)


def logical_sharding(logical: Sequence[str | None], mesh: Mesh,
                     rules: ShardingRules) -> NamedSharding:
    """NamedSharding for a tensor described only by logical axes (params:
    their def shapes are constructed divisible -- heads padded to TP,
    vocab padded to a lane multiple -- so no shape check is needed)."""
    return NamedSharding(mesh, rules.mesh_axes(logical, mesh))


def check_divisibility(shape: Sequence[int], spec: P, mesh: Mesh) -> None:
    """Raise if ``spec`` shards any dim of ``shape`` non-evenly."""
    for i, (dim, entry) in enumerate(zip(shape, spec)):
        k = 1
        for a in _axes_of(entry):
            k *= mesh.shape[a]
        if dim % k:
            raise ValueError(
                f"dim {i} of shape {tuple(shape)} not divisible by mesh axes "
                f"{entry!r} (product {k})")


def constrain(x: jax.Array, logical: Sequence[str | None], mesh: Mesh,
              rules: ShardingRules,
              exclude_axes: Sequence[str] = ()) -> jax.Array:
    """with_sharding_constraint via safe_spec (the injectable model hook)."""
    spec = safe_spec(x.shape, logical, mesh, rules, exclude_axes=exclude_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
