"""Distribution layer: platform meshes + logical-axis sharding rules.

The image's MESH layer names a *platform* (local / pod / multipod); the
container resolves it to a concrete device mesh here. Model code never sees
the mesh -- it annotates tensors with logical axis names, and the rules in
``dist.sharding`` translate those names into mesh ``PartitionSpec``s.
"""

from repro.dist.mesh import PLATFORMS, batch_axes, make_platform_mesh
from repro.dist.sharding import (
    ShardingRules,
    check_divisibility,
    constrain,
    logical_sharding,
    safe_spec,
)

__all__ = [
    "PLATFORMS",
    "batch_axes",
    "make_platform_mesh",
    "ShardingRules",
    "check_divisibility",
    "constrain",
    "logical_sharding",
    "safe_spec",
]
