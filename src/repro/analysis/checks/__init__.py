"""The six project-invariant checks behind ``repro lint``.

Order here is presentation order for ``repro lint --list-rules``; each
module's docstring is the authoritative statement of its contract.
"""

from repro.analysis.checks.donation import DonationCheck
from repro.analysis.checks.metrics_writer import MetricsWriterCheck
from repro.analysis.checks.span_lifecycle import SpanLifecycleCheck
from repro.analysis.checks.pool_mutation import PoolMutationCheck
from repro.analysis.checks.jit_capture import JitCaptureCheck
from repro.analysis.checks.tick_determinism import TickDeterminismCheck

ALL_CHECKS = [
    DonationCheck,
    MetricsWriterCheck,
    SpanLifecycleCheck,
    PoolMutationCheck,
    JitCaptureCheck,
    TickDeterminismCheck,
]

__all__ = ["ALL_CHECKS", "DonationCheck", "MetricsWriterCheck",
           "SpanLifecycleCheck", "PoolMutationCheck", "JitCaptureCheck",
           "TickDeterminismCheck"]
