"""donation: jax buffer-donation discipline.

``jax.jit(..., donate_argnums=...)`` invalidates the donated Python
reference the moment the jitted callable runs -- the buffer is aliased to
an output and may be overwritten in place. Reading the old reference
afterwards is undefined behaviour that XLA only sometimes reports. The
serving hot path leans on donation everywhere (``SlotEngine.decode``
donates the KV cache, the module-level ``_insert_*_jit`` scatters donate
the bank), so the rule is:

* after a call to a donating callable, the donated argument expression
  must not be read again until it is re-assigned (the canonical shape is
  ``self.cache = donating(self.cache, ...)`` -- donation and re-bind in
  one statement);
* a ``jax.jit`` whose ``donate_argnums`` points at the live prefix-page
  pool must not exist: the prefix-prefill path reads cached pages straight
  out of the pool, so the pool argument stays undonated
  (see ``Container.lower_serve_step``, the ``pfx`` branch).
"""

from __future__ import annotations

import ast
import re

from repro.analysis.core import Check, Finding

_POOL_RE = re.compile(r"\bpool\b", re.IGNORECASE)


def _jit_call(node: ast.AST) -> ast.Call | None:
    if isinstance(node, ast.Call) and \
            Check.unparse(node.func) in ("jax.jit", "jit"):
        return node
    return None


def _donate_positions(call: ast.Call) -> tuple[int, ...]:
    """Literal donate_argnums positions of a jax.jit call; () when absent
    or unresolvable. An ``(1,) if donate else ()`` IfExp resolves to the
    donating branch -- the hazard exists whenever donation is possible."""
    arg = Check.call_kwarg(call, "donate_argnums")
    if isinstance(arg, ast.IfExp):
        for branch in (arg.body, arg.orelse):
            pos = _literal_positions(branch)
            if pos:
                return pos
        return ()
    return _literal_positions(arg)


def _literal_positions(node: ast.AST | None) -> tuple[int, ...]:
    if node is None:
        return ()
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                out.append(e.value)
        return tuple(out)
    return ()


def _walk_stmt(stmt: ast.stmt):
    """Every expression node of one statement, not descending into nested
    function/class/lambda bodies (their execution is deferred)."""
    todo = [stmt]
    while todo:
        node = todo.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef, ast.Lambda)):
                continue
            todo.append(child)


def _store_targets(stmt: ast.stmt) -> list[str]:
    """Expressions re-bound by this statement (clearing a pending
    donation). Subscript stores do NOT clear -- ``x[0] = v`` still reads
    the donated buffer ``x``."""
    out = []

    def tgt(node):
        if isinstance(node, (ast.Name, ast.Attribute)):
            out.append(Check.unparse(node))
        elif isinstance(node, (ast.Tuple, ast.List)):
            for e in node.elts:
                tgt(e)
        elif isinstance(node, ast.Starred):
            tgt(node.value)

    if isinstance(stmt, ast.Assign):
        for t in stmt.targets:
            tgt(t)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        tgt(stmt.target)
    elif isinstance(stmt, ast.Delete):
        for t in stmt.targets:
            tgt(t)
    elif isinstance(stmt, ast.For):
        tgt(stmt.target)
    elif isinstance(stmt, ast.With):
        for item in stmt.items:
            if item.optional_vars is not None:
                tgt(item.optional_vars)
    return out


class DonationCheck(Check):
    rule = "donation"
    description = ("no use of a donated buffer reference after the "
                   "donating call; the prefix pool stays undonated")

    # attribute callables known to donate (position is 0-based over the
    # call's own positional args): SlotEngine.decode donates the cache
    # (Container builds it with donate_argnums=(1,)), self._insert binds
    # the module-level donating scatter.
    KNOWN_DONATING_ATTRS = {"decode": (1,), "_insert": (0,)}

    def run(self, project):
        for f in project.files:
            if f.tree is None:
                continue
            module_names = {}
            for node in f.tree.body:
                if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name):
                    jc = _jit_call(node.value)
                    if jc is not None:
                        pos = _donate_positions(jc)
                        if pos:
                            module_names[node.targets[0].id] = pos
            for fn in ast.walk(f.tree):
                if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield from self._check_function(f, fn, module_names)

    # -- use-after-donation ---------------------------------------------------
    def _check_function(self, f, fn, module_names):
        donating = dict(module_names)
        findings: list[Finding] = []
        seen: set[tuple[int, str]] = set()
        self._scan_block(f, fn.body, donating, {}, findings, seen)
        yield from findings
        yield from self._check_pool_donation(f, fn)

    def _scan_block(self, f, stmts, donating, pending, findings, seen):
        """Linear walk; ``pending`` maps a donated expression string to the
        line it was donated on. Branches fork a copy and merge by union;
        loop bodies run twice so a donation can collide with a read in the
        next iteration."""
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            if isinstance(stmt, ast.If):
                pb = dict(pending)
                self._scan_block(f, stmt.body, donating, pb, findings, seen)
                po = dict(pending)
                self._scan_block(f, stmt.orelse, donating, po, findings,
                                 seen)
                pending.clear()
                pending.update(pb)
                pending.update(po)
                continue
            if isinstance(stmt, (ast.For, ast.While)):
                self._simple_stmt(f, stmt, donating, pending, findings,
                                  seen, header_only=True)
                for _ in range(2):
                    self._scan_block(f, stmt.body, donating, pending,
                                     findings, seen)
                self._scan_block(f, stmt.orelse, donating, pending,
                                 findings, seen)
                continue
            if isinstance(stmt, ast.Try):
                self._scan_block(f, stmt.body, donating, pending, findings,
                                 seen)
                for h in stmt.handlers:
                    self._scan_block(f, h.body, donating, dict(pending),
                                     findings, seen)
                self._scan_block(f, stmt.finalbody, donating, pending,
                                 findings, seen)
                continue
            if isinstance(stmt, ast.With):
                self._simple_stmt(f, stmt, donating, pending, findings,
                                  seen, header_only=True)
                self._scan_block(f, stmt.body, donating, pending, findings,
                                 seen)
                continue
            self._simple_stmt(f, stmt, donating, pending, findings, seen)

    def _simple_stmt(self, f, stmt, donating, pending, findings, seen,
                     header_only=False):
        nodes = (list(ast.iter_child_nodes(stmt))[:1] if header_only
                 else [stmt])
        # 1) reads of still-pending donated references
        for root in nodes:
            for node in _walk_stmt(root) if root is stmt \
                    else ast.walk(root):
                if isinstance(node, (ast.Name, ast.Attribute)) and \
                        isinstance(getattr(node, "ctx", None), ast.Load):
                    expr = self.unparse(node)
                    if expr in pending:
                        key = (node.lineno, expr)
                        if key not in seen:
                            seen.add(key)
                            findings.append(Finding(
                                rule=self.rule, file=f.rel,
                                line=node.lineno,
                                message=f"{expr!r} is read after being "
                                        f"donated on line "
                                        f"{pending[expr]} -- the buffer "
                                        "may already be overwritten",
                                hint="re-bind the reference from the "
                                     "call's output (x = step(x, ...)) "
                                     "before any further use"))
        if header_only:
            return
        # 2) register new local donating names + new donations
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and \
                isinstance(stmt.targets[0], ast.Name):
            jc = _jit_call(stmt.value)
            if jc is not None:
                pos = _donate_positions(jc)
                if pos:
                    donating[stmt.targets[0].id] = pos
                else:           # rebound to a non-donating jit
                    donating.pop(stmt.targets[0].id, None)
        for node in _walk_stmt(stmt):
            if not isinstance(node, ast.Call):
                continue
            positions = None
            if isinstance(node.func, ast.Name):
                positions = donating.get(node.func.id)
            elif isinstance(node.func, ast.Attribute):
                positions = (donating.get(node.func.attr)
                             or self.KNOWN_DONATING_ATTRS.get(
                                 node.func.attr))
            if not positions:
                continue
            for p in positions:
                if p < len(node.args) and \
                        isinstance(node.args[p], (ast.Name, ast.Attribute)):
                    pending[self.unparse(node.args[p])] = node.lineno
        # 3) re-binds clear pending donations
        for expr in _store_targets(stmt):
            pending.pop(expr, None)

    # -- prefix-pool donation -------------------------------------------------
    def _check_pool_donation(self, f, fn):
        """A jitted step whose donated argument is the live page pool:
        find ``v = jax.jit(..., donate_argnums=K)`` followed by
        ``v.lower(...)`` / ``v(...)`` with a pool-named expression at a
        donated position."""
        # every rebinding of each name, in line order: names like `jitted`
        # are reused across branches (some donating, some not), so a call
        # site resolves against its NEAREST preceding assignment
        bindings: dict[str, list[tuple[int, tuple[int, ...]]]] = {}
        any_donating = False
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name):
                jc = _jit_call(stmt.value)
                pos = _donate_positions(jc) if jc is not None else ()
                bindings.setdefault(stmt.targets[0].id, []).append(
                    (stmt.lineno, pos))
                any_donating = any_donating or bool(pos)
        if not any_donating:
            return
        for hist in bindings.values():
            hist.sort()
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = None
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "lower" and \
                    isinstance(node.func.value, ast.Name):
                name = node.func.value.id
            elif isinstance(node.func, ast.Name):
                name = node.func.id
            positions: tuple[int, ...] = ()
            for lineno, pos in bindings.get(name or "", ()):
                if lineno < node.lineno:
                    positions = pos
                else:
                    break
            for p in positions:
                if p < len(node.args) and \
                        _POOL_RE.search(self.unparse(node.args[p])):
                    yield Finding(
                        rule=self.rule, file=f.rel, line=node.lineno,
                        message=f"donated argument {p} of {name!r} is the "
                                "live prefix page pool "
                                f"({self.unparse(node.args[p])!r})",
                        hint="the prefix-prefill path reads cached pages "
                             "out of the pool; lower it WITHOUT "
                             "donate_argnums (see Container."
                             "lower_serve_step, pfx branch)")
