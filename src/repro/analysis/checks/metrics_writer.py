"""metrics-writer: single-writer completion metrics.

The fleet rollup and the bitwise live-vs-recompute acceptance test both
assume the completion histograms (``latency_ticks``, ``ttft_ticks``,
``itl_milliticks``) and counters (``requests_completed``, ``tokens_out``)
have exactly one writer: ``obs/report.py:observe_completion``. A second
recording site anywhere else desynchronises the recompute and silently
breaks ``completion_snapshot`` equality. Registering the instruments
elsewhere (for eager visibility in ``repro top``) is fine -- only
``.record(...)`` / ``.inc(...)`` is restricted.

The check also guards registry hygiene: one name -> one instrument kind
across the tree, and label values must be bounded (no f-strings, no
``.format``/``%`` interpolation, no per-request ``rid`` labels -- each
distinct label set is a separate registry series).
"""

from __future__ import annotations

import ast

from repro.analysis.core import Check, Finding

PROTECTED_HISTOGRAMS = ("latency_ticks", "ttft_ticks", "itl_milliticks")
PROTECTED_COUNTERS = ("requests_completed", "tokens_out")
WRITER_SUFFIX = "obs/report.py"

_FACTORIES = ("counter", "gauge", "histogram")
_RESERVED_KWARGS = {"width", "n_buckets"}
_WRITE_METHODS = {"record", "inc"}


def _is_writer(rel: str) -> bool:
    return rel.replace("\\", "/").endswith(WRITER_SUFFIX)


def _protected_factory(call: ast.Call) -> str | None:
    """The protected metric name when ``call`` is a factory call creating
    one of the completion instruments, else None."""
    if not isinstance(call.func, ast.Attribute) or not call.args:
        return None
    name = Check.const_str(call.args[0])
    if call.func.attr == "histogram" and name in PROTECTED_HISTOGRAMS:
        return name
    if call.func.attr == "counter" and name in PROTECTED_COUNTERS:
        return name
    return None


class MetricsWriterCheck(Check):
    rule = "metrics-writer"
    description = ("observe_completion is the only writer of completion "
                   "metrics; registry names collision-free, label values "
                   "bounded")

    def run(self, project):
        # name -> (kind, rel, line) across the whole scanned tree
        registrations: dict[str, tuple[str, str, int]] = {}
        for f in project.files:
            if f.tree is None:
                continue
            writer = _is_writer(f.rel)
            # var expr -> protected metric name, from assignments like
            # ``h = reg.histogram("ttft_ticks", ...)``
            bound: dict[str, str] = {}
            for node in ast.walk(f.tree):
                if isinstance(node, ast.Assign) and \
                        isinstance(node.value, ast.Call):
                    name = _protected_factory(node.value)
                    if name:
                        for t in node.targets:
                            if isinstance(t, (ast.Name, ast.Attribute)):
                                bound[self.unparse(t)] = name
                if not isinstance(node, ast.Call):
                    continue
                yield from self._registration(f, node, registrations)
                yield from self._labels(f, node)
                if not writer:
                    yield from self._write_site(f, node, bound)

    # -- the single-writer rule -----------------------------------------------
    def _write_site(self, f, node: ast.Call, bound):
        func = node.func
        if not isinstance(func, ast.Attribute) or \
                func.attr not in _WRITE_METHODS:
            return
        recv = func.value
        name = None
        if isinstance(recv, ast.Call):            # chained factory().record
            name = _protected_factory(recv)
        elif isinstance(recv, (ast.Name, ast.Attribute)):
            name = bound.get(self.unparse(recv))
        if name:
            yield Finding(
                rule=self.rule, file=f.rel, line=node.lineno,
                message=f"completion metric {name!r} is recorded outside "
                        f"{WRITER_SUFFIX}:observe_completion",
                hint="route the observation through observe_completion() "
                     "so the live registry stays bitwise-recomputable "
                     "from the trace buffers")

    # -- registry hygiene -----------------------------------------------------
    def _registration(self, f, node: ast.Call, registrations):
        func = node.func
        if not isinstance(func, ast.Attribute) or \
                func.attr not in _FACTORIES or not node.args:
            return
        name = self.const_str(node.args[0])
        if name is None:
            return
        prior = registrations.get(name)
        if prior is None:
            registrations[name] = (func.attr, f.rel, node.lineno)
        elif prior[0] != func.attr:
            yield Finding(
                rule=self.rule, file=f.rel, line=node.lineno,
                message=f"metric name {name!r} registered as "
                        f"{func.attr} here but as {prior[0]} at "
                        f"{prior[1]}:{prior[2]}",
                hint="one name -> one instrument kind; rename one of "
                     "the two")

    def _labels(self, f, node: ast.Call):
        func = node.func
        if not isinstance(func, ast.Attribute) or \
                func.attr not in _FACTORIES or not node.args:
            return
        if self.const_str(node.args[0]) is None:
            return
        for kw in node.keywords:
            if kw.arg is None or kw.arg in _RESERVED_KWARGS:
                continue
            bad = self._unbounded(kw.value)
            if bad:
                yield Finding(
                    rule=self.rule, file=f.rel, line=node.lineno,
                    message=f"label {kw.arg!r} has unbounded value "
                            f"({bad}): each distinct value is a separate "
                            "registry series",
                    hint="label values must come from a small fixed set "
                         "(pod id, phase, reason); put per-request detail "
                         "in the trace, not the label")

    @staticmethod
    def _unbounded(value: ast.expr) -> str | None:
        if isinstance(value, ast.JoinedStr):
            return "f-string"
        if isinstance(value, ast.Call) and \
                isinstance(value.func, ast.Attribute) and \
                value.func.attr == "format":
            return ".format() interpolation"
        if isinstance(value, ast.BinOp) and isinstance(value.op, ast.Mod):
            return "%-interpolation"
        for sub in ast.walk(value):
            if isinstance(sub, ast.Name) and sub.id == "rid":
                return "per-request rid"
            if isinstance(sub, ast.Attribute) and sub.attr == "rid":
                return "per-request rid"
        return None
