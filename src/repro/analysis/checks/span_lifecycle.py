"""span-lifecycle: every trace emission is a legal state transition.

The span state machine lives in ``obs/tracing.py`` as data
(``SPAN_KINDS`` + ``SPAN_TRANSITIONS``); ``validate_span_log`` replays it
at runtime, ``export_chrome`` renders it, and the bitwise
live-vs-recompute test relies on the lifecycle derived from it. This
check keeps the three representations in sync without importing any of
them:

1. the transition table's keys must be exactly ``SPAN_KINDS`` (adding a
   span type without wiring its transitions is an error);
2. every kind must appear literally in ``export_chrome`` (the renderer
   handles it) -- a new span type silently dropped from traces is how
   lifecycle bugs hide;
3. every ``buffer.record(rid, "<kind>", tick)`` emission site must name a
   known kind, and -- for orchestrator code -- the *set* of kinds a file
   scope emits must be closed under the table: each emitted kind either
   may start a lifecycle or has an emitted predecessor, and each emitted
   non-terminal kind has an emitted successor (``preempt`` without
   ``resume``/``shed``/``reject`` anywhere is a stuck lifecycle);
4. ``TERMINAL_SPANS`` must be a literal subset of ``SPAN_KINDS`` and
   genuinely terminal: no transition may name a terminal kind as a
   predecessor (``validate_span_log`` refuses successors of terminals at
   runtime, and the cross-process fleet-closure check counts a lifecycle
   closed at them -- a table that disagrees makes the fabric's
   zero-lost-requests gate vacuous).
"""

from __future__ import annotations

import ast

from repro.analysis.core import Check, Finding

TRACING_REL = "src/repro/orchestrator/obs/tracing.py"


def _module_assign(tree: ast.Module, name: str) -> ast.expr | None:
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    return node.value
        elif isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name) and \
                node.target.id == name and node.value is not None:
            return node.value
    return None


def _find_function(tree: ast.Module, name: str):
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


class SpanLifecycleCheck(Check):
    rule = "span-lifecycle"
    description = ("trace emissions name known span kinds and form legal, "
                   "closed lifecycles; exporter handles every kind")

    def run(self, project):
        tracing = project.locate(TRACING_REL)
        if tracing is None or tracing.tree is None:
            yield Finding(
                rule=self.rule, file=TRACING_REL, line=1,
                message="cannot locate obs/tracing.py to derive the span "
                        "state machine",
                severity="warning",
                hint="run repro lint from the repo root")
            return
        kinds, transitions, table_findings = self._load_machine(tracing)
        yield from table_findings
        if kinds and transitions:
            yield from self._check_exporter(tracing, kinds)
        # emission sites; orchestrator files pool into one closure check
        # (the router records "route"/"reroute" in its own buffer, the
        # scheduler continues with "submit".."complete" in the pod's --
        # lifecycles cross files AND processes by design; the runtime
        # analog pools per-process span files via validate_fleet_closure)
        emitted: dict[str, tuple[str, int]] = {}  # kind -> first site
        for f in project.files:
            if f.tree is None or f is tracing:
                continue
            orchestrator = self._orchestrator_scope(f.rel)
            for node in ast.walk(f.tree):
                site = self._emission(node)
                if site is None:
                    continue
                kind_node, line = site
                kind = self.const_str(kind_node)
                if kind is None:
                    yield Finding(
                        rule=self.rule, file=f.rel, line=line,
                        message="span kind should be a string literal so "
                                "the lifecycle is statically checkable",
                        severity="warning",
                        hint="emit a literal kind; branch at the call "
                             "site, not inside the kind argument")
                    continue
                if kinds and kind not in kinds:
                    yield Finding(
                        rule=self.rule, file=f.rel, line=line,
                        message=f"unknown span kind {kind!r} (not in "
                                "tracing.SPAN_KINDS)",
                        hint="add the kind to SPAN_KINDS + "
                             "SPAN_TRANSITIONS and teach export_chrome "
                             "to render it")
                    continue
                if orchestrator:
                    emitted.setdefault(kind, (f.rel, line))
        if transitions and emitted:
            yield from self._check_closure(emitted, transitions)

    # -- deriving the machine -------------------------------------------------
    def _load_machine(self, tracing):
        findings = []
        kinds: tuple[str, ...] = ()
        transitions: dict[str, tuple] = {}
        kinds_node = _module_assign(tracing.tree, "SPAN_KINDS")
        trans_node = _module_assign(tracing.tree, "SPAN_TRANSITIONS")
        try:
            if kinds_node is not None:
                kinds = tuple(ast.literal_eval(kinds_node))
        except ValueError:
            kinds_node = None
        try:
            if trans_node is not None:
                transitions = dict(ast.literal_eval(trans_node))
        except ValueError:
            trans_node = None
        if kinds_node is None:
            findings.append(Finding(
                rule=self.rule, file=tracing.rel, line=1,
                message="SPAN_KINDS is missing or not a literal tuple"))
        if trans_node is None:
            findings.append(Finding(
                rule=self.rule, file=tracing.rel, line=1,
                message="SPAN_TRANSITIONS is missing or not a literal "
                        "dict",
                hint="define SPAN_TRANSITIONS = {kind: (allowed "
                     "predecessors...)} next to SPAN_KINDS"))
        if kinds and transitions and set(kinds) != set(transitions):
            missing = sorted(set(kinds) - set(transitions))
            extra = sorted(set(transitions) - set(kinds))
            findings.append(Finding(
                rule=self.rule, file=tracing.rel, line=1,
                message="SPAN_TRANSITIONS keys != SPAN_KINDS "
                        f"(missing {missing}, extra {extra})",
                hint="every span kind needs an entry in the transition "
                     "table"))
        findings.extend(self._check_terminals(tracing, kinds, transitions))
        return kinds, transitions, findings

    def _check_terminals(self, tracing, kinds, transitions):
        term_node = _module_assign(tracing.tree, "TERMINAL_SPANS")
        try:
            terminals = (tuple(ast.literal_eval(term_node))
                         if term_node is not None else None)
        except ValueError:
            terminals = None
        if terminals is None:
            yield Finding(
                rule=self.rule, file=tracing.rel, line=1,
                message="TERMINAL_SPANS is missing or not a literal "
                        "tuple",
                hint="define TERMINAL_SPANS next to SPAN_TRANSITIONS; "
                     "the fleet-closure check counts lifecycles closed "
                     "at these kinds")
            return
        if kinds:
            unknown = sorted(set(terminals) - set(kinds))
            if unknown:
                yield Finding(
                    rule=self.rule, file=tracing.rel, line=1,
                    message=f"TERMINAL_SPANS entries {unknown} are not "
                            "in SPAN_KINDS")
        for kind in terminals:
            followers = sorted(
                k for k, preds in transitions.items()
                if isinstance(preds, tuple) and kind in preds)
            if followers:
                yield Finding(
                    rule=self.rule, file=tracing.rel, line=1,
                    message=f"terminal span {kind!r} is a legal "
                            f"predecessor of {followers} -- terminals "
                            "must have no successors",
                    hint="either drop the kind from TERMINAL_SPANS or "
                         "remove it from those transition entries; "
                         "validate_span_log and the fleet-closure check "
                         "both assume terminals end the log")

    def _check_exporter(self, tracing, kinds):
        exporter = _find_function(tracing.tree, "export_chrome")
        if exporter is None:
            yield Finding(
                rule=self.rule, file=tracing.rel, line=1,
                message="export_chrome not found; span kinds have no "
                        "renderer")
            return
        literals = {n.value for n in ast.walk(exporter)
                    if isinstance(n, ast.Constant)
                    and isinstance(n.value, str)}
        for kind in kinds:
            if kind not in literals:
                yield Finding(
                    rule=self.rule, file=tracing.rel,
                    line=exporter.lineno,
                    message=f"span kind {kind!r} is not handled by "
                            "export_chrome",
                    hint="add a phase/instant mapping for the new kind "
                         "so Chrome traces keep rendering it")

    # -- emission sites -------------------------------------------------------
    @staticmethod
    def _emission(node: ast.AST):
        """``<buffer>.record(rid, kind, tick, ...)`` -- a trace emission
        is a .record call with >= 3 positional args (metric .record calls
        take one)."""
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr == "record" and len(node.args) >= 3:
            return node.args[1], node.lineno
        return None

    @staticmethod
    def _orchestrator_scope(rel: str) -> bool:
        """Files whose emissions participate in the closure check: the
        orchestrator package (minus obs/, whose buffers are generic), or
        any file named like an orchestrator module (lint fixtures)."""
        parts = rel.replace("\\", "/").split("/")
        if "obs" in parts:
            return False
        return "orchestrator" in parts[:-1] or \
            parts[-1] in ("scheduler.py", "router.py", "pod.py")

    def _check_closure(self, emitted, transitions):
        """Fleet-wide closure over orchestrator emissions: every emitted
        kind must be reachable (may start a lifecycle, or some emitted
        kind is a legal predecessor) and every emitted non-terminal kind
        must have an emitted successor. One hop each way transitively
        covers whole chains (``complete`` needs ``prefill``/
        ``decode_chunk``, which in turn need ``admit``...)."""
        kinds = set(emitted)
        for kind in sorted(emitted):
            rel, line = emitted[kind]
            preds = transitions.get(kind, ())
            if preds and None not in preds and not (set(preds) & kinds):
                yield Finding(
                    rule=self.rule, file=rel, line=line,
                    message=f"span {kind!r} is emitted but none of its "
                            f"legal predecessors {tuple(preds)} are "
                            "emitted anywhere -- the transition can "
                            "never be legal",
                    hint="emit the predecessor span (or delete this "
                         "unreachable emission)")
            successors = tuple(k for k, pr in transitions.items()
                               if kind in pr)
            if successors and not (set(successors) & kinds):
                yield Finding(
                    rule=self.rule, file=rel, line=line,
                    message=f"span {kind!r} is emitted but no successor "
                            f"({successors}) is ever emitted -- "
                            "lifecycles entering this state get stuck",
                    hint="a non-terminal span needs a continuation "
                         "(e.g. every preempt must later resume, shed "
                         "or reject)")
