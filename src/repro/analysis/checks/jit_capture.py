"""jit-capture: closures handed to jax.jit must be pure of orchestrator
state.

``jax.jit`` traces its callable once per input signature; anything the
callable *closes over* is baked into the trace. Capturing per-tick
mutable orchestrator state (``self.pos``, ``self.cur_tok``, the page
table, the pool object...) produces either a stale snapshot (the jitted
step keeps using tick-0 values) or a silent retrace storm when jax
treats the captured value as a new constant each call. The data must
flow through the traced *arguments* instead.

Second hazard: ``static_argnums`` requires hashable values -- calling a
jitted function with a list/dict/set display at a static position raises
at runtime (or worse, retraces per call once someone "fixes" it by
tupling inconsistently). We flag display literals at statically-declared
positions.
"""

from __future__ import annotations

import ast

from repro.analysis.core import Check, Finding

# per-tick mutable orchestrator attributes: scheduler slot state, pool
# bookkeeping, queue contents. Capturing any of these in a jitted closure
# snapshots one tick forever.
MUTABLE_STATE = {"pos", "cur_tok", "active", "free", "cache", "tick",
                 "queue", "paused", "table", "reserved", "owned",
                 "shared", "prefix", "pool"}

_DISPLAYS = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
             ast.SetComp)


def _jit_call(node: ast.AST) -> ast.Call | None:
    if isinstance(node, ast.Call) and \
            Check.unparse(node.func) in ("jax.jit", "jit"):
        return node
    return None


def _static_positions(call: ast.Call) -> tuple[int, ...]:
    arg = Check.call_kwarg(call, "static_argnums")
    if isinstance(arg, ast.Constant) and isinstance(arg.value, int):
        return (arg.value,)
    if isinstance(arg, (ast.Tuple, ast.List)):
        return tuple(e.value for e in arg.elts
                     if isinstance(e, ast.Constant)
                     and isinstance(e.value, int))
    return ()


class JitCaptureCheck(Check):
    rule = "jit-capture"
    description = ("jitted closures must not capture per-tick mutable "
                   "state; static_argnums positions must get hashable "
                   "values")

    def run(self, project):
        for f in project.files:
            if f.tree is None:
                continue
            yield from self._check_file(f)

    def _check_file(self, f):
        # function-local defs, for resolving jax.jit(local_fn)
        local_defs: dict[int, dict[str, ast.FunctionDef]] = {}
        for fn in ast.walk(f.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                local_defs[id(fn)] = {
                    sub.name: sub for sub in ast.walk(fn)
                    if isinstance(sub, ast.FunctionDef) and sub is not fn}
        # static_argnums bookkeeping: jitted name -> static positions
        statics: dict[str, tuple[int, ...]] = {}
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                jc = _jit_call(node.value)
                if jc is not None:
                    pos = _static_positions(jc)
                    if pos:
                        statics[node.targets[0].id] = pos
            jc = _jit_call(node)
            if jc is None:
                continue
            yield from self._check_capture(f, jc, local_defs)
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            pos: tuple[int, ...] = ()
            inner = _jit_call(node.func)
            if inner is not None:            # jax.jit(f, ...)(args)
                pos = _static_positions(inner)
            elif isinstance(node.func, ast.Name):
                pos = statics.get(node.func.id, ())
            for p in pos:
                if p < len(node.args) and \
                        isinstance(node.args[p], _DISPLAYS):
                    yield Finding(
                        rule=self.rule, file=f.rel, line=node.lineno,
                        message=f"unhashable "
                                f"{type(node.args[p]).__name__.lower()} "
                                f"literal at static_argnums position {p}",
                        hint="static args are hashed for the trace "
                             "cache; pass a tuple (or hoist the value "
                             "into the closure if it is constant)")

    def _check_capture(self, f, jit: ast.Call, local_defs):
        target = jit.args[0] if jit.args else \
            self.call_kwarg(jit, "fun")
        bodies: list[ast.AST] = []
        if isinstance(target, ast.Lambda):
            bodies = [target.body]
        elif isinstance(target, ast.Name):
            # a locally-defined closure (module-level functions take
            # their state as arguments by construction)
            for defs in local_defs.values():
                fn = defs.get(target.id)
                if fn is not None:
                    bodies = list(fn.body)
                    break
        for body in bodies:
            for node in ast.walk(body):
                if isinstance(node, ast.Attribute) and \
                        isinstance(node.ctx, ast.Load) and \
                        isinstance(node.value, ast.Name) and \
                        node.value.id == "self" and \
                        node.attr in MUTABLE_STATE:
                    yield Finding(
                        rule=self.rule, file=f.rel, line=node.lineno,
                        message=f"jitted closure captures per-tick "
                                f"mutable state 'self.{node.attr}'",
                        hint="pass it as a traced argument to the "
                             "jitted function; captured state is "
                             "snapshotted at trace time and never "
                             "updates")
