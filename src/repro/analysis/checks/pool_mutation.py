"""pool-mutation: PagePool internals have one owner.

PagePool's refcount/free-list/registry bookkeeping (``free``, ``table``,
``owned``, ``shared``, ``reserved``, ``refcount``, ``radix``, ``store``,
``events``, ``_pinned``, ``paused``, ``_clock``) is kept consistent by
its own methods plus the ``check()`` invariant sweep. A scheduler that
pokes ``pool.refcount`` -- or reaches into the radix tree or the spill
store -- directly bypasses both, and the corruption only surfaces ticks
later as a double-free or a leaked page. Two sub-rules:

* outside ``page_pool.py``, no store/del/augmented-assign to a pool
  internal and no mutating container method (``append``, ``pop``,
  ``add``, ...) called on one;
* every *public* mutating method of PagePool (derived from the class
  body by fixpoint over self-calls) must be exercised by the property
  tests in ``tests/test_page_pool.py``, under ``check()`` -- an
  invariant nobody drives through the random schedule is an invariant
  that silently rots.
"""

from __future__ import annotations

import ast
import re

from repro.analysis.core import Check, Finding

POOL_REL = "src/repro/orchestrator/page_pool.py"
TESTS_REL = "tests/test_page_pool.py"

# bookkeeping attributes; intersected with what PagePool.__init__ actually
# assigns so renames don't leave the check pinned to stale names
INTERNAL_CANDIDATES = {"free", "table", "owned", "shared", "reserved",
                       "refcount", "radix", "store", "events", "_pinned",
                       "paused", "_clock"}
MUTATORS = {"append", "extend", "insert", "remove", "pop", "popleft",
            "clear", "add", "discard", "update", "setdefault", "sort"}

_POOL_RE = re.compile(r"pool", re.IGNORECASE)


def _is_pool_file(rel: str) -> bool:
    return rel.replace("\\", "/").endswith("page_pool.py")


def _self_attr(node: ast.AST) -> str | None:
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


class PoolMutationCheck(Check):
    rule = "pool-mutation"
    description = ("no mutation of PagePool internals outside "
                   "page_pool.py; every public mutating method covered "
                   "by the property tests")

    def run(self, project):
        pool = project.locate(POOL_REL)
        internals = self._derive_internals(pool)
        for f in project.files:
            if f.tree is None or _is_pool_file(f.rel):
                continue
            yield from self._check_file(f, internals)
        # coverage half only when page_pool.py itself is in scope
        if pool is not None and pool.tree is not None and \
                any(_is_pool_file(f.rel) for f in project.files):
            yield from self._check_coverage(project, pool, internals)

    def _derive_internals(self, pool) -> set[str]:
        if pool is None or pool.tree is None:
            return set(INTERNAL_CANDIDATES)
        assigned = set()
        for cls in ast.walk(pool.tree):
            if not (isinstance(cls, ast.ClassDef) and
                    cls.name == "PagePool"):
                continue
            for fn in cls.body:
                if isinstance(fn, ast.FunctionDef) and \
                        fn.name == "__init__":
                    for node in ast.walk(fn):
                        targets = []
                        if isinstance(node, ast.Assign):
                            targets = node.targets
                        elif isinstance(node, (ast.AnnAssign,
                                               ast.AugAssign)):
                            targets = [node.target]
                        for t in targets:
                            attr = _self_attr(t)
                            if attr:
                                assigned.add(attr)
        return (assigned & INTERNAL_CANDIDATES) or set(INTERNAL_CANDIDATES)

    # -- external mutation ----------------------------------------------------
    def _pool_internal(self, node: ast.AST, internals) -> str | None:
        """``<pool-ish>.<internal>`` or a subscript of one; returns the
        attribute name."""
        if isinstance(node, ast.Subscript):
            node = node.value
        if isinstance(node, ast.Attribute) and node.attr in internals and \
                _POOL_RE.search(self.unparse(node.value)):
            return node.attr
        return None

    def _check_file(self, f, internals):
        for node in ast.walk(f.tree):
            targets = []
            verb = "assigned"
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            elif isinstance(node, ast.Delete):
                targets, verb = node.targets, "deleted"
            for t in targets:
                attr = self._pool_internal(t, internals)
                if attr:
                    yield Finding(
                        rule=self.rule, file=f.rel, line=node.lineno,
                        message=f"PagePool internal {attr!r} is {verb} "
                                "directly outside page_pool.py",
                        hint="go through a PagePool method (reserve/"
                             "alloc_upto/release/share/cow/...) so "
                             "refcounts and the free list stay "
                             "consistent under check()")
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr in MUTATORS:
                attr = self._pool_internal(node.func.value, internals)
                if attr:
                    yield Finding(
                        rule=self.rule, file=f.rel, line=node.lineno,
                        message=f"mutating call .{node.func.attr}() on "
                                f"PagePool internal {attr!r} outside "
                                "page_pool.py",
                        hint="add/extend a PagePool method instead of "
                             "reaching into its bookkeeping")

    # -- property-test coverage -----------------------------------------------
    def _check_coverage(self, project, pool, internals):
        methods = self._public_mutating_methods(pool, internals)
        tests = project.locate(TESTS_REL)
        if tests is None or tests.tree is None:
            yield Finding(
                rule=self.rule, file=pool.rel, line=1,
                message=f"{TESTS_REL} not found; PagePool's mutating "
                        "API has no property coverage",
                severity="warning")
            return
        called = {n.func.attr for n in ast.walk(tests.tree)
                  if isinstance(n, ast.Call)
                  and isinstance(n.func, ast.Attribute)}
        for name, line in sorted(methods.items()):
            if name not in called:
                yield Finding(
                    rule=self.rule, file=pool.rel, line=line,
                    message=f"public mutating method {name!r} is never "
                            f"exercised by {TESTS_REL}",
                    hint="add it as an op in the random property "
                         "schedule so check() sees its effects "
                         "interleaved with the others")
        if "check" not in called:
            yield Finding(
                rule=self.rule, file=pool.rel, line=1,
                message=f"{TESTS_REL} never calls PagePool.check(); "
                        "mutations are not validated against the "
                        "invariants")

    def _public_mutating_methods(self, pool, internals) -> dict[str, int]:
        """Fixpoint: a method mutates if it writes a pool internal (or
        calls a container mutator on one) directly, or calls a mutating
        method; public = no leading underscore."""
        direct: dict[str, bool] = {}
        calls: dict[str, set[str]] = {}
        lines: dict[str, int] = {}
        for cls in ast.walk(pool.tree):
            if not (isinstance(cls, ast.ClassDef) and
                    cls.name == "PagePool"):
                continue
            for fn in cls.body:
                if not isinstance(fn, ast.FunctionDef) or \
                        fn.name == "__init__":
                    continue
                lines[fn.name] = fn.lineno
                calls[fn.name] = set()
                mutates = False
                for node in ast.walk(fn):
                    targets = []
                    if isinstance(node, ast.Assign):
                        targets = node.targets
                    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                        targets = [node.target]
                    elif isinstance(node, ast.Delete):
                        targets = node.targets
                    for t in targets:
                        base = t.value if isinstance(t, ast.Subscript) \
                            else t
                        if _self_attr(base) in internals:
                            mutates = True
                    if isinstance(node, ast.Call) and \
                            isinstance(node.func, ast.Attribute):
                        base = node.func.value
                        if isinstance(base, ast.Subscript):
                            base = base.value
                        if node.func.attr in MUTATORS and \
                                _self_attr(base) in internals:
                            mutates = True
                        if _self_attr(node.func) is not None or \
                                (isinstance(node.func.value, ast.Name)
                                 and node.func.value.id == "self"):
                            calls[fn.name].add(node.func.attr)
                direct[fn.name] = mutates
        mutating = {m for m, d in direct.items() if d}
        changed = True
        while changed:
            changed = False
            for m, callees in calls.items():
                if m not in mutating and callees & mutating:
                    mutating.add(m)
                    changed = True
        return {m: lines[m] for m in mutating if not m.startswith("_")}
