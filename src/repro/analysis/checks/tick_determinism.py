"""tick-determinism: scheduler/router step paths are clockless and
ordered.

The acceptance test for the observability layer recomputes the whole
metrics registry from the trace buffers and requires a *bitwise* match
with the live registry. That only holds because the orchestrator is
clocked in ticks: admission, routing, preemption and completion are pure
functions of (tick, queue contents, pool state). Wall-clock reads,
``random`` draws and unordered-``set`` iteration in those paths make two
runs (or the live run and its recompute) diverge.

Scope: files named like orchestrator step modules (``scheduler.py``,
``router.py``, ``request_queue.py``, ``pod.py``, ``page_pool.py``,
``prefix_registry.py`` -- the pool's eviction order and the radix walk
feed admission decisions, so they are step paths too), every function
except ``__init__`` (construction may seed ids and wall-clock offsets; steps
may not). Allowed escape hatch: ``time.perf_counter()`` assigned to a
``t0``-style local or accumulated into a ``*_s`` attribute -- that is
the sanctioned *reporting-only* duration pattern (never fed back into
scheduling decisions).
"""

from __future__ import annotations

import ast
import re

from repro.analysis.core import Check, Finding

SCOPE_BASENAMES = {"scheduler.py", "router.py", "request_queue.py",
                   "pod.py", "page_pool.py", "prefix_registry.py"}

_BANNED_CALLS = {
    "time.time", "time.monotonic", "time.monotonic_ns", "time.time_ns",
    "time.localtime", "time.ctime",
    "datetime.now", "datetime.utcnow", "datetime.datetime.now",
    "datetime.datetime.utcnow", "datetime.date.today", "date.today",
    "uuid.uuid1", "uuid.uuid4",
}
_BANNED_PREFIXES = ("random.", "np.random.", "numpy.random.")
_ALLOWED_RANDOM = {"np.random.default_rng", "numpy.random.default_rng"}
_TIMER_LOCAL_RE = re.compile(r"^t\d*$")


def _in_scope(rel: str) -> bool:
    return rel.replace("\\", "/").rsplit("/", 1)[-1] in SCOPE_BASENAMES


class TickDeterminismCheck(Check):
    rule = "tick-determinism"
    description = ("no wall-clock, random draws or unordered-set "
                   "iteration in scheduler/router step paths")

    def run(self, project):
        for f in project.files:
            if f.tree is None or not _in_scope(f.rel):
                continue
            set_attrs = self._set_attrs(f.tree)
            for fn in ast.walk(f.tree):
                if isinstance(fn, (ast.FunctionDef,
                                   ast.AsyncFunctionDef)) and \
                        fn.name != "__init__":
                    yield from self._check_function(f, fn, set_attrs)

    @staticmethod
    def _set_attrs(tree: ast.Module) -> set[str]:
        """self-attributes initialised to a set in any __init__ in this
        file (e.g. the router's drain list) -- iterating them raw is
        order-nondeterministic."""
        out = set()
        for fn in ast.walk(tree):
            if not (isinstance(fn, ast.FunctionDef) and
                    fn.name == "__init__"):
                continue
            for node in ast.walk(fn):
                target = value = None
                if isinstance(node, ast.Assign) and len(node.targets) == 1:
                    target, value = node.targets[0], node.value
                elif isinstance(node, ast.AnnAssign):
                    target, value = node.target, node.value
                if not (isinstance(target, ast.Attribute) and
                        isinstance(target.value, ast.Name) and
                        target.value.id == "self"):
                    continue
                if isinstance(value, (ast.Set, ast.SetComp)) or (
                        isinstance(value, ast.Call) and
                        isinstance(value.func, ast.Name) and
                        value.func.id in ("set", "frozenset")):
                    out.add(target.attr)
        return out

    def _check_function(self, f, fn, set_attrs):
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.stmt):
                yield from self._check_calls(f, stmt)
        for node in ast.walk(fn):
            if isinstance(node, ast.For):
                yield from self._check_iter(f, node.iter, set_attrs)
            elif isinstance(node, (ast.ListComp, ast.SetComp,
                                   ast.DictComp, ast.GeneratorExp)):
                for comp in node.generators:
                    yield from self._check_iter(f, comp.iter, set_attrs)

    # -- clock & randomness ---------------------------------------------------
    def _check_calls(self, f, stmt: ast.stmt):
        for node in ast.iter_child_nodes(stmt):
            if isinstance(node, ast.stmt):
                continue        # nested statements get their own pass
            for call in ast.walk(node):
                if not isinstance(call, ast.Call):
                    continue
                name = self.unparse(call.func)
                if name == "time.perf_counter":
                    if not self._sanctioned_timer(stmt):
                        yield Finding(
                            rule=self.rule, file=f.rel, line=call.lineno,
                            message="time.perf_counter() outside the "
                                    "reporting-only duration pattern",
                            hint="wall time may only be measured into a "
                                 "tN local or accumulated into a *_s "
                                 "attribute, never fed into scheduling "
                                 "decisions")
                    continue
                if name in _BANNED_CALLS:
                    yield Finding(
                        rule=self.rule, file=f.rel, line=call.lineno,
                        message=f"nondeterministic call {name}() in a "
                                "step path",
                        hint="the orchestrator is tick-clocked; derive "
                             "what you need from the tick counter or do "
                             "it in __init__")
                elif name.startswith(_BANNED_PREFIXES) and \
                        name not in _ALLOWED_RANDOM:
                    yield Finding(
                        rule=self.rule, file=f.rel, line=call.lineno,
                        message=f"unseeded random draw {name}() in a "
                                "step path",
                        hint="use a generator seeded in __init__ "
                             "(np.random.default_rng(seed)) so replays "
                             "are bitwise-identical")

    @staticmethod
    def _sanctioned_timer(stmt: ast.stmt) -> bool:
        """``t0 = time.perf_counter()`` or
        ``self.x_s += time.perf_counter() - t0``."""
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and \
                isinstance(stmt.targets[0], ast.Name) and \
                _TIMER_LOCAL_RE.match(stmt.targets[0].id):
            return True
        if isinstance(stmt, ast.AugAssign):
            t = stmt.target
            if isinstance(t, ast.Attribute) and t.attr.endswith("_s"):
                return True
            if isinstance(t, ast.Name) and t.id.endswith("_s"):
                return True
        return False

    # -- unordered iteration --------------------------------------------------
    def _check_iter(self, f, it: ast.expr, set_attrs):
        unordered = None
        if isinstance(it, (ast.Set, ast.SetComp)):
            unordered = "a set literal"
        elif isinstance(it, ast.Call) and \
                isinstance(it.func, ast.Name) and \
                it.func.id in ("set", "frozenset"):
            unordered = f"{it.func.id}(...)"
        elif isinstance(it, ast.Attribute) and it.attr in set_attrs:
            unordered = f"set attribute '{self.unparse(it)}'"
        if unordered:
            yield Finding(
                rule=self.rule, file=f.rel, line=it.lineno,
                message=f"iteration over {unordered} in a step path is "
                        "order-nondeterministic",
                hint="wrap it in sorted(...) -- tie-break order decides "
                     "which request is admitted/preempted first")
