"""Static analysis for the serving stack (``repro lint``).

Pure AST + string analysis of the repo's hand-maintained contracts --
donation discipline, the single-writer metrics rule, the span-lifecycle
state machine, PagePool mutation ownership, jit capture hygiene and
tick determinism. No imports of the checked code, no jax: a full run
takes well under a second.
"""

from repro.analysis.core import (
    Check,
    Finding,
    LintResult,
    Project,
    all_checks,
    load_baseline,
    main,
    run_lint,
    write_baseline,
)

__all__ = ["Check", "Finding", "LintResult", "Project", "all_checks",
           "load_baseline", "main", "run_lint", "write_baseline"]
