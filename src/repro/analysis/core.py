"""repro lint: the checker framework behind ``repro lint``.

The serving stack runs on hand-maintained contracts -- the single-writer
completion-metrics rule, PagePool's refcount discipline, the Container's
donation conventions, the span-lifecycle state machine the bitwise
live-vs-recompute check depends on. Each was enforced only by convention
and by whichever test happened to trip. This module turns them into
machine-checked invariants: a :class:`Check` walks parsed ASTs and yields
:class:`Finding`s with a rule id, ``file:line`` and a fix hint.

Conventions:

* **Suppression** -- ``# repro: lint-ok[rule-id]`` on the flagged line (or
  the line directly above it) silences that rule there; a comma list
  silences several, a bare ``# repro: lint-ok`` silences everything on the
  line. Suppressions are for *justified* exceptions (say why in a nearby
  comment), not for making CI green.
* **Baseline** -- ``--baseline findings.json`` filters out previously
  recorded findings (``--write-baseline`` records the current set), so the
  suite can land on a tree with known debt and only fail on NEW findings.
* **Ratchet** -- ``--ratchet ratchet.json`` fails the run when the number
  of ``lint-ok`` suppressions GREW past the committed count
  (``--write-ratchet`` records it). Baselines grandfather old findings;
  the ratchet stops new debt from hiding behind suppression comments --
  CI gates on both, so the only way to add a suppression is to commit the
  updated ratchet file in the same change, where review sees it.
* **Scope** -- checks see a :class:`Project` (every scanned file, parsed
  once) so cross-file rules (is ``PagePool.pause`` exercised by the
  property tests?) read both sides. Files outside the lint scope that a
  rule depends on (``page_pool.py`` internals, ``tracing.py``'s span
  table) are pulled in read-only via :meth:`Project.locate`.

Checks are pure AST + string analysis: no imports of the checked code, no
jax, so ``repro lint`` runs in well under a second and CI can gate on it
cheaply.
"""

from __future__ import annotations

import argparse
import ast
import json
import re
import sys
from dataclasses import dataclass, field
from pathlib import Path

# directories never walked when a scan path is a directory (explicit file
# arguments bypass this -- the fixture tests lint seeded-violation files)
EXCLUDED_DIRS = {"__pycache__", ".git", ".stevedore", ".hypothesis",
                 "lint_fixtures", ".pytest_cache"}

_SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*lint-ok(?:\[([A-Za-z0-9_,\- ]*)\])?")

SEVERITIES = ("error", "warning")


@dataclass(frozen=True)
class Finding:
    """One rule violation at ``file:line``."""
    rule: str
    file: str                   # path as scanned (repo-relative in CI)
    line: int
    message: str
    severity: str = "error"
    hint: str = ""

    @property
    def key(self) -> str:
        """Stable identity for baselines: rule + location."""
        return f"{self.rule}:{self.file}:{self.line}"

    def render(self) -> str:
        out = f"{self.file}:{self.line}: {self.severity} [{self.rule}] " \
              f"{self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out


class FileCtx:
    """One scanned file: source, parsed AST, and its suppression map."""

    def __init__(self, path: Path, rel: str):
        self.path = path
        self.rel = rel
        self.source = path.read_text()
        self.lines = self.source.splitlines()
        try:
            self.tree: ast.Module | None = ast.parse(self.source,
                                                     filename=rel)
        except SyntaxError as e:
            self.tree = None
            self.syntax_error = e
        # line -> set of suppressed rule ids ("*" = all)
        self.suppressions: dict[int, set[str]] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if not m:
                continue
            rules = ({r.strip() for r in m.group(1).split(",") if r.strip()}
                     if m.group(1) else {"*"})
            self.suppressions[i] = rules

    def suppressed(self, rule: str, line: int) -> bool:
        """A finding is suppressed by a marker on its own line or on the
        line directly above (for lines too long to carry a comment)."""
        for ln in (line, line - 1):
            rules = self.suppressions.get(ln)
            if rules and ("*" in rules or rule in rules):
                return True
        return False


class Project:
    """Everything a check may look at: scanned files + root-anchored
    lookups for contract files outside the scan scope."""

    def __init__(self, root: Path, files: list[FileCtx]):
        self.root = root
        self.files = files
        self._extra: dict[str, FileCtx | None] = {}

    def locate(self, rel: str) -> FileCtx | None:
        """Find a file by repo-relative suffix: scanned files first, then
        a read-only load from ``root/rel``. Returns None when absent."""
        suffix = rel.replace("\\", "/")
        for f in self.files:
            if f.rel.replace("\\", "/").endswith(suffix):
                return f
        if rel not in self._extra:
            p = self.root / rel
            self._extra[rel] = FileCtx(p, rel) if p.is_file() else None
        return self._extra[rel]


class Check:
    """Base class: subclasses set ``rule``/``description`` and implement
    ``run(project)`` yielding Findings. One instance per lint run."""

    rule = "abstract"
    description = ""

    def run(self, project: Project):
        raise NotImplementedError

    # -- shared AST helpers ---------------------------------------------------
    @staticmethod
    def unparse(node: ast.AST) -> str:
        try:
            return ast.unparse(node)
        except Exception:               # pragma: no cover - malformed node
            return "<expr>"

    @staticmethod
    def call_kwarg(call: ast.Call, name: str) -> ast.expr | None:
        for kw in call.keywords:
            if kw.arg == name:
                return kw.value
        return None

    @staticmethod
    def const_str(node: ast.AST) -> str | None:
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            return node.value
        return None


@dataclass
class LintResult:
    findings: list[Finding] = field(default_factory=list)
    suppressed: int = 0
    baselined: int = 0
    files: int = 0

    @property
    def errors(self) -> int:
        return sum(1 for f in self.findings if f.severity == "error")

    @property
    def warnings(self) -> int:
        return sum(1 for f in self.findings if f.severity == "warning")


def _collect_files(paths: list[str]) -> list[Path]:
    out: list[Path] = []
    seen: set[Path] = set()
    for p in paths:
        path = Path(p)
        if path.is_file():
            candidates = [path]
        elif path.is_dir():
            candidates = sorted(
                f for f in path.rglob("*.py")
                if not (set(f.parts) & EXCLUDED_DIRS))
        else:
            raise FileNotFoundError(f"lint path does not exist: {p}")
        for f in candidates:
            r = f.resolve()
            if r not in seen:
                seen.add(r)
                out.append(f)
    return out


def find_root(paths: list[str]) -> Path:
    """Repo root for cross-file lookups: the nearest ancestor of a scan
    path that contains ``src/repro``; the cwd otherwise."""
    for p in paths:
        d = Path(p).resolve()
        if d.is_file():
            d = d.parent
        for anc in (d, *d.parents):
            if (anc / "src" / "repro").is_dir():
                return anc
    return Path.cwd()


def all_checks() -> list[Check]:
    from repro.analysis.checks import ALL_CHECKS
    return [cls() for cls in ALL_CHECKS]


def run_lint(paths: list[str], *, rules: list[str] | None = None,
             baseline: set[str] | None = None) -> LintResult:
    """Run every (selected) check over ``paths``; returns unsuppressed,
    un-baselined findings sorted by location."""
    checks = all_checks()
    if rules:
        known = {c.rule for c in checks}
        unknown = [r for r in rules if r not in known]
        if unknown:
            raise ValueError(
                f"unknown rule(s) {unknown}; known: {sorted(known)}")
        checks = [c for c in checks if c.rule in rules]
    files = [FileCtx(p, str(p)) for p in _collect_files(paths)]
    project = Project(find_root(paths), files)
    by_rel = {f.rel: f for f in files}
    result = LintResult(files=len(files))

    findings: list[Finding] = []
    for f in files:
        if f.tree is None:
            findings.append(Finding(
                rule="syntax", file=f.rel, line=f.syntax_error.lineno or 1,
                message=f"syntax error: {f.syntax_error.msg}"))
    for check in checks:
        findings.extend(check.run(project))

    for finding in sorted(findings,
                          key=lambda f: (f.file, f.line, f.rule)):
        ctx = by_rel.get(finding.file)
        if ctx is not None and ctx.suppressed(finding.rule, finding.line):
            result.suppressed += 1
            continue
        if baseline and finding.key in baseline:
            result.baselined += 1
            continue
        result.findings.append(finding)
    return result


def load_baseline(path: str) -> set[str]:
    data = json.loads(Path(path).read_text())
    return set(data.get("findings", []))


def load_ratchet(path: str) -> int:
    data = json.loads(Path(path).read_text())
    return int(data.get("suppressions", 0))


def write_ratchet(path: str, result: LintResult) -> None:
    Path(path).write_text(json.dumps(
        {"version": 1, "suppressions": result.suppressed}, indent=1) + "\n")


def write_baseline(path: str, result: LintResult) -> None:
    Path(path).write_text(json.dumps(
        {"version": 1,
         "findings": sorted(f.key for f in result.findings)}, indent=1))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro lint",
        description="project-invariant static analysis for the serving "
                    "stack (AST-based, no imports of the checked code)")
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files or directories to scan (default: src tests)")
    ap.add_argument("--strict", action="store_true",
                    help="fail on warnings too, not just errors")
    ap.add_argument("--rule", action="append", default=None, metavar="ID",
                    help="run only this rule (repeatable)")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="ignore findings recorded in this baseline file")
    ap.add_argument("--write-baseline", default=None, metavar="FILE",
                    help="record the current findings as the baseline")
    ap.add_argument("--ratchet", default=None, metavar="FILE",
                    help="fail when lint-ok suppressions exceed the count "
                         "committed in FILE (the suppression ratchet)")
    ap.add_argument("--write-ratchet", default=None, metavar="FILE",
                    help="record the current suppression count as the "
                         "ratchet baseline")
    ap.add_argument("--list-rules", action="store_true",
                    help="list rule ids and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for c in all_checks():
            print(f"{c.rule:18s} {c.description}")
        return 0

    paths = args.paths or ["src", "tests"]
    baseline = load_baseline(args.baseline) if args.baseline else None
    try:
        result = run_lint(paths, rules=args.rule, baseline=baseline)
    except (FileNotFoundError, ValueError) as e:
        print(f"repro lint: {e}", file=sys.stderr)
        return 2
    if args.write_baseline:
        write_baseline(args.write_baseline, result)
        print(f"wrote {len(result.findings)} finding(s) to "
              f"{args.write_baseline}")
        return 0
    if args.write_ratchet:
        write_ratchet(args.write_ratchet, result)
        print(f"wrote suppression count {result.suppressed} to "
              f"{args.write_ratchet}")
        return 0
    for f in result.findings:
        print(f.render())
    extras = []
    if result.suppressed:
        extras.append(f"{result.suppressed} suppressed")
    if result.baselined:
        extras.append(f"{result.baselined} baselined")
    tail = f" ({', '.join(extras)})" if extras else ""
    print(f"repro lint: {result.errors} error(s), "
          f"{result.warnings} warning(s) across {result.files} "
          f"file(s){tail}")
    failing = result.errors + (result.warnings if args.strict else 0)
    if args.ratchet:
        try:
            allowed = load_ratchet(args.ratchet)
        except (OSError, json.JSONDecodeError, ValueError) as e:
            print(f"repro lint: cannot read ratchet {args.ratchet}: {e}",
                  file=sys.stderr)
            return 2
        if result.suppressed > allowed:
            print(f"repro lint: suppression ratchet FAILED -- "
                  f"{result.suppressed} lint-ok marker(s), baseline "
                  f"allows {allowed}; fix the finding or commit an "
                  f"updated ratchet (--write-ratchet {args.ratchet})")
            failing += 1
        elif result.suppressed < allowed:
            print(f"repro lint: suppressions dropped to "
                  f"{result.suppressed} (baseline {allowed}) -- tighten "
                  f"the ratchet with --write-ratchet {args.ratchet}")
    return 1 if failing else 0
