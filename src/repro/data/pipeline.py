"""Deterministic, restartable data pipeline.

Paper §4.1 runs data I/O through *host mounts* into the container; here the
"mount" is an array store on the host filesystem read into the container's
overlay. Two sources:

* ``SyntheticLM`` -- deterministic Zipf-ish token streams keyed by
  (seed, step, shard): restart-exact (resuming at step k regenerates the
  identical batch k), which is what makes checkpoint/restart bitwise
  reproducible without persisting a dataloader state blob.
* ``MemmapLM``   -- token shards memory-mapped from a host directory
  (one .npy per host, the "one big file per node" shape the paper's Fig. 4
  argues for -- many tiny files is exactly the import problem).

Batches are next-token-prediction: tokens[t] predicts labels[t] =
stream[t+1].
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_len: int = 0
    d_model: int = 0          # for frontend embedding stubs


class SyntheticLM:
    """Zipf-distributed token stream; fully deterministic in (seed, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        tok_len = cfg.seq_len - cfg.frontend_len
        key = int.from_bytes(
            hashlib.sha256(f"{cfg.seed}:{step}".encode()).digest()[:8], "little"
        )
        rng = np.random.default_rng(key)
        # zipf-ish: sample ranks, clip to vocab
        z = rng.zipf(1.2, size=(cfg.global_batch, tok_len + 1))
        stream = np.minimum(z - 1, cfg.vocab_size - 1).astype(np.int32)
        out = {
            "tokens": stream[:, :-1],
            "labels": stream[:, 1:],
        }
        if cfg.frontend_len:
            out["frontend_embeds"] = rng.standard_normal(
                (cfg.global_batch, cfg.frontend_len, cfg.d_model)
            ).astype(np.float32) * 0.02
        return out


class MemmapLM:
    """Token shards mmapped from ``root/shard-*.npy`` (host-mount analog)."""

    def __init__(self, cfg: DataConfig, root: str | Path):
        self.cfg = cfg
        self.shards = sorted(Path(root).glob("shard-*.npy"))
        if not self.shards:
            raise FileNotFoundError(f"no shard-*.npy under {root}")
        self._data = np.concatenate([np.load(p, mmap_mode="r")[:]
                                     for p in self.shards])

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        need = cfg.global_batch * (cfg.seq_len + 1)
        n = self._data.shape[0]
        start = (step * need) % max(1, n - need)
        flat = np.asarray(self._data[start:start + need], dtype=np.int32)
        flat = flat.reshape(cfg.global_batch, cfg.seq_len + 1)
        return {"tokens": flat[:, :-1], "labels": flat[:, 1:]}

    @staticmethod
    def write_shards(root: str | Path, tokens: np.ndarray, n_shards: int = 4):
        root = Path(root)
        root.mkdir(parents=True, exist_ok=True)
        for i, part in enumerate(np.array_split(tokens.astype(np.int32), n_shards)):
            np.save(root / f"shard-{i:05d}.npy", part)


def batches(source, start_step: int = 0) -> Iterator[dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield source.batch(step)
        step += 1
