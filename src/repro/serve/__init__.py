from repro.serve.serve_step import ServeStepBuilder, greedy_sample

__all__ = ["ServeStepBuilder", "greedy_sample"]
