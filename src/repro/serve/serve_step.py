"""Serving steps: batched prefill, single-token decode, and the
slot-granular variants that power the orchestrator's continuous batching.

``prefill``      : (params, tokens[, frontend_embeds]) -> (last_logits, cache)
``decode``       : (params, cache, tokens (B,1), idx)  -> (logits, new_cache)
``prefill_slot`` : (params, tokens (B,P), length[, frontend_embeds, fe_len])
                                                -> (first_tokens (B,), cache)
``decode_slots`` : (params, cache, tokens (B,1), pos (B,))
                                                -> (next_tokens (B,), cache)

Frontend-embedding archs (musicgen / internvl2) prepend a per-request
modality prefix: ``prefill_slot`` built with ``frontend_len=F`` takes an
(B, F, d_model) embedding buffer plus the per-row count of real prefix rows
and packs [prefix, prompt] contiguously, so the KV cache covers
prefix+prompt and decode proceeds at absolute positions fe_len+len+t with
no further frontend involvement.

The slot variants treat the batch dimension as a bank of independent
*KV-cache slots*: each row is one in-flight request at its own depth
(``pos`` per row), so requests of different lengths decode in lockstep and
a finished slot can be refilled without touching its neighbours.

The ``*_paged`` variants replace the contiguous per-slot slabs with a
global page pool + per-slot page table (kernels/paged_attention): same
token-for-token semantics, but slots share KV memory at page granularity
so admission is bounded by pool pressure, not per-slot ``max_len`` slabs.

Sampling masks physically-padded vocab columns (models pad the vocab to a
lane/TP multiple -- see models/layers.padded_vocab) so padded ids can never
be emitted.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.dist.sharding import ShardingRules
from repro.models.transformer import Model


# dispatch classes for compile accounting (Container.compile_serve_step
# buckets cache hits/misses per class; SlotEngine.status surfaces them):
# prefill executables are per-bucket and dominate compile count, decode
# executables are per-geometry and dominate steady-state dispatch
PREFILL_STEPS = frozenset({"prefill", "prefill_slot", "prefill_slot_paged"})
DECODE_STEPS = frozenset({"decode", "decode_slots", "decode_chunk",
                          "decode_slots_paged", "decode_chunk_paged"})


def dispatch_class(kind: str) -> str:
    """\"prefill\" | \"decode\" | \"other\" for a serve-step kind."""
    if kind in PREFILL_STEPS:
        return "prefill"
    if kind in DECODE_STEPS:
        return "decode"
    return "other"


def greedy_sample(logits: jax.Array, vocab_size: int) -> jax.Array:
    vp = logits.shape[-1]
    if vp != vocab_size:
        col = jnp.arange(vp) >= vocab_size
        logits = jnp.where(col, -jnp.inf, logits)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


@dataclass
class ServeStepBuilder:
    model: Model
    mesh: Mesh
    rules: ShardingRules

    def build_prefill(self, cache_len: int) -> Callable:
        def prefill(params, tokens, frontend_embeds=None):
            logits, cache, _ = self.model.forward(
                params, tokens, frontend_embeds=frontend_embeds,
                collect_cache=True, cache_len=cache_len)
            return logits[:, -1], cache

        return prefill

    def build_decode(self) -> Callable:
        def decode(params, cache, tokens, idx):
            logits, new_cache = self.model.decode_step(params, cache, tokens, idx)
            return logits[:, -1], new_cache

        return decode

    def build_prefill_slot(self, cache_len: int,
                           frontend_len: int = 0) -> Callable:
        """Prefill request rows whose prompts are right-padded to a bucket.

        tokens: (B, P_bucket); length: int32 count of real tokens -- a
        scalar for the orchestrator's one-request-per-prefill path (B=1) or
        a (B,) vector for the static driver's wave prefill.
        Returns (first_token (B,), cache padded to ``cache_len``).

        With ``frontend_len`` > 0 the signature gains
        ``(frontend_embeds (B, F, D), fe_len)``: a modality prefix consumed
        AHEAD of the token prompt (packed contiguously by Model.forward, so
        tokens sit at positions fe_len..fe_len+length-1 and the first token
        is sampled at position fe_len+length-1).

        Right padding is causally safe for full attention: pad-position K/V
        land at positions >= the real content, which the causal mask hides
        until the decode loop overwrites them in place. (Ring-buffer and
        recurrent caches are NOT pad-safe -- callers use exact-length
        buckets there; see orchestrator.scheduler.SlotEngine.)
        """
        vocab = self.model.cfg.vocab_size

        def _sample_at(logits, last_pos):
            last = jnp.take_along_axis(
                logits, last_pos.reshape(-1, 1, 1), axis=1)[:, 0]
            return greedy_sample(last, vocab)

        if frontend_len:
            def prefill_slot(params, tokens, length, frontend_embeds, fe_len):
                logits, cache, _ = self.model.forward(
                    params, tokens, frontend_embeds=frontend_embeds,
                    frontend_len=fe_len, collect_cache=True,
                    cache_len=cache_len)
                return _sample_at(logits,
                                  jnp.asarray(fe_len + length - 1)), cache

            return prefill_slot

        def prefill_slot(params, tokens, length):
            logits, cache, _ = self.model.forward(
                params, tokens, collect_cache=True, cache_len=cache_len)
            return _sample_at(logits, jnp.asarray(length - 1)), cache

        return prefill_slot

    def build_decode_slots(self) -> Callable:
        """One decode tick over a slot bank: every row advances by one token
        at its own position. Free slots decode garbage into their own rows,
        which the next insertion overwrites -- no masking needed in-kernel.
        """
        decode = self.build_decode()
        vocab = self.model.cfg.vocab_size

        def decode_slots(params, cache, tokens, pos):
            logits, new_cache = decode(params, cache, tokens, pos)
            return greedy_sample(logits, vocab), new_cache

        return decode_slots

    def build_decode_chunk(self, n_steps: int) -> Callable:
        """Multi-step slot decode: ``n_steps`` ticks in ONE dispatch.

        Amortizes per-dispatch host overhead (pytree flatten, executable
        call, token sync) over ``n_steps`` decode ticks -- the multi-step
        scheduling trick. Slots that finish mid-chunk keep decoding until
        the chunk boundary; the host discards their surplus tokens (bounded
        waste of ``n_steps - 1`` positions, accounted by the scheduler).

        (params, cache, tokens (B,1), pos (B,)) ->
            (toks (B, n_steps), next_tokens (B,1), pos+n_steps, cache)
        """
        decode = self.build_decode()
        vocab = self.model.cfg.vocab_size

        def decode_chunk(params, cache, tokens, pos):
            def body(carry, _):
                cache, tok, pos = carry
                logits, cache = decode(params, cache, tok, pos)
                nxt = greedy_sample(logits, vocab)[:, None]
                return (cache, nxt, pos + 1), nxt[:, 0]

            (cache, tok, pos), toks = jax.lax.scan(
                body, (cache, tokens, pos), None, length=n_steps)
            return jnp.moveaxis(toks, 0, 1), tok, pos, cache

        return decode_chunk

    # -- paged variants (KV in a global page pool; see kernels/paged_attention
    # and orchestrator/page_pool.py) ----------------------------------------

    def build_prefill_slot_paged(self, prompt_len: int, page_size: int,
                                 frontend_len: int = 0,
                                 prefix_len: int = 0) -> Callable:
        """prefill_slot whose cache comes back PAGE-MAJOR, ready to scatter
        into the pool: each attention entry is (count, n_kv, n_prompt_pages,
        page_size, hd) with n_prompt_pages = ceil((frontend_len +
        prompt_len) / page_size) -- the frontend prefix occupies the leading
        cache positions, exactly as in the contiguous layout. The host
        writes row j of that tree into physical page ``table[slot, j]`` (one
        jitted scatter -- see scheduler). Padding rows beyond the true
        content carry right-pad garbage; the paged mask hides everything
        past the written positions until decode overwrites it.

        With ``prefix_len`` > 0 (prefix-registry hit) this becomes the
        SUFFIX prefill: ``tokens`` are only the uncached tail of the prompt
        (bucketed to ``prompt_len``), the signature gains the live page
        pool plus the (ceil(prefix_len / page_size),) physical page ids of
        the matched prefix chain, and query positions are offset past the
        prefix. ``prefix_len`` may end MID-page (a radix partial match):
        the boundary page -- the last ``prefix_pages`` entry -- is then a
        read-only MERGE OPERAND: its first ``prefix_len % page_size``
        positions are copied ahead of the suffix KV so the returned
        page-major cache starts page-aligned, and the host scatters it into
        the slot's private rows starting AFTER the fully-shared rows (the
        boundary page itself stays shared property of the registry)."""
        if prefix_len:
            if frontend_len:
                raise NotImplementedError(
                    "prefix-cached suffix prefill does not compose with "
                    "frontend embeddings")
            span = prompt_len                  # the suffix bucket
            vocab = self.model.cfg.vocab_size
            frac = prefix_len % page_size      # front-partial merge width
            np_ = -(-(frac + span) // page_size)
            pad = np_ * page_size - (frac + span)

            def prefill_suffix_paged(params, pool, tokens, length,
                                     prefix_pages):
                logits, cache, _ = self.model.forward(
                    params, tokens, collect_cache=True, cache_len=span,
                    prefix_kv=pool, prefix_pages=prefix_pages,
                    prefix_len=prefix_len)
                last = jnp.take_along_axis(
                    logits, jnp.asarray(length - 1).reshape(-1, 1, 1),
                    axis=1)[:, 0]
                first = greedy_sample(last, vocab)

                def to_pages(e, pl):
                    # e: (count, 1, S, n_kv, hd) suffix cache;
                    # pl: (count, n_kv, n_pages, ps, hd) live pool leaf
                    e = e[:, 0]
                    if frac:
                        # front-partial merge: the shared boundary page's
                        # first ``frac`` positions lead the slot's first
                        # private page (KV there depends only on identical
                        # preceding tokens, so the copy is sound)
                        bp = jnp.take(pl, prefix_pages[-1], axis=2)
                        bp = bp[:, :, :frac].transpose(0, 2, 1, 3)
                        e = jnp.concatenate([bp.astype(e.dtype), e], axis=1)
                    if pad:
                        e = jnp.pad(e, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    cnt, _, n_kv, hd = e.shape
                    e = e.reshape(cnt, np_, page_size, n_kv, hd)
                    return e.transpose(0, 3, 1, 2, 4)

                return first, jax.tree.map(to_pages, cache, pool)

            return prefill_suffix_paged

        span = prompt_len + frontend_len
        inner = self.build_prefill_slot(span, frontend_len)
        np_ = -(-span // page_size)
        pad = np_ * page_size - span

        def prefill_slot_paged(params, tokens, length, *fe_args):
            first, cache = inner(params, tokens, length, *fe_args)

            def to_pages(e):
                # (count, 1, S, n_kv, hd) -> (count, n_kv, np_, ps, hd)
                e = e[:, 0]
                if pad:
                    e = jnp.pad(e, ((0, 0), (0, pad), (0, 0), (0, 0)))
                cnt, _, n_kv, hd = e.shape
                e = e.reshape(cnt, np_, page_size, n_kv, hd)
                return e.transpose(0, 3, 1, 2, 4)

            return first, jax.tree.map(to_pages, cache)

        return prefill_slot_paged

    def build_decode_slots_paged(self) -> Callable:
        """One decode tick over the slot bank with paged KV: identical
        semantics to decode_slots plus the (B, max_pages) page table."""
        vocab = self.model.cfg.vocab_size

        def decode_slots_paged(params, cache, tokens, pos, page_table):
            logits, new_cache = self.model.decode_step(
                params, cache, tokens, pos, page_table=page_table)
            return greedy_sample(logits[:, -1], vocab), new_cache

        return decode_slots_paged

    def build_decode_chunk_paged(self, n_steps: int) -> Callable:
        """Multi-step paged slot decode. The page table is FIXED for the
        whole chunk: the scheduler pre-allocates pages covering every write
        position pos..pos+n_steps-1 before dispatch (alloc-on-write happens
        host-side, bounded one chunk ahead)."""
        vocab = self.model.cfg.vocab_size

        def decode_chunk_paged(params, cache, tokens, pos, page_table):
            def body(carry, _):
                cache, tok, pos = carry
                logits, cache = self.model.decode_step(
                    params, cache, tok, pos, page_table=page_table)
                nxt = greedy_sample(logits[:, -1], vocab)[:, None]
                return (cache, nxt, pos + 1), nxt[:, 0]

            (cache, tok, pos), toks = jax.lax.scan(
                body, (cache, tokens, pos), None, length=n_steps)
            return jnp.moveaxis(toks, 0, 1), tok, pos, cache

        return decode_chunk_paged

    def build_generate_loop(self, n_steps: int) -> Callable:
        """Greedy autoregressive loop (used by examples + integration tests)."""
        decode = self.build_decode()
        vocab = self.model.cfg.vocab_size

        def generate(params, cache, first_token, start_idx):
            def body(carry, _):
                cache, tok, idx = carry
                logits, cache = decode(params, cache, tok, idx)
                nxt = greedy_sample(logits, vocab)[:, None]
                return (cache, nxt, idx + 1), nxt[:, 0]

            (cache, _, _), toks = jax.lax.scan(
                body, (cache, first_token, start_idx), None, length=n_steps)
            return jnp.moveaxis(toks, 0, 1), cache   # (B, n_steps)

        return generate
