"""Serving steps: batched prefill and single-token decode.

``prefill``: (params, tokens[, frontend_embeds]) -> (last_logits, cache)
``decode`` : (params, cache, tokens (B,1), idx)  -> (logits, new_cache)

Sampling masks physically-padded vocab columns (models pad the vocab to a
lane/TP multiple -- see models/layers.padded_vocab) so padded ids can never
be emitted.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.dist.sharding import ShardingRules
from repro.models.transformer import Model


def greedy_sample(logits: jax.Array, vocab_size: int) -> jax.Array:
    vp = logits.shape[-1]
    if vp != vocab_size:
        col = jnp.arange(vp) >= vocab_size
        logits = jnp.where(col, -jnp.inf, logits)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


@dataclass
class ServeStepBuilder:
    model: Model
    mesh: Mesh
    rules: ShardingRules

    def build_prefill(self, cache_len: int) -> Callable:
        def prefill(params, tokens, frontend_embeds=None):
            logits, cache, _ = self.model.forward(
                params, tokens, frontend_embeds=frontend_embeds,
                collect_cache=True, cache_len=cache_len)
            return logits[:, -1], cache

        return prefill

    def build_decode(self) -> Callable:
        def decode(params, cache, tokens, idx):
            logits, new_cache = self.model.decode_step(params, cache, tokens, idx)
            return logits[:, -1], new_cache

        return decode

    def build_generate_loop(self, n_steps: int) -> Callable:
        """Greedy autoregressive loop (used by examples + integration tests)."""
        decode = self.build_decode()
        vocab = self.model.cfg.vocab_size

        def generate(params, cache, first_token, start_idx):
            def body(carry, _):
                cache, tok, idx = carry
                logits, cache = decode(params, cache, tok, idx)
                nxt = greedy_sample(logits, vocab)[:, None]
                return (cache, nxt, idx + 1), nxt[:, 0]

            (cache, _, _), toks = jax.lax.scan(
                body, (cache, first_token, start_idx), None, length=n_steps)
            return jnp.moveaxis(toks, 0, 1), cache   # (B, n_steps)

        return generate
