"""Request admission: priority-lane FIFO queue with arrival times.

``GenRequest`` is one generation job (prompt + decode budget). The queue
keeps one FIFO lane per priority class (``interactive`` ahead of
``batch``): admission is strictly in submission order WITHIN a class, and
an arrived interactive head always goes before an arrived batch head --
the QoS split that keeps latency-sensitive traffic from queueing behind
bulk work under overload. Requests that have not *arrived* yet
(``arrival`` is a tick stamp, letting benchmarks replay staggered traffic
deterministically) block only their own lane. The scheduler bounds
admissions per tick (``fairness_cap``) so a burst of new prompts cannot
stall in-flight decode indefinitely -- the classic continuous-batching
prefill/decode interleave.
"""

from __future__ import annotations

import hashlib
from collections import deque
from dataclasses import dataclass, field

import numpy as np

# admission preference order: interactive lanes drain first
PRIORITIES = ("interactive", "batch")


@dataclass
class GenRequest:
    rid: int
    prompt: np.ndarray                  # (P,) int32 token ids
    max_new_tokens: int
    eos_id: int | None = None
    arrival: int = 0                    # earliest admission tick
    # modality prefix for frontend-embedding archs (musicgen/internvl2):
    # (fe_len, d_model) float embeddings consumed AHEAD of the token prompt.
    # None = unconditional generation (valid on frontend archs too); any
    # non-None prefix is rejected by text-only engines at admission.
    frontend: np.ndarray | None = None
    # declared SHARED leading token block (e.g. a fleet-wide system prompt):
    # prompt[:prefix_len] is eligible for copy-on-write prefix-page sharing
    # on paged engines, keyed by prefix_digest (md5 over the block, computed
    # here; placement can hash on it -- see PodRouter's prefix-hash policy).
    # 0 = nothing shareable. Clamped to prompt_len.
    prefix_len: int = 0
    prefix_digest: str | None = None    # derived; do not set manually
    # QoS class: "interactive" requests are admitted ahead of "batch"
    # requests, are never shed by the router's overload policy, and may
    # preempt a running batch request under pool pressure. "batch" is the
    # sheddable/preemptible bulk tier.
    priority: str = "interactive"
    # admission SLO: if set, the request must be ADMITTED (first token
    # sampled) within this many ticks of max(arrival, submit) or it is
    # shed at the admission site instead of serving a uselessly-late
    # response. None = no deadline.
    deadline_ticks: int | None = None

    # -- runtime state (owned by the scheduler/engine) ----------------------
    # queued | running | preempted | done | rejected | shed
    state: str = "queued"
    tokens: list[int] = field(default_factory=list)  # generated ids
    submit_tick: int = -1
    admit_tick: int = -1                # FIRST admission (TTFT anchor);
    done_tick: int = -1                 # resumes never move it
    replica: str | None = None
    slot: int | None = None
    finish_reason: str | None = None    # eos | length | oversized | shed
    #                                   # | deadline
    error: str | None = None            # human-readable rejection reason
    # page-level preemption record (owned by the scheduler): times this
    # request was paused mid-decode to release its pages to a
    # higher-priority admission, later resumed via suffix re-prefill
    preemptions: int = 0
    # router-tier placement record (owned by PodRouter): which pod the
    # request was routed to, and whether that was a spillover re-route
    # (the policy's preferred pod could never fit it, another pod could)
    pod: str | None = None
    spilled: bool = False
    # fabric-tier failover record (owned by FabricRouter): times this
    # request was re-routed off a dead pod to a survivor (resumed via
    # suffix re-prefill when tokens were already committed)
    reroutes: int = 0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.size == 0:
            raise ValueError(f"request {self.rid}: prompt must be 1-D, non-empty")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens must be >= 1")
        if self.frontend is not None:
            self.frontend = np.asarray(self.frontend, np.float32)
            if self.frontend.ndim != 2 or self.frontend.shape[0] == 0:
                raise ValueError(
                    f"request {self.rid}: frontend must be a non-empty "
                    "(fe_len, d_model) array")
        if self.priority not in PRIORITIES:
            raise ValueError(
                f"request {self.rid}: priority must be one of {PRIORITIES}, "
                f"got {self.priority!r}")
        if self.deadline_ticks is not None and self.deadline_ticks < 0:
            raise ValueError(
                f"request {self.rid}: deadline_ticks must be >= 0")
        self.prefix_len = max(0, min(int(self.prefix_len), self.prompt_len))
        # the digest is the cache/placement KEY only; correctness never
        # rests on it (the pool compares the full block on lookup)
        self.prefix_digest = (hashlib.md5(
            self.prompt[:self.prefix_len].tobytes()).hexdigest()
            if self.prefix_len else None)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def frontend_len(self) -> int:
        return 0 if self.frontend is None else int(self.frontend.shape[0])


class RequestQueue:
    """Priority-lane admission queue: one FIFO deque per priority class.

    ``pop_ready`` preserves submission order WITHIN a class and prefers an
    arrived interactive head over an arrived batch head (strict priority,
    the overload behavior the SLO benchmark pins). Not-yet-arrived
    requests block only their own lane until their arrival tick (the
    queue is a trace replayer, not a reorderer). Preempted requests
    re-enter at the FRONT of their lane (``requeue``): they were admitted
    before everything still queued in that class, so resuming them first
    keeps per-class FIFO fairness."""

    def __init__(self):
        self._lanes: dict[str, deque[GenRequest]] = {
            p: deque() for p in PRIORITIES}
        self.submitted = 0
        self.admitted = 0

    def submit(self, req: GenRequest, tick: int = 0) -> None:
        if req.state != "queued":
            raise ValueError(f"request {req.rid} already {req.state}")
        req.submit_tick = tick
        self._lanes[req.priority].append(req)
        self.submitted += 1

    def requeue(self, req: GenRequest) -> None:
        """Re-enqueue a PREEMPTED request at the front of its lane for
        resume. Not a submission: submit stamps/counters are untouched."""
        if req.state != "preempted":
            raise ValueError(
                f"request {req.rid}: only preempted requests requeue "
                f"(state {req.state})")
        self._lanes[req.priority].appendleft(req)

    def __len__(self) -> int:
        return sum(len(q) for q in self._lanes.values())

    @property
    def pending(self) -> int:
        return len(self)

    def pending_by_class(self) -> dict[str, int]:
        return {p: len(q) for p, q in self._lanes.items()}

    def _ready_lane(self, tick: int) -> deque[GenRequest] | None:
        for p in PRIORITIES:
            q = self._lanes[p]
            if q and q[0].arrival <= tick:
                return q
        return None

    def has_ready(self, tick: int) -> bool:
        return self._ready_lane(tick) is not None

    def peek_ready(self, tick: int) -> GenRequest | None:
        """Admission head (highest-priority arrived lane front) WITHOUT
        popping -- lets the scheduler hold the head under pool
        backpressure instead of reordering around it."""
        q = self._ready_lane(tick)
        return q[0] if q is not None else None

    def pop_ready(self, tick: int) -> GenRequest | None:
        """Next request in lane-priority FIFO order, or None if no lane
        head has arrived. The scheduler pops to admit AND to reject/shed,
        so ``admitted`` is counted at the admission site, not here."""
        q = self._ready_lane(tick)
        return q.popleft() if q is not None else None
