"""Request admission: FIFO queue with arrival times and a fairness cap.

``GenRequest`` is one generation job (prompt + decode budget). The queue
admits strictly in submission order (FIFO) among requests that have
*arrived* (``arrival`` is a tick stamp, letting benchmarks replay staggered
traffic deterministically). The scheduler bounds admissions per tick
(``fairness_cap``) so a burst of new prompts cannot stall in-flight decode
indefinitely -- the classic continuous-batching prefill/decode interleave.
"""

from __future__ import annotations

import hashlib
from collections import deque
from dataclasses import dataclass, field

import numpy as np


@dataclass
class GenRequest:
    rid: int
    prompt: np.ndarray                  # (P,) int32 token ids
    max_new_tokens: int
    eos_id: int | None = None
    arrival: int = 0                    # earliest admission tick
    # modality prefix for frontend-embedding archs (musicgen/internvl2):
    # (fe_len, d_model) float embeddings consumed AHEAD of the token prompt.
    # None = unconditional generation (valid on frontend archs too); any
    # non-None prefix is rejected by text-only engines at admission.
    frontend: np.ndarray | None = None
    # declared SHARED leading token block (e.g. a fleet-wide system prompt):
    # prompt[:prefix_len] is eligible for copy-on-write prefix-page sharing
    # on paged engines, keyed by prefix_digest (md5 over the block, computed
    # here; placement can hash on it -- see PodRouter's prefix-hash policy).
    # 0 = nothing shareable. Clamped to prompt_len.
    prefix_len: int = 0
    prefix_digest: str | None = None    # derived; do not set manually

    # -- runtime state (owned by the scheduler/engine) ----------------------
    state: str = "queued"               # queued | running | done
    tokens: list[int] = field(default_factory=list)  # generated ids
    submit_tick: int = -1
    admit_tick: int = -1
    done_tick: int = -1
    replica: str | None = None
    slot: int | None = None
    finish_reason: str | None = None    # eos | length | oversized
    error: str | None = None            # human-readable rejection reason
    # router-tier placement record (owned by PodRouter): which pod the
    # request was routed to, and whether that was a spillover re-route
    # (the policy's preferred pod could never fit it, another pod could)
    pod: str | None = None
    spilled: bool = False

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.size == 0:
            raise ValueError(f"request {self.rid}: prompt must be 1-D, non-empty")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens must be >= 1")
        if self.frontend is not None:
            self.frontend = np.asarray(self.frontend, np.float32)
            if self.frontend.ndim != 2 or self.frontend.shape[0] == 0:
                raise ValueError(
                    f"request {self.rid}: frontend must be a non-empty "
                    "(fe_len, d_model) array")
        self.prefix_len = max(0, min(int(self.prefix_len), self.prompt_len))
        # the digest is the cache/placement KEY only; correctness never
        # rests on it (the pool compares the full block on lookup)
        self.prefix_digest = (hashlib.md5(
            self.prompt[:self.prefix_len].tobytes()).hexdigest()
            if self.prefix_len else None)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def frontend_len(self) -> int:
        return 0 if self.frontend is None else int(self.frontend.shape[0])


class RequestQueue:
    """FIFO admission queue. ``pop_ready`` preserves submission order among
    arrived requests; not-yet-arrived requests block those behind them only
    until their arrival tick (the queue is a trace replayer, not a
    reorderer)."""

    def __init__(self):
        self._q: deque[GenRequest] = deque()
        self.submitted = 0
        self.admitted = 0

    def submit(self, req: GenRequest, tick: int = 0) -> None:
        if req.state != "queued":
            raise ValueError(f"request {req.rid} already {req.state}")
        req.submit_tick = tick
        self._q.append(req)
        self.submitted += 1

    def __len__(self) -> int:
        return len(self._q)

    @property
    def pending(self) -> int:
        return len(self._q)

    def has_ready(self, tick: int) -> bool:
        return bool(self._q) and self._q[0].arrival <= tick

    def peek_ready(self, tick: int) -> GenRequest | None:
        """FIFO head if it has arrived, WITHOUT popping -- lets the
        scheduler hold the head under pool backpressure instead of
        reordering around it."""
        return self._q[0] if self.has_ready(tick) else None

    def pop_ready(self, tick: int) -> GenRequest | None:
        """Next request in FIFO order, or None if the head has not arrived.
        The scheduler pops both to admit AND to reject, so ``admitted`` is
        counted at the admission site, not here."""
        if not self.has_ready(tick):
            return None
        return self._q.popleft()
