"""Request lifecycle tracing: typed span events in a bounded ring buffer,
with a Chrome trace-event exporter.

Every ``GenRequest`` accrues point events as it moves through the stack::

    submit -> [route] -> queue -> admit|reject|shed -> prefill
           -> decode_chunk* -> [preempt -> resume -> ...]* -> complete

``shed`` is the QoS overload path (router threshold shedding or a missed
admission deadline); ``preempt``/``resume`` bracket a page-level
preemption (pages released mid-decode, suffix re-prefill later).

recorded into the owning pod's ``TraceBuffer`` (the router keeps its own
buffer for placement events and fleet-level rejections). Timestamps are
scheduler *ticks* -- the deterministic clock the whole orchestrator runs
on -- so the same trace replayed twice produces the byte-identical span
log, and aggregate metrics recomputed from it bitwise-match the live
registry (see ``obs.report.recompute_registry``).

``export_chrome`` pairs the point events into Chrome trace-event JSON
(``ph: "X"`` complete events on a per-request timeline), so a serve run
recorded with ``serve --trace out.json`` opens directly in Perfetto /
``chrome://tracing``: one process row per pod, one thread row per
request, with queue/prefill/decode spans carrying pod/replica/slot/
page-count/prefix-hit attributes in ``args``.
"""

from __future__ import annotations

import json
from collections import deque
from dataclasses import dataclass
from pathlib import Path

SPAN_KINDS = ("submit", "route", "queue", "admit", "reject", "shed",
              "prefill", "decode_chunk", "preempt", "resume", "complete",
              "spill", "restore")

# The lifecycle state machine as data: kind -> legal predecessors within
# one (buffer, rid) span log. ``None`` means the kind may start a log:
# ``route`` lands in the chosen pod's buffer before ``submit``, and the
# router's own buffer opens fleet-level ``reject``/``shed`` logs with no
# preceding submit. ``repro lint`` derives its span-lifecycle rule from
# this table (keep it a pure literal) and ``validate_span_log`` replays
# recorded buffers against it.
SPAN_TRANSITIONS = {
    "submit": (None, "route"),
    "route": (None,),
    "queue": ("submit",),
    "admit": ("submit", "queue"),
    "reject": (None, "submit", "queue", "preempt"),
    "shed": (None, "submit", "queue", "preempt"),
    "prefill": ("admit", "resume", "spill", "restore"),
    "decode_chunk": ("prefill", "decode_chunk", "spill"),
    "preempt": ("prefill", "decode_chunk"),
    "resume": ("preempt",),
    "complete": ("prefill", "decode_chunk"),
    # spill-tier movements of the prefix registry, attributed to the
    # request whose allocation/share triggered them: spills fire under any
    # pool pressure (admission prefill or decode alloc-on-write -- the
    # latter lands after the request's own prefill/decode spans), restores
    # only while mapping a matched chain (between admit/resume and the
    # suffix prefill)
    "spill": ("admit", "resume", "prefill", "decode_chunk", "spill",
              "restore"),
    "restore": ("admit", "resume", "spill", "restore"),
}

# kinds with no successors: once recorded, the (buffer, rid) log is closed
TERMINAL_SPANS = ("reject", "shed", "complete")

# one tick rendered as 1000 "microseconds" so sub-tick spans (prefill) stay
# visible at default Perfetto zoom
TICK_US = 1000


@dataclass(frozen=True)
class SpanEvent:
    """One typed point event in a request's lifecycle. ``attrs`` is a
    sorted (key, value) tuple -- hashable and deterministically ordered,
    so span logs compare byte-for-byte across runs."""
    rid: int
    name: str
    tick: int
    attrs: tuple = ()

    def attr(self, key: str, default=None):
        for k, v in self.attrs:
            if k == key:
                return v
        return default


class TraceBuffer:
    """Bounded ring buffer of span events (one per pod, one per router).

    Fixed capacity: a long-lived serving fleet records forever and the
    oldest spans fall off; ``dropped`` counts them so exporters and the
    recompute check know whether the log is complete."""

    def __init__(self, capacity: int = 1 << 16, name: str = "trace"):
        if capacity < 1:
            raise ValueError("TraceBuffer needs capacity >= 1")
        self.capacity = int(capacity)
        self.name = name
        self._events: deque[SpanEvent] = deque(maxlen=self.capacity)
        self.recorded = 0

    @property
    def dropped(self) -> int:
        return self.recorded - len(self._events)

    def record(self, rid: int, name: str, tick: int, **attrs) -> None:
        if name not in SPAN_KINDS:
            raise ValueError(f"unknown span kind {name!r}; one of {SPAN_KINDS}")
        self._events.append(SpanEvent(
            rid=int(rid), name=name, tick=int(tick),
            attrs=tuple(sorted(attrs.items()))))
        self.recorded += 1

    def events(self) -> list[SpanEvent]:
        return list(self._events)

    def by_request(self) -> dict[int, list[SpanEvent]]:
        """Events grouped per rid, in record order (which is tick order:
        the scheduler records monotonically)."""
        out: dict[int, list[SpanEvent]] = {}
        for e in self._events:
            out.setdefault(e.rid, []).append(e)
        return out

    def clear(self) -> None:
        self._events.clear()
        self.recorded = 0

    def status(self) -> dict:
        return {"capacity": self.capacity, "buffered": len(self._events),
                "recorded": self.recorded, "dropped": self.dropped}


def validate_span_log(buffers) -> dict:
    """Replay recorded span buffers against ``SPAN_TRANSITIONS``: within
    each ``(buffer, rid)`` log every event's predecessor must be legal,
    nothing may follow a terminal span, and ticks must be monotone.
    Buffers that have dropped events (ring overflow) skip the
    start-of-log check -- the true first span may have fallen off.
    Raises ``ValueError`` at the first violation; returns summary stats.
    """
    n_buffers = 0
    requests = 0
    events = 0
    for buf in buffers:
        n_buffers += 1
        truncated = buf.dropped > 0
        for rid, evs in sorted(buf.by_request().items()):
            requests += 1
            prev = None
            for e in evs:
                events += 1
                allowed = SPAN_TRANSITIONS.get(e.name)
                if allowed is None:
                    raise ValueError(
                        f"{buf.name}/rid {rid}: unknown span kind "
                        f"{e.name!r}")
                if prev is None:
                    if None not in allowed and not truncated:
                        raise ValueError(
                            f"{buf.name}/rid {rid}: log starts with "
                            f"{e.name!r}, which requires a predecessor "
                            f"in {allowed}")
                else:
                    if prev.name in TERMINAL_SPANS:
                        raise ValueError(
                            f"{buf.name}/rid {rid}: {e.name!r} recorded "
                            f"after terminal span {prev.name!r}")
                    if prev.name not in allowed:
                        raise ValueError(
                            f"{buf.name}/rid {rid}: illegal transition "
                            f"{prev.name!r} -> {e.name!r} (legal "
                            f"predecessors: {allowed})")
                    if e.tick < prev.tick:
                        raise ValueError(
                            f"{buf.name}/rid {rid}: tick goes backwards "
                            f"at {e.name!r} ({prev.tick} -> {e.tick})")
                prev = e
    return {"buffers": n_buffers, "requests": requests,
            "events": events}


def _x(name, ts, dur, pid, tid, rid, **args):
    return {"name": name, "ph": "X", "ts": ts * TICK_US,
            "dur": max(0, dur) * TICK_US, "pid": pid, "tid": tid,
            "args": {"rid": rid, **args}}


def _i(name, ts, pid, tid, rid, **args):
    return {"name": name, "ph": "i", "s": "t", "ts": ts * TICK_US,
            "pid": pid, "tid": tid, "args": {"rid": rid, **args}}


def export_chrome(buffers, path: str | Path | None = None) -> dict:
    """Render span buffers as a Chrome trace-event JSON object (and write
    it to ``path`` when given). One pid per buffer (pod / router), one tid
    per request; point events are paired into ``X`` complete spans:

    * ``queue``   : submit (or arrival, whichever is later) -> admit/reject
    * ``prefill`` : the admission (or resume) tick (1 tick wide), with
      positions/pages/prefix-hit attrs
    * ``decode``  : one span per decode chunk, ``chunk`` ticks wide
    * ``paused``  : preempt -> resume (pages released, request queued)
    * ``generate``: admit -> complete envelope (tokens attr)
    * ``route`` / ``reject`` / ``shed`` / ``preempt`` / ``resume`` /
      ``complete`` / ``spill`` / ``restore``: instants (the last two are
      the prefix registry's tier movements, digest attr)
    """
    events = []
    for pid, buf in enumerate(buffers):
        events.append({"name": "process_name", "ph": "M", "ts": 0, "pid": pid,
                       "args": {"name": getattr(buf, "name", f"pod{pid}")}})
        for rid, evs in sorted(buf.by_request().items()):
            tid = rid
            submit = admit = None
            baseline = None
            preempt = None
            for e in evs:
                if e.name == "submit":
                    submit = e
                    baseline = max(e.tick, int(e.attr("arrival", e.tick)))
                elif e.name == "route":
                    events.append(_i("route", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "admit":
                    admit = e
                    if baseline is not None:
                        events.append(_x("queue", baseline,
                                         e.tick - baseline, pid, tid, rid))
                elif e.name == "preempt":
                    preempt = e
                    events.append(_i("preempt", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "resume":
                    if preempt is not None:
                        events.append(_x("paused", preempt.tick,
                                         e.tick - preempt.tick, pid, tid,
                                         rid))
                        preempt = None
                    events.append(_i("resume", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "shed":
                    if baseline is not None:
                        events.append(_x("queue", baseline,
                                         e.tick - baseline, pid, tid, rid))
                    events.append(_i("shed", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "prefill":
                    events.append(_x("prefill", e.tick, 1, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "decode_chunk":
                    events.append(_x("decode", e.tick,
                                     int(e.attr("chunk", 1)), pid, tid, rid,
                                     slot=e.attr("slot")))
                elif e.name == "spill":
                    events.append(_i("spill", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "restore":
                    events.append(_i("restore", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "reject":
                    if baseline is not None:
                        events.append(_x("queue", baseline,
                                         e.tick - baseline, pid, tid, rid))
                    events.append(_i("reject", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "complete":
                    if admit is not None:
                        events.append(_x("generate", admit.tick,
                                         e.tick - admit.tick, pid, tid, rid,
                                         tokens=e.attr("tokens")))
                    events.append(_i("complete", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
    # deterministic, per-request-monotone order: spans are paired out of
    # record order (the generate envelope starts at admit but is only
    # known at complete), so sort non-metadata events by (pid, rid, ts)
    meta = [e for e in events if e["ph"] == "M"]
    rest = sorted((e for e in events if e["ph"] != "M"),
                  key=lambda e: (e["pid"], e["args"]["rid"], e["ts"]))
    trace = {"traceEvents": meta + rest, "displayTimeUnit": "ms",
             "otherData": {"clock": "scheduler ticks",
                           "tick_us": TICK_US}}
    if path is not None:
        Path(path).write_text(json.dumps(trace, indent=1))
    return trace


def validate_chrome_trace(trace: dict | str | Path) -> dict:
    """Minimal schema check for an exported trace (the CI gate): a
    non-empty ``traceEvents`` list, every event carrying ``ph``/``ts``/
    ``pid``/``name``, complete (``ph:"X"``) events carrying a present and
    non-negative ``dur``, and timestamps monotone per request (grouped by
    ``(pid, args.rid)``). Raises ``ValueError`` with the first violation;
    returns summary stats on success."""
    if not isinstance(trace, dict):
        trace = json.loads(Path(trace).read_text())
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise ValueError("trace has no traceEvents")
    last_ts: dict[tuple, float] = {}
    requests = set()
    for i, e in enumerate(events):
        for key in ("name", "ph", "ts", "pid"):
            if key not in e:
                raise ValueError(f"event {i} ({e}) is missing {key!r}")
        if e["ph"] == "M":
            continue
        if e["ph"] == "X":
            # a complete event without ANY dur is malformed, not 0-length:
            # defaulting it used to let dur-less spans slide through CI
            if "dur" not in e:
                raise ValueError(f"event {i} ({e['name']}) is a complete "
                                 "event with no 'dur'")
            if e["dur"] < 0:
                raise ValueError(f"event {i} has negative duration")
        rid = (e.get("args") or {}).get("rid")
        if rid is None:
            raise ValueError(f"event {i} carries no args.rid")
        key = (e["pid"], rid)
        requests.add(key)
        if e["ts"] < last_ts.get(key, 0):
            raise ValueError(
                f"event {i} ({e['name']}) goes backwards for request {key}: "
                f"ts {e['ts']} < {last_ts[key]}")
        last_ts[key] = e["ts"]
    return {"events": len(events), "requests": len(requests)}
