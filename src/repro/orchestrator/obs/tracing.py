"""Request lifecycle tracing: typed span events in a bounded ring buffer,
with a Chrome trace-event exporter.

Every ``GenRequest`` accrues point events as it moves through the stack::

    submit -> [route] -> queue -> admit|reject|shed -> prefill
           -> decode_chunk* -> [preempt -> resume -> ...]* -> complete

``shed`` is the QoS overload path (router threshold shedding or a missed
admission deadline); ``preempt``/``resume`` bracket a page-level
preemption (pages released mid-decode, suffix re-prefill later).

recorded into the owning pod's ``TraceBuffer`` (the router keeps its own
buffer for placement events and fleet-level rejections). Timestamps are
scheduler *ticks* -- the deterministic clock the whole orchestrator runs
on -- so the same trace replayed twice produces the byte-identical span
log, and aggregate metrics recomputed from it bitwise-match the live
registry (see ``obs.report.recompute_registry``).

``export_chrome`` pairs the point events into Chrome trace-event JSON
(``ph: "X"`` complete events on a per-request timeline), so a serve run
recorded with ``serve --trace out.json`` opens directly in Perfetto /
``chrome://tracing``: one process row per pod, one thread row per
request, with queue/prefill/decode spans carrying pod/replica/slot/
page-count/prefix-hit attributes in ``args``.
"""

from __future__ import annotations

import json
import os
from collections import deque
from dataclasses import dataclass
from pathlib import Path

SPAN_KINDS = ("submit", "route", "queue", "admit", "reject", "shed",
              "prefill", "decode_chunk", "preempt", "resume", "complete",
              "spill", "restore", "heartbeat", "evict", "reroute")

# The lifecycle state machine as data: kind -> legal predecessors within
# one (buffer, rid) span log. ``None`` means the kind may start a log:
# ``route`` lands in the chosen pod's buffer before ``submit``, and the
# router's own buffer opens fleet-level ``reject``/``shed`` logs with no
# preceding submit. ``repro lint`` derives its span-lifecycle rule from
# this table (keep it a pure literal) and ``validate_span_log`` replays
# recorded buffers against it.
SPAN_TRANSITIONS = {
    "submit": (None, "route"),
    "route": (None,),
    "queue": ("submit",),
    "admit": ("submit", "queue"),
    # route/reroute predecessors: the ROUTER's buffer rejects a request
    # after recording its placement when no (surviving) member can ever
    # fit it -- the fleet-level infeasible path
    "reject": (None, "submit", "queue", "preempt", "route", "reroute"),
    "shed": (None, "submit", "queue", "preempt"),
    "prefill": ("admit", "resume", "spill", "restore"),
    "decode_chunk": ("prefill", "decode_chunk", "spill"),
    "preempt": ("prefill", "decode_chunk"),
    # a resume may START a log: a request rerouted off a dead pod arrives
    # at the survivor already preempted (the pod death was its implicit
    # preemption) and its resume is the first span in the survivor's buffer
    "resume": (None, "preempt"),
    "complete": ("prefill", "decode_chunk"),
    # spill-tier movements of the prefix registry, attributed to the
    # request whose allocation/share triggered them: spills fire under any
    # pool pressure (admission prefill or decode alloc-on-write -- the
    # latter lands after the request's own prefill/decode spans), restores
    # only while mapping a matched chain (between admit/resume and the
    # suffix prefill)
    "spill": ("admit", "resume", "prefill", "decode_chunk", "spill",
              "restore"),
    "restore": ("admit", "resume", "spill", "restore"),
    # fabric-tier spans, recorded in the ROUTER's buffer only. Heartbeats
    # accrue per member under a synthetic per-pod rid (-1 - ordinal);
    # evict closes that member's log. Reroute is recorded under the
    # REQUEST's rid after its route span -- a request rerouted twice
    # (cascading pod deaths) chains reroute -> reroute.
    "heartbeat": (None, "heartbeat"),
    "evict": (None, "heartbeat"),
    "reroute": ("route", "reroute"),
}

# kinds with no successors: once recorded, the (buffer, rid) log is closed
# (evict closes a fabric member's synthetic heartbeat log; replacement pods
# get a fresh ordinal, so an evicted member's rid never records again)
TERMINAL_SPANS = ("reject", "shed", "complete", "evict")

# one tick rendered as 1000 "microseconds" so sub-tick spans (prefill) stay
# visible at default Perfetto zoom
TICK_US = 1000


@dataclass(frozen=True)
class SpanEvent:
    """One typed point event in a request's lifecycle. ``attrs`` is a
    sorted (key, value) tuple -- hashable and deterministically ordered,
    so span logs compare byte-for-byte across runs.

    ``wall`` is an OPTIONAL wall-clock timestamp (``time.time()``) carried
    ALONGSIDE the tick for fabric runs where pods are real processes with
    real clocks. It is deliberately outside ``attrs`` and excluded from
    the determinism story: in-process runs record ``None`` everywhere so
    span logs still compare byte-for-byte, and the recompute/validate
    paths never read it."""
    rid: int
    name: str
    tick: int
    attrs: tuple = ()
    wall: float | None = None

    def attr(self, key: str, default=None):
        for k, v in self.attrs:
            if k == key:
                return v
        return default


class TraceBuffer:
    """Bounded ring buffer of span events (one per pod, one per router).

    Fixed capacity: a long-lived serving fleet records forever and the
    oldest spans fall off; ``dropped`` counts them so exporters and the
    recompute check know whether the log is complete."""

    def __init__(self, capacity: int = 1 << 16, name: str = "trace"):
        if capacity < 1:
            raise ValueError("TraceBuffer needs capacity >= 1")
        self.capacity = int(capacity)
        self.name = name
        self._events: deque[SpanEvent] = deque(maxlen=self.capacity)
        self.recorded = 0

    @property
    def dropped(self) -> int:
        return self.recorded - len(self._events)

    def record(self, rid: int, name: str, tick: int, *,
               wall: float | None = None, **attrs) -> None:
        if name not in SPAN_KINDS:
            raise ValueError(f"unknown span kind {name!r}; one of {SPAN_KINDS}")
        self._events.append(SpanEvent(
            rid=int(rid), name=name, tick=int(tick),
            attrs=tuple(sorted(attrs.items())), wall=wall))
        self.recorded += 1

    def events(self) -> list[SpanEvent]:
        return list(self._events)

    def by_request(self) -> dict[int, list[SpanEvent]]:
        """Events grouped per rid, in record order (which is tick order:
        the scheduler records monotonically)."""
        out: dict[int, list[SpanEvent]] = {}
        for e in self._events:
            out.setdefault(e.rid, []).append(e)
        return out

    def clear(self) -> None:
        self._events.clear()
        self.recorded = 0

    def status(self) -> dict:
        return {"capacity": self.capacity, "buffered": len(self._events),
                "recorded": self.recorded, "dropped": self.dropped}


def validate_span_log(buffers) -> dict:
    """Replay recorded span buffers against ``SPAN_TRANSITIONS``: within
    each ``(buffer, rid)`` log every event's predecessor must be legal,
    nothing may follow a terminal span, and ticks must be monotone.
    Buffers that have dropped events (ring overflow) skip the
    start-of-log check -- the true first span may have fallen off.
    Raises ``ValueError`` at the first violation; returns summary stats.
    """
    n_buffers = 0
    requests = 0
    events = 0
    for buf in buffers:
        n_buffers += 1
        truncated = buf.dropped > 0
        for rid, evs in sorted(buf.by_request().items()):
            requests += 1
            prev = None
            for e in evs:
                events += 1
                allowed = SPAN_TRANSITIONS.get(e.name)
                if allowed is None:
                    raise ValueError(
                        f"{buf.name}/rid {rid}: unknown span kind "
                        f"{e.name!r}")
                if prev is None:
                    if None not in allowed and not truncated:
                        raise ValueError(
                            f"{buf.name}/rid {rid}: log starts with "
                            f"{e.name!r}, which requires a predecessor "
                            f"in {allowed}")
                else:
                    if prev.name in TERMINAL_SPANS:
                        raise ValueError(
                            f"{buf.name}/rid {rid}: {e.name!r} recorded "
                            f"after terminal span {prev.name!r}")
                    if prev.name not in allowed:
                        raise ValueError(
                            f"{buf.name}/rid {rid}: illegal transition "
                            f"{prev.name!r} -> {e.name!r} (legal "
                            f"predecessors: {allowed})")
                    if e.tick < prev.tick:
                        raise ValueError(
                            f"{buf.name}/rid {rid}: tick goes backwards "
                            f"at {e.name!r} ({prev.tick} -> {e.tick})")
                prev = e
    return {"buffers": n_buffers, "requests": requests,
            "events": events}


def dump_span_log(buffer: TraceBuffer, path: str | Path) -> Path:
    """Persist one buffer's span log as JSON -- the per-process span file a
    fabric worker flushes so the router-side closure check (and ``repro
    lint``'s cross-process pooling) can read spans emitted in another
    process. ``recorded`` rides along so ``dropped`` survives the round
    trip and truncated logs keep skipping the start-of-log check."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    doc = {"name": buffer.name, "capacity": buffer.capacity,
           "recorded": buffer.recorded,
           "events": [[e.rid, e.name, e.tick, list(e.attrs), e.wall]
                      for e in buffer.events()]}
    tmp = path.with_suffix(".tmp")
    tmp.write_text(json.dumps(doc))
    os.replace(tmp, path)
    return path


def load_span_log(path: str | Path) -> TraceBuffer:
    """Rehydrate a ``dump_span_log`` file into a TraceBuffer equivalent to
    the one that wrote it (same name/capacity/recorded), so every
    validator and exporter consumes local and cross-process spans through
    one type."""
    doc = json.loads(Path(path).read_text())
    buf = TraceBuffer(capacity=doc["capacity"], name=doc["name"])
    for rid, name, tick, attrs, wall in doc["events"]:
        buf._events.append(SpanEvent(
            rid=int(rid), name=name, tick=int(tick),
            attrs=tuple((k, v) for k, v in attrs), wall=wall))
    buf.recorded = int(doc["recorded"])
    return buf


def validate_fleet_closure(buffers) -> dict:
    """Cross-buffer lifecycle closure: every ROUTED request must reach a
    terminal span SOMEWHERE in the fleet, even though its lifecycle is
    split across buffers (route/reroute in the router's, submit..complete
    in one or more pods' -- more than one when a pod died mid-decode and
    the request resumed on a survivor).

    This is the zero-lost-requests check the fault-injection benchmark
    gates on: a request routed to a pod that was killed and never
    rerouted shows up here as an open lifecycle. Synthetic fabric rids
    (negative: per-member heartbeat/evict logs) are exempt -- they close
    per-buffer via ``evict`` and never represent user work. Buffers that
    dropped events skip the check (the terminal may have fallen off the
    ring). Raises ``ValueError`` naming the first open request; returns
    summary stats."""
    routed: dict[int, int] = {}      # rid -> reroute count
    closed: set[int] = set()
    truncated = False
    for buf in buffers:
        truncated = truncated or buf.dropped > 0
        for e in buf.events():
            if e.rid < 0:
                continue
            if e.name == "route":
                routed.setdefault(e.rid, 0)
            elif e.name == "reroute":
                routed[e.rid] = routed.get(e.rid, 0) + 1
            elif e.name in TERMINAL_SPANS:
                closed.add(e.rid)
    open_rids = sorted(set(routed) - closed)
    if open_rids and not truncated:
        raise ValueError(
            f"fleet span closure: {len(open_rids)} routed request(s) never "
            f"reached a terminal span (first: rid {open_rids[0]}) -- "
            "work was lost")
    return {"routed": len(routed), "closed": len(set(routed) & closed),
            "rerouted": sum(1 for n in routed.values() if n),
            "reroutes": sum(routed.values()), "truncated": truncated}


def _x(name, ts, dur, pid, tid, rid, **args):
    return {"name": name, "ph": "X", "ts": ts * TICK_US,
            "dur": max(0, dur) * TICK_US, "pid": pid, "tid": tid,
            "args": {"rid": rid, **args}}


def _i(name, ts, pid, tid, rid, **args):
    return {"name": name, "ph": "i", "s": "t", "ts": ts * TICK_US,
            "pid": pid, "tid": tid, "args": {"rid": rid, **args}}


def export_chrome(buffers, path: str | Path | None = None) -> dict:
    """Render span buffers as a Chrome trace-event JSON object (and write
    it to ``path`` when given). One pid per buffer (pod / router), one tid
    per request; point events are paired into ``X`` complete spans:

    * ``queue``   : submit (or arrival, whichever is later) -> admit/reject
    * ``prefill`` : the admission (or resume) tick (1 tick wide), with
      positions/pages/prefix-hit attrs
    * ``decode``  : one span per decode chunk, ``chunk`` ticks wide
    * ``paused``  : preempt -> resume (pages released, request queued)
    * ``generate``: admit -> complete envelope (tokens attr)
    * ``route`` / ``reject`` / ``shed`` / ``preempt`` / ``resume`` /
      ``complete`` / ``spill`` / ``restore``: instants (the last two are
      the prefix registry's tier movements, digest attr)
    """
    events = []
    for pid, buf in enumerate(buffers):
        events.append({"name": "process_name", "ph": "M", "ts": 0, "pid": pid,
                       "args": {"name": getattr(buf, "name", f"pod{pid}")}})
        for rid, evs in sorted(buf.by_request().items()):
            tid = rid
            submit = admit = None
            baseline = None
            preempt = None
            for e in evs:
                if e.name == "submit":
                    submit = e
                    baseline = max(e.tick, int(e.attr("arrival", e.tick)))
                elif e.name == "route":
                    events.append(_i("route", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "admit":
                    admit = e
                    if baseline is not None:
                        events.append(_x("queue", baseline,
                                         e.tick - baseline, pid, tid, rid))
                elif e.name == "preempt":
                    preempt = e
                    events.append(_i("preempt", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "resume":
                    if preempt is not None:
                        events.append(_x("paused", preempt.tick,
                                         e.tick - preempt.tick, pid, tid,
                                         rid))
                        preempt = None
                    events.append(_i("resume", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "shed":
                    if baseline is not None:
                        events.append(_x("queue", baseline,
                                         e.tick - baseline, pid, tid, rid))
                    events.append(_i("shed", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "prefill":
                    events.append(_x("prefill", e.tick, 1, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "decode_chunk":
                    events.append(_x("decode", e.tick,
                                     int(e.attr("chunk", 1)), pid, tid, rid,
                                     slot=e.attr("slot")))
                elif e.name == "spill":
                    events.append(_i("spill", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "restore":
                    events.append(_i("restore", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "heartbeat":
                    events.append(_i("heartbeat", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "evict":
                    events.append(_i("evict", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "reroute":
                    events.append(_i("reroute", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "reject":
                    if baseline is not None:
                        events.append(_x("queue", baseline,
                                         e.tick - baseline, pid, tid, rid))
                    events.append(_i("reject", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
                elif e.name == "complete":
                    if admit is not None:
                        events.append(_x("generate", admit.tick,
                                         e.tick - admit.tick, pid, tid, rid,
                                         tokens=e.attr("tokens")))
                    events.append(_i("complete", e.tick, pid, tid, rid,
                                     **dict(e.attrs)))
    # deterministic, per-request-monotone order: spans are paired out of
    # record order (the generate envelope starts at admit but is only
    # known at complete), so sort non-metadata events by (pid, rid, ts)
    meta = [e for e in events if e["ph"] == "M"]
    rest = sorted((e for e in events if e["ph"] != "M"),
                  key=lambda e: (e["pid"], e["args"]["rid"], e["ts"]))
    trace = {"traceEvents": meta + rest, "displayTimeUnit": "ms",
             "otherData": {"clock": "scheduler ticks",
                           "tick_us": TICK_US}}
    if path is not None:
        Path(path).write_text(json.dumps(trace, indent=1))
    return trace


def validate_chrome_trace(trace: dict | str | Path) -> dict:
    """Minimal schema check for an exported trace (the CI gate): a
    non-empty ``traceEvents`` list, every event carrying ``ph``/``ts``/
    ``pid``/``name``, complete (``ph:"X"``) events carrying a present and
    non-negative ``dur``, and timestamps monotone per request (grouped by
    ``(pid, args.rid)``). Raises ``ValueError`` with the first violation;
    returns summary stats on success."""
    if not isinstance(trace, dict):
        trace = json.loads(Path(trace).read_text())
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise ValueError("trace has no traceEvents")
    last_ts: dict[tuple, float] = {}
    requests = set()
    for i, e in enumerate(events):
        for key in ("name", "ph", "ts", "pid"):
            if key not in e:
                raise ValueError(f"event {i} ({e}) is missing {key!r}")
        if e["ph"] == "M":
            continue
        if e["ph"] == "X":
            # a complete event without ANY dur is malformed, not 0-length:
            # defaulting it used to let dur-less spans slide through CI
            if "dur" not in e:
                raise ValueError(f"event {i} ({e['name']}) is a complete "
                                 "event with no 'dur'")
            if e["dur"] < 0:
                raise ValueError(f"event {i} has negative duration")
        rid = (e.get("args") or {}).get("rid")
        if rid is None:
            raise ValueError(f"event {i} carries no args.rid")
        key = (e["pid"], rid)
        requests.add(key)
        if e["ts"] < last_ts.get(key, 0):
            raise ValueError(
                f"event {i} ({e['name']}) goes backwards for request {key}: "
                f"ts {e['ts']} < {last_ts[key]}")
        last_ts[key] = e["ts"]
    return {"events": len(events), "requests": len(requests)}
