"""Shared metrics registry: counters, gauges, fixed-bucket histograms.

One registry per pod replaces the hand-rolled counter attributes that were
scattered across ``SlotEngine``, ``ContinuousScheduler``, ``PagePool`` and
``PodRouter``: every accounting site increments a named, optionally
labelled metric, and ``repro ps`` / ``repro top`` / the fig benchmarks
read one snapshot instead of re-deriving numbers from five ad-hoc places.
The old attribute names survive as read-only property shims so no caller
changed shape.

Everything here is tick-clocked and deterministic: metrics carry no
wall-clock state, so the same request trace produces the bitwise-same
snapshot (the property the span-log recompute check in ``obs.report``
pins). Wall-time accounting (``prefill_s``/``decode_s``) deliberately
stays OUTSIDE the registry, on the engines, for exactly that reason.

``Histogram`` is a fixed-bucket streaming histogram whose ``percentile``
is *nearest-rank by construction*: samples are floored to their bucket's
lower bound, so the reported percentile is ``floor(s / width) * width``
of the true nearest-rank sample ``s`` -- identical to
``telemetry.nearest_rank`` for ``width == 1`` on integer samples (the
tick-valued latency histograms), and within one bucket width otherwise.
"""

from __future__ import annotations

import math


class Counter:
    """Monotone non-negative integer count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += int(n)


class Gauge:
    """Point-in-time value plus its high-water mark."""

    __slots__ = ("value", "high")

    def __init__(self):
        self.value = 0
        self.high = 0

    def set(self, v) -> None:
        self.value = v
        if v > self.high:
            self.high = v


class Histogram:
    """Fixed-bucket streaming histogram over non-negative samples.

    Bucket ``i`` covers ``[i * width, (i + 1) * width)``; samples at or
    past the last bucket clamp into it (so extreme percentiles degrade to
    a lower bound instead of growing memory). ``percentile`` applies the
    repo-wide nearest-rank definition to the bucket counts and returns the
    rank-th sample's bucket lower bound.

    Buckets optionally carry an *exemplar*: a representative request id
    recorded alongside a sample, so a percentile read links back to a
    concrete trace (``repro top`` shows the rid behind the p99).
    Exemplars combine by minimum, which makes them independent of record
    and merge order -- the live registry and the span-log recompute stay
    bitwise-identical.
    """

    __slots__ = ("width", "n_buckets", "counts", "count", "sum",
                 "exemplars")

    def __init__(self, width: int = 1, n_buckets: int = 512):
        if width < 1 or n_buckets < 1:
            raise ValueError("histogram needs width >= 1 and n_buckets >= 1")
        self.width = int(width)
        self.n_buckets = int(n_buckets)
        self.counts = [0] * self.n_buckets
        self.count = 0
        self.sum = 0
        self.exemplars: dict[int, int] = {}

    def record(self, v, *, exemplar: int | None = None) -> None:
        v = int(v)
        if v < 0:
            raise ValueError(f"histogram sample must be >= 0, got {v}")
        idx = min(v // self.width, self.n_buckets - 1)
        self.counts[idx] += 1
        self.count += 1
        self.sum += v
        if exemplar is not None:
            exemplar = int(exemplar)
            cur = self.exemplars.get(idx)
            if cur is None or exemplar < cur:
                self.exemplars[idx] = exemplar

    def percentile(self, pct: float):
        """Nearest-rank percentile at bucket resolution; 0 for no samples
        (callers that must distinguish check ``count`` -- see the
        ``latency_count`` convention in telemetry/ps)."""
        if not 0 <= pct <= 100:
            raise ValueError(f"percentile must be in [0, 100], got {pct}")
        if self.count == 0:
            return 0
        rank = max(1, math.ceil(pct / 100.0 * self.count))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank:
                return i * self.width
        return (self.n_buckets - 1) * self.width     # pragma: no cover

    def merge(self, other: "Histogram") -> None:
        if (other.width, other.n_buckets) != (self.width, self.n_buckets):
            raise ValueError(
                f"cannot merge histograms of geometry "
                f"({other.width}, {other.n_buckets}) into "
                f"({self.width}, {self.n_buckets})")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum += other.sum
        for i, e in other.exemplars.items():
            cur = self.exemplars.get(i)
            if cur is None or e < cur:
                self.exemplars[i] = e

    def exemplar_at(self, pct: float) -> int | None:
        """The exemplar rid of the bucket that ``percentile(pct)`` lands
        in; None when the histogram is empty or the bucket never saw an
        exemplar-carrying sample."""
        if self.count == 0:
            return None
        rank = max(1, math.ceil(pct / 100.0 * self.count))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank:
                return self.exemplars.get(i)
        return None                                  # pragma: no cover

    def snapshot(self) -> dict:
        # sparse counts: state files refresh every few ticks, and a dense
        # 4096-zero vector per histogram per pod would dominate them
        return {
            "width": self.width,
            "n_buckets": self.n_buckets,
            "count": self.count,
            "sum": self.sum,
            "counts": {str(i): c for i, c in enumerate(self.counts) if c},
            "exemplars": {str(i): e
                          for i, e in sorted(self.exemplars.items())},
        }

    @classmethod
    def from_snapshot(cls, snap: dict) -> "Histogram":
        h = cls(width=snap["width"], n_buckets=snap["n_buckets"])
        for i, c in snap["counts"].items():
            h.counts[int(i)] = int(c)
        h.count = int(snap["count"])
        h.sum = int(snap["sum"])
        # absent in pre-exemplar snapshots; default keeps them loadable
        h.exemplars = {int(i): int(e)
                       for i, e in snap.get("exemplars", {}).items()}
        return h


def _label_key(labels: dict) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(labels.items()))


class MetricsRegistry:
    """Named metrics with optional labels, get-or-create semantics.

    ``counter("tokens_generated", replica="pod-x/r0")`` returns the same
    object on every call, so hot paths bind the metric once at init and
    increment a plain attribute. ``snapshot()`` is a deterministic nested
    dict (sorted keys) suitable for the pod state files; registries
    aggregate with :func:`merge_snapshots` (the router's fleet view).
    """

    def __init__(self):
        self._counters: dict[str, dict[str, Counter]] = {}
        self._gauges: dict[str, dict[str, Gauge]] = {}
        self._histograms: dict[str, dict[str, Histogram]] = {}

    # -- get-or-create -------------------------------------------------------
    def counter(self, name: str, **labels) -> Counter:
        return self._counters.setdefault(name, {}).setdefault(
            _label_key(labels), Counter())

    def gauge(self, name: str, **labels) -> Gauge:
        return self._gauges.setdefault(name, {}).setdefault(
            _label_key(labels), Gauge())

    def histogram(self, name: str, *, width: int = 1, n_buckets: int = 512,
                  **labels) -> Histogram:
        h = self._histograms.setdefault(name, {}).setdefault(
            _label_key(labels), Histogram(width=width, n_buckets=n_buckets))
        if (h.width, h.n_buckets) != (width, n_buckets):
            raise ValueError(
                f"histogram {name!r} already registered with geometry "
                f"({h.width}, {h.n_buckets}), requested ({width}, "
                f"{n_buckets})")
        return h

    # -- reads ---------------------------------------------------------------
    def total(self, name: str) -> int:
        """Counter/gauge value summed across labels (0 if unregistered)."""
        series = self._counters.get(name) or self._gauges.get(name) or {}
        return sum(m.value for m in series.values())

    def merged_histogram(self, name: str) -> Histogram | None:
        series = self._histograms.get(name)
        if not series:
            return None
        out = None
        for h in series.values():
            if out is None:
                out = Histogram(width=h.width, n_buckets=h.n_buckets)
            out.merge(h)
        return out

    def percentile(self, name: str, pct: float):
        h = self.merged_histogram(name)
        return h.percentile(pct) if h else 0

    # -- snapshots -----------------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "counters": {
                name: {lk: c.value for lk, c in sorted(series.items())}
                for name, series in sorted(self._counters.items())},
            "gauges": {
                name: {lk: {"value": g.value, "high": g.high}
                       for lk, g in sorted(series.items())}
                for name, series in sorted(self._gauges.items())},
            "histograms": {
                name: {lk: h.snapshot() for lk, h in sorted(series.items())}
                for name, series in sorted(self._histograms.items())},
        }


def merge_snapshots(snaps: list[dict]) -> dict:
    """Aggregate registry snapshots (the router's fleet rollup): counters
    and gauge values sum across sources, gauge highs sum too (per-pod
    peaks are independent, so the fleet high-water is their sum as an
    upper bound), histograms add bucket-wise. Labels are preserved, so a
    per-replica breakdown survives aggregation."""
    out = {"counters": {}, "gauges": {}, "histograms": {}}
    for snap in snaps:
        for name, series in snap.get("counters", {}).items():
            dst = out["counters"].setdefault(name, {})
            for lk, v in series.items():
                dst[lk] = dst.get(lk, 0) + v
        for name, series in snap.get("gauges", {}).items():
            dst = out["gauges"].setdefault(name, {})
            for lk, g in series.items():
                cur = dst.setdefault(lk, {"value": 0, "high": 0})
                cur["value"] += g["value"]
                cur["high"] += g["high"]
        for name, series in snap.get("histograms", {}).items():
            dst = out["histograms"].setdefault(name, {})
            for lk, hs in series.items():
                if lk not in dst:
                    dst[lk] = Histogram.from_snapshot(hs).snapshot()
                else:
                    h = Histogram.from_snapshot(dst[lk])
                    h.merge(Histogram.from_snapshot(hs))
                    dst[lk] = h.snapshot()
    return out


def snapshot_percentile(snap: dict, name: str, pct: float):
    """Nearest-rank percentile over a snapshot's histogram ``name``,
    merged across labels. Returns None when the histogram is absent or
    empty -- renderers print ``-`` instead of a fake 0-tick latency."""
    series = snap.get("histograms", {}).get(name)
    if not series:
        return None
    merged = None
    for hs in series.values():
        h = Histogram.from_snapshot(hs)
        if merged is None:
            merged = Histogram(width=h.width, n_buckets=h.n_buckets)
        merged.merge(h)
    if merged is None or merged.count == 0:
        return None
    return merged.percentile(pct)


def snapshot_exemplar(snap: dict, name: str, pct: float) -> int | None:
    """Representative rid behind ``snapshot_percentile(snap, name, pct)``:
    merges the histogram across labels and returns the exemplar of the
    nearest-rank bucket (None when absent)."""
    series = snap.get("histograms", {}).get(name)
    if not series:
        return None
    merged = None
    for hs in series.values():
        h = Histogram.from_snapshot(hs)
        if merged is None:
            merged = Histogram(width=h.width, n_buckets=h.n_buckets)
        merged.merge(h)
    if merged is None or merged.count == 0:
        return None
    return merged.exemplar_at(pct)


def snapshot_count(snap: dict, name: str) -> int:
    series = snap.get("histograms", {}).get(name) or {}
    return sum(hs.get("count", 0) for hs in series.values())


def snapshot_total(snap: dict, name: str) -> int:
    """Counter (or gauge value) total across labels from a snapshot."""
    series = snap.get("counters", {}).get(name)
    if series is not None:
        return sum(series.values())
    gauges = snap.get("gauges", {}).get(name) or {}
    return sum(g.get("value", 0) for g in gauges.values())
