"""Derived latency decomposition from span logs: TTFT, ITL, and the
registry-recompute check.

Definitions (tick clock, measured from request ARRIVAL -- the trace
stagger is offered load, not queueing delay, same convention as
``telemetry.request_latencies``):

* **TTFT** (time-to-first-token): ticks from ``max(arrival, submit)`` to
  the admission tick. The first token is sampled by the prefill dispatch
  on the admission tick itself, so TTFT == queueing delay + prefill.
* **ITL** (inter-token latency): decode ticks per generated token after
  the first, ``(done - admit) / (n_tokens - 1)``. Stored in the registry
  as integer *milli-ticks* (floor) so the histogram stays exact and
  deterministic; reported in ticks.

``recompute_registry`` rebuilds the pod-level completion metrics purely
from a span log. Because both sides run the same integer formulas on the
same tick stamps, a complete log (``dropped == 0``) recomputes to the
bitwise-identical snapshot the live registry wrote -- the determinism
check the acceptance criteria pin (same trace -> same numbers).
"""

from __future__ import annotations

from repro.orchestrator.obs.metrics import MetricsRegistry
from repro.orchestrator.telemetry import nearest_rank

# one geometry for every tick-valued histogram (latency/ttft) and for the
# milli-tick ITL histogram -- shared by the live scheduler and the
# recompute path so their snapshots are comparable field-for-field
TICK_HIST = dict(width=1, n_buckets=4096)
ITL_HIST = dict(width=50, n_buckets=1024)       # 0.05-tick resolution


def itl_milliticks(admit_tick: int, done_tick: int, n_tokens: int) -> int:
    """Integer milli-ticks per post-first token; 0 for single-token
    requests (no inter-token gap exists)."""
    if n_tokens <= 1:
        return 0
    return ((done_tick - admit_tick) * 1000) // (n_tokens - 1)


def observe_completion(metrics: MetricsRegistry, *, arrival: int,
                       submit_tick: int, admit_tick: int, done_tick: int,
                       n_tokens: int, rid: int | None = None) -> None:
    """Record one completed request into a pod registry. The ONLY writer
    of the completion metrics -- the live scheduler and the span-log
    recompute both call this, so they agree by construction. ``rid``
    tags each latency bucket with a representative request (exemplar), so
    a p99 read links back to a concrete trace; exemplars min-combine, so
    passing rids in any order keeps the bitwise match."""
    base = max(arrival, submit_tick)
    metrics.counter("requests_completed").inc()
    metrics.counter("tokens_out").inc(n_tokens)
    metrics.histogram("latency_ticks", **TICK_HIST).record(
        done_tick - base, exemplar=rid)
    metrics.histogram("ttft_ticks", **TICK_HIST).record(
        admit_tick - base, exemplar=rid)
    metrics.histogram("itl_milliticks", **ITL_HIST).record(
        itl_milliticks(admit_tick, done_tick, n_tokens), exemplar=rid)


def request_lifecycles(buffers) -> dict[int, dict]:
    """Per-request lifecycle digest from span buffers: rid -> {submit,
    arrival, admit, done, tokens, chunks, rejected, shed, preemptions}.
    Buffers are merged (router + pods), so route/reject/shed events
    recorded at the router tier land on the same rid as the pod-side
    spans. ``admit`` is the FIRST admission tick (the TTFT anchor) -- a
    preempted request's resume never moves it."""
    out: dict[int, dict] = {}
    for buf in buffers:
        for e in buf.events():
            rec = out.setdefault(e.rid, {
                "submit": None, "arrival": 0, "admit": None, "done": None,
                "tokens": 0, "chunks": 0, "rejected": False, "shed": False,
                "preemptions": 0, "priority": None})
            if e.name == "submit":
                rec["submit"] = e.tick
                rec["arrival"] = int(e.attr("arrival", 0))
            elif e.name == "admit":
                if rec["admit"] is None:
                    rec["admit"] = e.tick
                if e.attr("priority") is not None:
                    rec["priority"] = e.attr("priority")
            elif e.name == "decode_chunk":
                rec["chunks"] += 1
            elif e.name == "preempt":
                rec["preemptions"] += 1
            elif e.name == "reject":
                rec["rejected"] = True
                rec["done"] = e.tick
            elif e.name == "shed":
                rec["shed"] = True
                rec["done"] = e.tick
            elif e.name == "complete":
                rec["done"] = e.tick
                rec["tokens"] = int(e.attr("tokens", 0))
    return out


def decomposition(buffers, priority: str | None = None) -> dict:
    """TTFT / ITL percentiles across all COMPLETED requests in the span
    buffers, using the repo-wide nearest-rank definition on the exact
    per-request values. ``latency_count`` 0 means "no samples" -- render
    ``-``, not 0 (the empty-input convention telemetry carries).

    Single-token completions have NO inter-token gap, so they are excluded
    from the ITL percentile list (``itl_count`` is the ITL sample count):
    counting their ``itl_milliticks == 0`` dragged reported ITL p50 toward
    0 on prefill-heavy traces. The registry histograms keep recording the
    0 samples -- the live-vs-recompute bitwise match is untouched.

    ``priority`` filters to one QoS class (requests tagged via the admit
    span's ``priority`` attr); None aggregates everything -- how fig10
    separates interactive and batch percentiles from one overload trace.
    """
    ttfts, itls = [], []
    for rec in request_lifecycles(buffers).values():
        if rec["rejected"] or rec["shed"] or rec["admit"] is None \
                or rec["done"] is None:
            continue
        if priority is not None and rec.get("priority") != priority:
            continue
        base = max(rec["arrival"], rec["submit"] if rec["submit"] is not None
                   else rec["admit"])
        ttfts.append(rec["admit"] - base)
        if rec["tokens"] >= 2:
            itls.append(itl_milliticks(rec["admit"], rec["done"],
                                       rec["tokens"]) / 1000.0)
    return {
        "latency_count": len(ttfts),
        "itl_count": len(itls),
        "ttft_p50_ticks": nearest_rank(ttfts, 50),
        "ttft_p99_ticks": nearest_rank(ttfts, 99),
        "itl_p50_ticks": nearest_rank(itls, 50),
        "itl_p99_ticks": nearest_rank(itls, 99),
    }


def recompute_registry(buffers) -> MetricsRegistry:
    """Rebuild the pod-level completion metrics from a span log alone.

    For a complete log (no ring-buffer drops) the returned registry's
    ``requests_completed`` / ``requests_rejected`` / ``tokens_out``
    counters and ``latency_ticks`` / ``ttft_ticks`` / ``itl_milliticks``
    histograms snapshot bitwise-identically to what the live schedulers
    recorded -- the tick clock makes observability replayable."""
    reg = MetricsRegistry()
    reg.counter("requests_rejected")
    reg.counter("requests_completed")
    reg.counter("requests_shed")
    reg.counter("tokens_out")
    reg.histogram("latency_ticks", **TICK_HIST)
    reg.histogram("ttft_ticks", **TICK_HIST)
    reg.histogram("itl_milliticks", **ITL_HIST)
    for rid, rec in sorted(request_lifecycles(buffers).items()):
        if rec["rejected"]:
            reg.counter("requests_rejected").inc()
            continue
        if rec["shed"]:
            reg.counter("requests_shed").inc()
            continue
        if rec["admit"] is None or rec["done"] is None:
            continue                    # still in flight at snapshot time
        observe_completion(
            reg, arrival=rec["arrival"],
            submit_tick=rec["submit"] if rec["submit"] is not None
            else rec["admit"],
            admit_tick=rec["admit"], done_tick=rec["done"],
            n_tokens=rec["tokens"], rid=rid)
    return reg


COMPLETION_METRICS = ("requests_completed", "requests_rejected",
                      "requests_shed", "tokens_out")
COMPLETION_HISTOGRAMS = ("latency_ticks", "ttft_ticks", "itl_milliticks")


def completion_snapshot(snap: dict) -> dict:
    """The comparable slice of a registry snapshot: completion counters +
    latency histograms, labels merged away (the recompute side has no
    replica labels)."""
    return {
        "counters": {name: sum(snap.get("counters", {}).get(name, {})
                               .values())
                     for name in COMPLETION_METRICS},
        "histograms": {name: snap.get("histograms", {}).get(name, {})
                       for name in COMPLETION_HISTOGRAMS},
    }
