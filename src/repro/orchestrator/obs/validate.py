"""Trace validation CLI: ``python -m repro.orchestrator.obs.validate t.json``.

The CI orchestrator job runs a ``serve --trace`` smoke and gates on this
exiting 0 -- the checks are the minimal Chrome trace-event schema
(``validate_chrome_trace``): every event has ``ph``/``ts``/``pid``/
``name``, durations are non-negative, timestamps monotone per request.
"""

from __future__ import annotations

import argparse
import sys

from repro.orchestrator.obs.tracing import validate_chrome_trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.orchestrator.obs.validate",
        description="validate a Chrome trace-event JSON exported by "
                    "`serve --trace`")
    ap.add_argument("trace", help="path to the trace JSON file")
    args = ap.parse_args(argv)
    try:
        stats = validate_chrome_trace(args.trace)
    except (OSError, ValueError) as e:
        print(f"INVALID {args.trace}: {e}", file=sys.stderr)
        return 1
    print(f"OK {args.trace}: {stats['events']} events, "
          f"{stats['requests']} requests")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
