"""Trace validation CLI: ``python -m repro.orchestrator.obs.validate``.

Two modes, both CI gates:

* ``validate t.json`` -- the Chrome trace-event schema check
  (``validate_chrome_trace``): every event has ``ph``/``ts``/``pid``/
  ``name``, durations are non-negative, timestamps monotone per request.
  The orchestrator job runs a ``serve --trace`` smoke and gates on this
  exiting 0.
* ``validate --spans <runtime-root> [--fleet NAME]`` -- the cross-host
  half: rehydrate every per-process span file a fabric fleet wrote under
  ``<root>/spans/``, replay each against the span state machine
  (``validate_span_log``), then prove fleet-wide lifecycle closure
  (``validate_fleet_closure``): every routed rid reached a terminal span
  SOMEWHERE, even when route/reroute and submit..complete live in
  different processes' files.
"""

from __future__ import annotations

import argparse
import sys

from repro.orchestrator.obs.tracing import (validate_chrome_trace,
                                            validate_fleet_closure,
                                            validate_span_log)


def _validate_spans(root: str, fleet: str | None) -> int:
    from repro.orchestrator.fabric import load_fleet_spans
    buffers = load_fleet_spans(root, fleet=fleet)
    scope = f"fleet {fleet!r}" if fleet else "all fleets"
    if not buffers:
        print(f"INVALID {root}: no span files for {scope} under "
              f"{root}/spans/", file=sys.stderr)
        return 1
    try:
        log = validate_span_log(buffers)
        closure = validate_fleet_closure(buffers)
    except ValueError as e:
        print(f"INVALID {root} ({scope}): {e}", file=sys.stderr)
        return 1
    print(f"OK {root} ({scope}): {log['buffers']} span file(s), "
          f"{log['events']} events; closure {closure['routed']} routed "
          f"/ {closure['closed']} closed / {closure['rerouted']} "
          "rerouted")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.orchestrator.obs.validate",
        description="validate a Chrome trace-event JSON exported by "
                    "`serve --trace`, or (--spans) a fabric fleet's "
                    "per-process span files")
    ap.add_argument("target",
                    help="trace JSON file; with --spans, the runtime "
                         "root the fleet served from")
    ap.add_argument("--spans", action="store_true",
                    help="validate per-process span files under "
                         "<target>/spans/ and the fleet-wide lifecycle "
                         "closure instead of a Chrome trace")
    ap.add_argument("--fleet", default=None,
                    help="with --spans: narrow to one fleet's files "
                         "(worker files are <fleet>-<ordinal>, the "
                         "router's <fleet>-router)")
    args = ap.parse_args(argv)
    if args.spans:
        return _validate_spans(args.target, args.fleet)
    try:
        stats = validate_chrome_trace(args.target)
    except (OSError, ValueError) as e:
        print(f"INVALID {args.target}: {e}", file=sys.stderr)
        return 1
    print(f"OK {args.target}: {stats['events']} events, "
          f"{stats['requests']} requests")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
