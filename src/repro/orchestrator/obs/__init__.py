"""Observability layer: tick-clocked tracing + metrics for the serving
fleet.

* :mod:`repro.orchestrator.obs.metrics` -- per-pod :class:`MetricsRegistry`
  (counters / gauges / fixed-bucket histograms) with deterministic
  snapshots and fleet-level aggregation.
* :mod:`repro.orchestrator.obs.tracing` -- per-request lifecycle span
  events in bounded ring buffers, exportable to Chrome trace-event JSON
  (Perfetto-openable via ``serve --trace out.json``).
* :mod:`repro.orchestrator.obs.report` -- TTFT / inter-token-latency
  decomposition derived from spans, plus the span-log -> registry
  recompute used to check bitwise reproducibility.
"""

from repro.orchestrator.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    merge_snapshots,
    snapshot_count,
    snapshot_exemplar,
    snapshot_percentile,
    snapshot_total,
)
from repro.orchestrator.obs.report import (
    ITL_HIST,
    TICK_HIST,
    completion_snapshot,
    decomposition,
    itl_milliticks,
    observe_completion,
    recompute_registry,
    request_lifecycles,
)
from repro.orchestrator.obs.tracing import (
    SPAN_KINDS,
    SPAN_TRANSITIONS,
    TERMINAL_SPANS,
    SpanEvent,
    TraceBuffer,
    dump_span_log,
    export_chrome,
    load_span_log,
    validate_chrome_trace,
    validate_fleet_closure,
    validate_span_log,
)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "merge_snapshots", "snapshot_count", "snapshot_percentile",
    "snapshot_total",
    "TICK_HIST", "ITL_HIST", "completion_snapshot", "decomposition",
    "itl_milliticks", "observe_completion", "recompute_registry",
    "request_lifecycles", "snapshot_exemplar",
    "SPAN_KINDS", "SPAN_TRANSITIONS", "TERMINAL_SPANS", "SpanEvent",
    "TraceBuffer", "dump_span_log", "export_chrome", "load_span_log",
    "validate_chrome_trace", "validate_fleet_closure", "validate_span_log",
]
