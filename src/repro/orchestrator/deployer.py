"""Rolling blue/green upgrades: re-resolve a tag, drain, swap.

The serving analog of the paper's mutable-tag workflow (§3.4: ``stable`` /
``2016.1.0r1`` pointers over immutable digests): a fleet runs whatever
digest its tag resolved to at bring-up; releasing means re-pointing the tag
and rolling the fleet. Per replica, the deployer

  1. builds the GREEN engine from the newly-resolved image first -- its
     compile goes through the shared CompileCache, so identical lowered
     steps (same shapes/mesh) warm-start and the replica is ready to serve
     the moment it is swapped in (the import-problem fix applied to
     rollover);
  2. marks the BLUE engine draining: no new admissions, in-flight requests
     decode to completion while the rest of the pod keeps serving;
  3. swaps GREEN into the pod and retires BLUE.

Capacity never drops below N-1 replicas and in-flight requests are never
killed -- the invariants the orchestrator tests pin down.
"""

from __future__ import annotations

from repro.orchestrator.pod import Pod
from repro.orchestrator.scheduler import ContinuousScheduler


class RollingDeployer:
    def __init__(self, pod: Pod, scheduler: ContinuousScheduler):
        self.pod = pod
        self.scheduler = scheduler

    def upgrade(self, ref: str | None = None) -> dict:
        """Roll the pod onto whatever ``ref`` (default: the pod's own tag)
        resolves to now. No-op if the digest is unchanged."""
        ref = ref or self.pod.ref
        if ref is None:
            raise ValueError("pod was built from a raw image; pass a ref")
        new_digest = self.pod.runtime.registry.resolve(ref)
        old_digest = self.pod.image.digest
        report = {"ref": ref, "from": old_digest[:12], "to": new_digest[:12],
                  "changed": new_digest != old_digest, "replicas": []}
        if not report["changed"]:
            return report

        new_image = self.pod.runtime.pull(ref)
        for i in range(len(self.pod.engines)):
            blue = self.pod.engines[i]
            green = self.pod.make_engine(new_image, i)   # compile before drain
            in_flight = len(blue.active)
            drain_ticks = self.scheduler.drain(blue)
            blue.release()          # free the blue generation's device state
            self.pod.engines[i] = green
            self.pod.retired.append(blue)
            report["replicas"].append({
                "replica": i,
                "in_flight_at_drain": in_flight,
                "drain_ticks": drain_ticks,
                "container_old": blue.container.container_id,
                "container_new": green.container.container_id,
            })
        self.pod.image = new_image
        self.pod.ref = ref
        self.pod.drop_params(old_digest)   # last blue gone; free its params
        self.pod.write_state()
        return report
