"""Rolling blue/green upgrades: re-resolve a tag, drain, swap.

The serving analog of the paper's mutable-tag workflow (§3.4: ``stable`` /
``2016.1.0r1`` pointers over immutable digests): a fleet runs whatever
digest its tag resolved to at bring-up; releasing means re-pointing the tag
and rolling the fleet. Per replica, the deployer

  1. builds the GREEN engine from the newly-resolved image first -- its
     compile goes through the shared CompileCache, so identical lowered
     steps (same shapes/mesh) warm-start and the replica is ready to serve
     the moment it is swapped in (the import-problem fix applied to
     rollover);
  2. marks the BLUE engine draining: no new admissions, in-flight requests
     decode to completion while the rest of the pod keeps serving;
  3. swaps GREEN into the pod and retires BLUE.

Capacity never drops below N-1 replicas and in-flight requests are never
killed -- the invariants the orchestrator tests pin down.

The same deployer scales to a **fleet**: construct it with a ``PodRouter``
instead of a (pod, scheduler) pair and ``upgrade()`` rolls pod-by-pod.
The rolling pod is drained *at the router* (new traffic routes around it;
its queued + in-flight work finishes on its own scheduler), every drain
tick goes through ``router.step`` so the non-rolling pods keep admitting
and decoding throughout, and fleet capacity never drops below N-1 pods
(the report records the observed floor).
"""

from __future__ import annotations

from repro.orchestrator.pod import Pod
from repro.orchestrator.router import PodRouter
from repro.orchestrator.scheduler import ContinuousScheduler


class RollingDeployer:
    def __init__(self, target: Pod | PodRouter,
                 scheduler: ContinuousScheduler | None = None):
        if isinstance(target, PodRouter):
            self.router: PodRouter | None = target
            self.pod, self.scheduler = None, None
        else:
            if scheduler is None:
                raise ValueError("pod-scoped deploys need the pod's scheduler")
            self.router = None
            self.pod, self.scheduler = target, scheduler

    def upgrade(self, ref: str | None = None) -> dict:
        """Roll onto whatever ``ref`` (default: the pod's/fleet's own tag)
        resolves to now. No-op if the digest is unchanged."""
        if self.router is not None:
            return self._upgrade_fleet(ref)
        return self._upgrade_pod(self.pod, self.scheduler, ref)

    # -- one pod (the original scope) ---------------------------------------
    def _upgrade_pod(self, pod: Pod, scheduler: ContinuousScheduler,
                     ref: str | None, tick_fn=None) -> dict:
        ref = ref or pod.ref
        if ref is None:
            raise ValueError("pod was built from a raw image; pass a ref")
        new_digest = pod.runtime.registry.resolve(ref)
        old_digest = pod.image.digest
        report = {"ref": ref, "from": old_digest[:12], "to": new_digest[:12],
                  "changed": new_digest != old_digest, "replicas": []}
        if not report["changed"]:
            return report

        new_image = pod.runtime.pull(ref)
        for i in range(len(pod.engines)):
            blue = pod.engines[i]
            green = pod.make_engine(new_image, i)   # compile before drain
            in_flight = len(blue.active)
            drain_ticks = scheduler.drain(blue, tick_fn=tick_fn)
            blue.release()          # free the blue generation's device state
            pod.engines[i] = green
            pod.retired.append(blue)
            report["replicas"].append({
                "replica": i,
                "in_flight_at_drain": in_flight,
                "drain_ticks": drain_ticks,
                "container_old": blue.container.container_id,
                "container_new": green.container.container_id,
            })
        pod.image = new_image
        pod.ref = ref
        pod.drop_params(old_digest)   # last blue gone; free its params
        pod.write_state()
        return report

    # -- the whole fleet ----------------------------------------------------
    def _upgrade_fleet(self, ref: str | None) -> dict:
        router = self.router
        refs = {p.ref for p in router.pods}
        ref = ref or (refs.pop() if len(refs) == 1 and None not in refs
                      else None)
        if ref is None:
            raise ValueError(
                "fleet pods carry no common tag; pass a ref explicitly")

        report = {"ref": ref, "router": router.router_id, "pods": [],
                  "changed": False,
                  # observed fleet-capacity floor across every drain tick:
                  # the N-1 invariant, measured rather than asserted
                  "capacity_floor": None}

        def note_capacity():
            report["capacity_floor"] = (
                router.capacity if report["capacity_floor"] is None
                else min(report["capacity_floor"], router.capacity))

        def tick():
            note_capacity()
            router.step()

        for pod in router.pods:
            router.drain_pod(pod)       # new traffic routes around this pod
            note_capacity()     # even an instant drain records the floor
            try:
                rec = self._upgrade_pod(pod, router.scheduler_for(pod), ref,
                                        tick_fn=tick)
            finally:
                router.undrain_pod(pod)
            report["pods"].append(rec)
            report["changed"] = report["changed"] or rec["changed"]
        router.write_state()
        return report
