"""Pod orchestrator: continuous-batching serving fleets over Containers.

The layer the paper's trajectory points at (Hale et al. run one container
well; Benedicic et al. run fleets of them): a ``Pod`` of N Container
replicas of one image, a FIFO ``RequestQueue``, a ``ContinuousScheduler``
doing iteration-level (Orca-style) batching over per-request KV-cache
slots, and a ``RollingDeployer`` that re-resolves a registry tag and
blue/green-rolls the fleet with drains -- warm-started through the shared
CompileCache.

``Pod(..., paged=True)`` swaps the contiguous per-slot KV slabs for a
global page pool (``PagePool`` + the Pallas paged-attention kernel):
admission is then bounded by pool pressure instead of per-slot ``max_len``
slabs, so short requests stop stranding memory and long ones stop being
rejected by the slab ceiling. ``prefix_cache=True`` adds copy-on-write
prefix page sharing on top: requests declaring the same leading token
block (``GenRequest.prefix_len``) share its refcounted KV pages through a
digest-keyed index and prefill only their suffix -- the paper's shared
immutable image layers, applied to the KV cache.

``PodRouter`` scales past one pod: N pods (each with its own scheduler and
queue) behind one submit()/step()/run() surface, with shortest-queue,
consistent-hash or prefix-hash (prefix-cache affinity) placement,
spillover-before-reject, and router-level drains -- ``RollingDeployer``
accepts a router and rolls the fleet pod-by-pod at >= N-1 pods of
capacity.

``FabricRouter`` (``repro.orchestrator.fabric``) takes the router
cross-host: pods become workers behind a framed message transport
(in-process loopback or one OS process per pod), with heartbeat liveness,
dead-pod eviction + exactly-once re-routing of in-flight work, and an
elastic spawn/drain/retire fleet.
"""

from repro.orchestrator.deployer import RollingDeployer
from repro.orchestrator.fabric import (FABRIC_POLICIES, FabricRouter,
                                       PodWorker, decode_request,
                                       encode_request, load_fleet_spans,
                                       loopback_spawner, proc_spawner)
from repro.orchestrator.page_pool import PagePool
from repro.orchestrator.pod import Pod
from repro.orchestrator.request_queue import (PRIORITIES, GenRequest,
                                              RequestQueue)
from repro.orchestrator.router import PLACEMENT_POLICIES, PodRouter
from repro.orchestrator.scheduler import ContinuousScheduler, SlotEngine
from repro.orchestrator.telemetry import latency_summary, nearest_rank

__all__ = [
    "GenRequest",
    "PRIORITIES",
    "RequestQueue",
    "PagePool",
    "Pod",
    "PodRouter",
    "PLACEMENT_POLICIES",
    "SlotEngine",
    "ContinuousScheduler",
    "RollingDeployer",
    "FABRIC_POLICIES",
    "FabricRouter",
    "PodWorker",
    "encode_request",
    "decode_request",
    "load_fleet_spans",
    "loopback_spawner",
    "proc_spawner",
    "latency_summary",
    "nearest_rank",
]
