"""Pod orchestrator: continuous-batching serving fleets over Containers.

The layer the paper's trajectory points at (Hale et al. run one container
well; Benedicic et al. run fleets of them): a ``Pod`` of N Container
replicas of one image, a FIFO ``RequestQueue``, a ``ContinuousScheduler``
doing iteration-level (Orca-style) batching over per-request KV-cache
slots, and a ``RollingDeployer`` that re-resolves a registry tag and
blue/green-rolls the fleet with drains -- warm-started through the shared
CompileCache.

``Pod(..., paged=True)`` swaps the contiguous per-slot KV slabs for a
global page pool (``PagePool`` + the Pallas paged-attention kernel):
admission is then bounded by pool pressure instead of per-slot ``max_len``
slabs, so short requests stop stranding memory and long ones stop being
rejected by the slab ceiling.
"""

from repro.orchestrator.deployer import RollingDeployer
from repro.orchestrator.page_pool import PagePool
from repro.orchestrator.pod import Pod
from repro.orchestrator.request_queue import GenRequest, RequestQueue
from repro.orchestrator.scheduler import ContinuousScheduler, SlotEngine

__all__ = [
    "GenRequest",
    "RequestQueue",
    "PagePool",
    "Pod",
    "SlotEngine",
    "ContinuousScheduler",
    "RollingDeployer",
]
