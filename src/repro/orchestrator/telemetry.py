"""Serving telemetry helpers shared by the drivers and benchmarks.

One percentile definition for the whole repo: *nearest-rank* (the smallest
sample such that at least ``pct`` percent of the data is <= it). The
serving driver used to index ``sorted(lat)[int(0.99 * n)]``, which is the
MAX for every n <= 100 (floor(0.99 n) = n-1) and biases the even-n median
a rank high -- fig6/fig8 inherited the same expression. serve.py, fig6 and
fig8 all call :func:`nearest_rank` now, so their p50/p99 columns are
comparable by construction.
"""

from __future__ import annotations

import math
from typing import Iterable, Sequence


def nearest_rank(values: Sequence[float] | Iterable[float],
                 pct: float) -> float:
    """Nearest-rank percentile: the ceil(pct/100 * n)-th smallest sample.

    pct outside [0, 100] raises (checked before anything else, so a bad
    caller fails even on an empty run); the rank is floored at 1, so p0
    asks for the first rank, not the -1st. Empty input returns 0 (a
    serving run with no completions has no latency, not an exception).
    """
    if not 0 <= pct <= 100:
        raise ValueError(f"percentile must be in [0, 100], got {pct}")
    vs = sorted(values)
    if not vs:
        return 0
    rank = max(1, math.ceil(pct / 100.0 * len(vs)))
    return vs[rank - 1]


def request_latencies(done: Iterable) -> list[int]:
    """Per-request serving latency in ticks, measured from when the request
    ARRIVED (trace stagger is offered load, not queueing delay), not from
    the bulk submit at tick 0."""
    return [r.done_tick - max(r.arrival, r.submit_tick) for r in done]


def latency_summary(done: Iterable) -> dict:
    """p50/p99 plus the sample count. ``nearest_rank`` returns 0 for empty
    input, indistinguishable from a true 0-tick latency -- renderers check
    ``latency_count`` and print ``-`` when it is 0."""
    lat = request_latencies(done)
    return {
        "latency_count": len(lat),
        "p50_latency_ticks": nearest_rank(lat, 50),
        "p99_latency_ticks": nearest_rank(lat, 99),
    }
