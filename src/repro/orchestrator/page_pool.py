"""PagePool: host-side block allocator for the paged KV cache.

The device holds one global pool per layer, ``(n_kv, n_pages, page_size,
hd)``; this class owns the free-list and the per-slot page table that maps
logical positions to physical pages. All bookkeeping is host numpy -- the
only device traffic it generates is the (n_slots, max_pages) int32 table
shipped with each decode dispatch.

Allocation protocol (reservation-based, preempt-free):

  * ``reserve(slot, n)`` at ADMISSION sets aside the request's worst-case
    page count (ceil((prompt + budget + chunk) / page_size)). Admission is
    gated on ``can_reserve`` -- the pool never over-commits, so a running
    request can never fail to get a page mid-decode and nothing is ever
    preempted. Backpressure = the scheduler simply stops admitting.
  * ``alloc_upto(slot, hi)`` is the lazy ALLOC-ON-WRITE: physical pages are
    pulled from the free-list only when decode is about to write position
    ``hi`` (prefill bulk-allocates the prompt's pages the same way). A
    request that exits early (EOS) therefore returns its never-written
    reserved pages without them ever leaving the free-list.
  * ``release(slot)`` at COMPLETION returns owned pages and the remaining
    reservation in one step and resets the table row.
  * ``pause(slot)`` is page-level PREEMPTION: the same full reclaim as
    ``release`` (private pages freed, reservation returned, shared pages
    decref'd) but the slot is marked *paused* -- ``check()`` pins that a
    paused slot holds nothing until a later ``reserve`` (the resume's
    suffix re-prefill) clears the flag. Preemption is the one deliberate
    exception to the preempt-free promise above: the SCHEDULER invokes it
    only against a lower-priority victim, so interactive admissions can
    reclaim pages without the pool ever over-committing.

Prefix sharing (the container-layer analogy: immutable image layers shared
by many containers):

  * a slot's leading, fully-written prompt pages can be PROMOTED into a
    digest-keyed prefix index (``cache_prefix``) -- they become immutable
    shared pages, refcounted per mapping;
  * a later request whose prompt starts with the same token block
    (``lookup`` compares the FULL block, not just the digest) maps those
    pages into its own table rows via ``share`` and only allocates private
    pages for its suffix;
  * ``release`` decrefs shared pages instead of freeing them -- other
    sharers and the index keep them alive. Refcount-0 cached pages stay
    resident as a warm cache and are reclaimed LRU-entry-at-a-time only
    under pool pressure (``_take_page`` eviction); a page with live refs is
    never evicted;
  * ``cow`` is the copy-on-write escape hatch: it remaps a slot's LAST
    shared table row to a fresh private page (the caller copies the device
    contents) so a sharer that must write inside the shared span can do so
    without perturbing the other sharers.

``free_unreserved`` generalizes to ``free + evictable - unfilled promises``
so admission can count reclaimable refcount-0 cached pages as headroom
while never breaking an outstanding reservation.

Page 0 is reserved as the *garbage page*: table rows reset to 0, so device
scatters/gathers through free or not-yet-extended slots land on a real page
whose contents are never read unmasked. ``capacity`` excludes it.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.orchestrator.obs.metrics import MetricsRegistry

GARBAGE_PAGE = 0


@dataclass
class PrefixEntry:
    """One cached prompt prefix: its digest, the FULL token block (for the
    exact compare that defeats digest collisions), and the immutable pages
    holding its first ``len(pages) * page_size`` KV positions."""
    digest: str
    tokens: np.ndarray            # (block_len,) int32, the declared block
    pages: list[int]              # physical page ids, page-aligned coverage
    last_used: int = 0            # LRU clock stamp
    hits: int = 0


class PagePool:
    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 max_pages: int, *, metrics: MetricsRegistry | None = None,
                 replica: str | None = None):
        if n_pages < 2:
            raise ValueError("PagePool needs >= 2 pages (page 0 is garbage)")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.n_slots = int(n_slots)
        self.max_pages = int(max_pages)
        self.free: list[int] = list(range(1, self.n_pages))
        self.table = np.full((self.n_slots, self.max_pages), GARBAGE_PAGE,
                             np.int32)
        self.owned: list[list[int]] = [[] for _ in range(self.n_slots)]
        # leading table rows mapped to SHARED (cached) pages; a slot's table
        # is always [shared rows, owned rows, garbage...]
        self.shared: list[list[int]] = [[] for _ in range(self.n_slots)]
        self.reserved = np.zeros(self.n_slots, np.int64)
        # per-page count of slot mappings (shared rows only; owned pages are
        # exclusively held, cached pages at refcount 0 are evictable)
        self.refcount = np.zeros(self.n_pages, np.int64)
        self.prefix: dict[str, PrefixEntry] = {}
        # slots paused by page-level preemption: all pages reclaimed, the
        # owning request waits queued for resume (check() pins emptiness)
        self.paused: set[int] = set()
        self._clock = 0
        # accounting (status + the fig7/fig9 benchmarks) lives in the shared
        # registry (the pod's when embedded, a private one standalone); the
        # old attribute names survive below as read-only property shims.
        # "pool_"-prefixed names keep pool prefix-hits/evictions distinct
        # from the engine-level counters of the same concept.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        labels = {"replica": replica} if replica is not None else {}
        self._c_alloc = self.metrics.counter("pages_allocated", **labels)
        self._c_freed = self.metrics.counter("pages_freed", **labels)
        self._c_evict = self.metrics.counter("pool_evictions", **labels)
        self._c_cow = self.metrics.counter("cow_copies", **labels)
        self._c_phits = self.metrics.counter("pool_prefix_hits", **labels)
        self._c_paused = self.metrics.counter("pool_preemptions", **labels)
        self._g_in_use = self.metrics.gauge("pool_in_use", **labels)

    # registry-backed shims for the pre-registry attribute names
    @property
    def pages_allocated(self) -> int:
        return self._c_alloc.value

    @property
    def pages_freed(self) -> int:
        return self._c_freed.value

    @property
    def evictions(self) -> int:
        return self._c_evict.value

    @property
    def cow_copies(self) -> int:
        return self._c_cow.value

    @property
    def prefix_hits(self) -> int:
        return self._c_phits.value

    @property
    def peak_in_use(self) -> int:
        return self._g_in_use.high

    # -- capacity -----------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Allocatable pages (garbage page excluded)."""
        return self.n_pages - 1

    @property
    def total_reserved(self) -> int:
        return int(self.reserved.sum())

    @property
    def total_owned(self) -> int:
        return sum(len(o) for o in self.owned)

    @property
    def cached_pages(self) -> int:
        """Pages resident in the prefix index (shared or warm)."""
        return sum(len(e.pages) for e in self.prefix.values())

    def _evictable(self, entry: PrefixEntry) -> bool:
        return all(self.refcount[p] == 0 for p in entry.pages)

    @property
    def evictable_pages(self) -> int:
        """Cached pages with no live sharers -- reclaimable under pressure."""
        return sum(len(e.pages) for e in self.prefix.values()
                   if self._evictable(e))

    @property
    def free_unreserved(self) -> int:
        """Headroom for NEW reservations: free + evictable cached pages,
        minus pages already promised to admitted requests but not yet drawn
        (the promise invariant ``check`` pins)."""
        unfilled = self.total_reserved - self.total_owned
        return len(self.free) + self.evictable_pages - unfilled

    def pages_for(self, positions: int) -> int:
        """Pages needed to cover ``positions`` KV positions."""
        return -(-int(positions) // self.page_size)

    def can_reserve(self, n: int) -> bool:
        return n <= self.free_unreserved

    def pin_cost(self, entry: PrefixEntry) -> int:
        """Extra headroom a ``share`` of ``entry`` consumes: pinning a
        currently-evictable entry removes ALL its pages from the evictable
        set, so admission must budget them like an allocation."""
        return len(entry.pages) if self._evictable(entry) else 0

    # -- allocation ---------------------------------------------------------
    def reserve(self, slot: int, n: int) -> None:
        if self.reserved[slot] or self.owned[slot] or self.shared[slot]:
            raise RuntimeError(f"slot {slot} already holds a reservation")
        if not self.can_reserve(n):
            raise RuntimeError(
                f"cannot reserve {n} pages: {self.free_unreserved} unreserved")
        # a paused slot coming back through reserve IS the resume: the
        # suffix re-prefill re-books its worst case like a fresh admission
        self.paused.discard(slot)
        self.reserved[slot] = n

    def _take_page(self) -> int:
        """One page off the free-list, evicting LRU refcount-0 prefix
        entries under pressure. Never touches a page with live refs."""
        while not self.free:
            victims = [e for e in self.prefix.values() if self._evictable(e)]
            if not victims:
                raise RuntimeError(
                    "page pool exhausted: no free pages and every cached "
                    "prefix has live sharers")
            lru = min(victims, key=lambda e: e.last_used)
            self._evict(lru)
        return self.free.pop()

    def _evict(self, entry: PrefixEntry) -> None:
        assert self._evictable(entry), "evicting a prefix with live refs"
        del self.prefix[entry.digest]
        self.free.extend(entry.pages)
        self._c_freed.inc(len(entry.pages))
        self._c_evict.inc()
        self._g_in_use.set(self.in_use)

    def alloc_upto(self, slot: int, hi: int) -> None:
        """Ensure pages cover logical positions [0, hi] for ``slot``.
        Shared rows count toward coverage; only private (owned) pages are
        drawn from the free-list."""
        need = self.pages_for(hi + 1)
        base = len(self.shared[slot])
        have = base + len(self.owned[slot])
        if need <= have:
            return
        if need - base > self.reserved[slot]:
            raise RuntimeError(
                f"slot {slot}: {need - base} private pages exceeds "
                f"reservation {int(self.reserved[slot])}")
        for j in range(have, need):
            page = self._take_page()
            self.owned[slot].append(page)
            self.table[slot, j] = page
            self._c_alloc.inc()
        self._g_in_use.set(self.in_use)

    def release(self, slot: int) -> None:
        """Full reclaim of PRIVATE state: owned pages and the remaining
        reservation return; shared pages are only decref'd -- they belong
        to the prefix index and possibly to other sharers' table rows, so
        freeing them here would let a reallocation clobber a live prefix."""
        pages = self.owned[slot]
        self.free.extend(pages)
        self._c_freed.inc(len(pages))
        self.owned[slot] = []
        for p in self.shared[slot]:
            self.refcount[p] -= 1
        self.shared[slot] = []
        self.reserved[slot] = 0
        self.table[slot, :] = GARBAGE_PAGE
        self.paused.discard(slot)
        self._g_in_use.set(self.in_use)

    def pause(self, slot: int) -> int:
        """Page-level preemption of ``slot``: reclaim its private pages and
        unfilled reservation (and decref its shared mappings) exactly like
        ``release``, then mark the slot paused. Returns the number of pages
        returned to the free-list. The paused mark is bookkeeping for
        ``check()`` -- a paused slot must hold NOTHING until its resume
        re-reserves -- and clears on the next ``reserve`` or ``release``."""
        if not (self.reserved[slot] or self.owned[slot] or self.shared[slot]):
            raise RuntimeError(f"slot {slot} has nothing to preempt")
        freed = len(self.owned[slot])
        self.release(slot)
        self.paused.add(slot)
        self._c_paused.inc()
        return freed

    # -- prefix sharing -----------------------------------------------------
    def lookup(self, digest: str, tokens: np.ndarray,
               touch: bool = False) -> PrefixEntry | None:
        """Cache probe. A digest match alone is NOT a hit: the stored block
        is compared token-for-token, so a colliding digest over different
        tokens misses instead of serving someone else's prefix."""
        entry = self.prefix.get(digest)
        if entry is None:
            return None
        tokens = np.asarray(tokens, np.int32)
        if entry.tokens.shape != tokens.shape or \
                not np.array_equal(entry.tokens, tokens):
            return None
        if touch:
            self._clock += 1
            entry.last_used = self._clock
        return entry

    def share(self, slot: int, entry: PrefixEntry, n: int) -> None:
        """Map the first ``n`` cached pages of ``entry`` into ``slot``'s
        leading table rows. Must precede any private allocation for the
        slot (shared rows always form the table prefix)."""
        if self.shared[slot] or self.owned[slot]:
            raise RuntimeError(f"slot {slot} already has mapped pages")
        if n < 1 or n > len(entry.pages):
            raise ValueError(f"share of {n} pages from a "
                             f"{len(entry.pages)}-page prefix")
        # pinning a currently-evictable entry shrinks the evictable set the
        # outstanding reservations count on: enforce the preempt-free
        # promise HERE, not just in the admission caller (can_start budgets
        # pin_cost before reserving; any other call path must too)
        pin = self.pin_cost(entry)
        if pin and self.free_unreserved < pin:
            raise RuntimeError(
                f"sharing would pin {pin} evictable pages promised to "
                f"outstanding reservations ({self.free_unreserved} "
                "unreserved)")
        pages = list(entry.pages[:n])
        for j, p in enumerate(pages):
            self.refcount[p] += 1
            self.table[slot, j] = p
        self.shared[slot] = pages
        self._clock += 1
        entry.last_used = self._clock
        entry.hits += 1
        self._c_phits.inc()
        self._g_in_use.set(self.in_use)

    def cache_prefix(self, digest: str, tokens: np.ndarray, slot: int,
                     n: int) -> bool:
        """Promote ``slot``'s first ``n`` owned pages into the prefix index
        (they must already hold fully-written prompt KV). The slot keeps
        using them -- as shared refs now -- and its reservation shrinks by
        ``n`` since those rows no longer draw private pages. First writer
        wins: an existing entry under the digest is kept untouched."""
        if digest in self.prefix:
            return False
        if self.shared[slot] or n < 1 or n > len(self.owned[slot]):
            return False
        pages = self.owned[slot][:n]
        self.owned[slot] = self.owned[slot][n:]
        self.shared[slot] = list(pages)
        for p in pages:
            self.refcount[p] += 1
        self.reserved[slot] -= n
        self._clock += 1
        self.prefix[digest] = PrefixEntry(
            digest=digest, tokens=np.array(tokens, np.int32, copy=True),
            pages=list(pages), last_used=self._clock)
        return True

    def cow(self, slot: int) -> tuple[int, int]:
        """Copy-on-write the slot's LAST shared table row: remap it to a
        fresh private page and decref the shared one. Returns (old, new)
        physical ids -- the caller copies the device page contents before
        any write. Draws against the slot's reservation."""
        if not self.shared[slot]:
            raise RuntimeError(f"slot {slot} has no shared pages to COW")
        if len(self.owned[slot]) + 1 > self.reserved[slot]:
            raise RuntimeError(
                f"slot {slot}: COW would exceed reservation "
                f"{int(self.reserved[slot])}")
        old = self.shared[slot].pop()
        row = len(self.shared[slot])
        new = self._take_page()
        self.refcount[old] -= 1
        self.owned[slot].insert(0, new)
        self.table[slot, row] = new
        self._c_alloc.inc()
        self._c_cow.inc()
        self._g_in_use.set(self.in_use)
        return old, new

    def drop_prefixes(self) -> int:
        """Evict every refcount-0 cached prefix (tests / explicit flush).
        Entries with live sharers survive. Returns entries evicted."""
        n = 0
        for e in [e for e in self.prefix.values() if self._evictable(e)]:
            self._evict(e)
            n += 1
        return n

    # -- introspection ------------------------------------------------------
    @property
    def in_use(self) -> int:
        """Non-free pages: privately owned + cached (shared or warm)."""
        return self.capacity - len(self.free)

    def check(self) -> None:
        """Invariants; raises AssertionError on any violation. Cheap enough
        to call after every operation in tests."""
        owned_all = [p for o in self.owned for p in o]
        cached_all = [p for e in self.prefix.values() for p in e.pages]
        assert GARBAGE_PAGE not in owned_all, "garbage page was allocated"
        assert GARBAGE_PAGE not in cached_all, "garbage page was cached"
        assert GARBAGE_PAGE not in self.free, "garbage page on free-list"
        assert len(set(owned_all)) == len(owned_all), "page owned twice"
        assert len(set(cached_all)) == len(cached_all), \
            "page cached in two prefixes"
        assert len(set(self.free)) == len(self.free), "free-list duplicate"
        assert not (set(owned_all) & set(self.free)), "page both owned+free"
        assert not (set(cached_all) & set(self.free)), "page both cached+free"
        assert not (set(owned_all) & set(cached_all)), \
            "page both owned and cached"
        assert len(self.free) + len(owned_all) + len(cached_all) \
            == self.capacity, "pages leaked or conjured"
        assert self.pages_allocated - self.pages_freed \
            == len(owned_all) + len(cached_all)
        # refcounts == shared-row occurrences, and every shared page is
        # backed by a live prefix entry (eviction requires refcount 0, so a
        # mapped page can never lose its entry out from under a sharer)
        refs: dict[int, int] = {}
        for slot, sh in enumerate(self.shared):
            for p in sh:
                refs[p] = refs.get(p, 0) + 1
            assert set(sh) <= set(cached_all), \
                f"slot {slot} shares a page missing from the prefix index"
        for p in range(self.n_pages):
            assert self.refcount[p] == refs.get(p, 0), \
                f"page {p}: refcount {int(self.refcount[p])} != " \
                f"{refs.get(p, 0)} table occurrences"
        for slot in range(self.n_slots):
            rows = self.shared[slot] + self.owned[slot]
            assert len(self.owned[slot]) <= self.reserved[slot], \
                "allocation > reservation"
            for j, page in enumerate(rows):
                assert self.table[slot, j] == page, "table/rows mismatch"
            assert (self.table[slot, len(rows):] == GARBAGE_PAGE).all(), \
                "table maps unallocated positions"
        assert self.total_reserved <= self.capacity, "pool over-committed"
        # the preempt-free promise: every reserved-but-undrawn page must be
        # coverable by free + evictable pages RIGHT NOW
        unfilled = self.total_reserved - self.total_owned
        assert unfilled <= len(self.free) + self.evictable_pages, \
            "outstanding reservations exceed reclaimable pages"
        # paused (preempted) slots hold NOTHING: their pages were reclaimed
        # at pause time and nothing may creep back before resume re-reserves
        assert self.paused <= set(range(self.n_slots)), "phantom paused slot"
        for slot in self.paused:
            assert not self.owned[slot] and not self.shared[slot] \
                and not self.reserved[slot], \
                f"paused slot {slot} still holds pages or a reservation"

    def status(self) -> dict:
        return {
            "pages": self.capacity,
            "page_size": self.page_size,
            "in_use": self.in_use,
            "reserved": self.total_reserved,
            "free_unreserved": self.free_unreserved,
            "peak_in_use": self.peak_in_use,
            "cached_pages": self.cached_pages,
            "cached_prefixes": len(self.prefix),
            "prefix_hits": self.prefix_hits,
            "evictions": self.evictions,
            "cow_copies": self.cow_copies,
            "preemptions": self._c_paused.value,
            "paused_slots": len(self.paused),
        }
