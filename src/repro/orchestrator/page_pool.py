"""PagePool: host-side block allocator for the paged KV cache.

The device holds one global pool per layer, ``(n_kv, n_pages, page_size,
hd)``; this class owns the free-list and the per-slot page table that maps
logical positions to physical pages. All bookkeeping is host numpy -- the
only device traffic it generates is the (n_slots, max_pages) int32 table
shipped with each decode dispatch, plus the spill/restore page copies its
registered callbacks perform.

Allocation protocol (reservation-based, preempt-free):

  * ``reserve(slot, n)`` at ADMISSION sets aside the request's worst-case
    page count (ceil((prompt + budget + chunk) / page_size)). Admission is
    gated on ``can_reserve`` -- the pool never over-commits, so a running
    request can never fail to get a page mid-decode and nothing is ever
    preempted. Backpressure = the scheduler simply stops admitting.
  * ``alloc_upto(slot, hi)`` is the lazy ALLOC-ON-WRITE: physical pages are
    pulled from the free-list only when decode is about to write position
    ``hi`` (prefill bulk-allocates the prompt's pages the same way). A
    request that exits early (EOS) therefore returns its never-written
    reserved pages without them ever leaving the free-list.
  * ``release(slot)`` at COMPLETION returns owned pages and the remaining
    reservation in one step and resets the table row.
  * ``pause(slot)`` is page-level PREEMPTION: the same full reclaim as
    ``release`` (private pages freed, reservation returned, shared pages
    decref'd) but the slot is marked *paused* -- ``check()`` pins that a
    paused slot holds nothing until a later ``reserve`` (the resume's
    suffix re-prefill) clears the flag.

Prefix registry (the container-image model: content-addressed layers shared
by every image stacked on them, re-pulled from the registry by digest when
evicted):

  * the prefix index is a RADIX TREE over page-aligned blocks
    (``prefix_registry.PrefixRadix``): one node per block, keyed by a
    chained digest, so "system prompt + few-shot examples" requests share
    the ancestor pages of plain "system prompt" requests instead of each
    family caching a disjoint whole-prefix entry;
  * ``match`` walks the tree for the longest registered ancestry --
    including a PARTIAL in-node match when the declared prefix ends
    mid-block (the boundary page becomes a read-only merge operand for the
    suffix prefill's first private page);
  * ``share_chain`` maps the matched chain into a slot's leading table rows
    (refcount per mapping) and restores any spilled chain node first;
    ``promote_chain`` registers a slot's freshly-written leading pages as
    new nodes, every complete block individually -- interior promotion
    grows existing families deeper;
  * under pool pressure ``_take_page`` SPILLS the LRU refcount-0 node
    (leaf-first, ties broken by digest so eviction order is deterministic):
    the page contents move to the host-RAM ``SpillStore`` keyed by node
    digest and the device page returns to the free-list; the node survives
    with ``page=None``. A later ``share_chain`` pulls it back by digest --
    a registry pull instead of a re-prefill. With the spill tier disabled
    (``spill_pages=0``) pressure falls back to true eviction.

``free_unreserved`` generalizes to ``free + evictable - unfilled promises``
so admission can count reclaimable refcount-0 cached pages as headroom
while never breaking an outstanding reservation. ``pin_cost`` dedupes by
page id, so a page reachable through several match nodes is only budgeted
once and admission never under-counts its headroom.

Page 0 is reserved as the *garbage page*: table rows reset to 0, so device
scatters/gathers through free or not-yet-extended slots land on a real page
whose contents are never read unmasked. ``capacity`` excludes it.
"""

from __future__ import annotations

import numpy as np

from repro.orchestrator.obs.metrics import MetricsRegistry
from repro.orchestrator.prefix_registry import (PrefixMatch, PrefixRadix,
                                                RadixNode, SpillStore)

GARBAGE_PAGE = 0


class PagePool:
    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 max_pages: int, *, metrics: MetricsRegistry | None = None,
                 replica: str | None = None,
                 spill_pages: int | None = 0):
        if n_pages < 2:
            raise ValueError("PagePool needs >= 2 pages (page 0 is garbage)")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.n_slots = int(n_slots)
        self.max_pages = int(max_pages)
        self.free: list[int] = list(range(1, self.n_pages))
        self.table = np.full((self.n_slots, self.max_pages), GARBAGE_PAGE,
                             np.int32)
        self.owned: list[list[int]] = [[] for _ in range(self.n_slots)]
        # leading table rows mapped to SHARED (cached) pages; a slot's table
        # is always [shared rows, owned rows, garbage...]
        self.shared: list[list[int]] = [[] for _ in range(self.n_slots)]
        self.reserved = np.zeros(self.n_slots, np.int64)
        # per-page count of slot mappings (shared rows only; owned pages are
        # exclusively held, cached pages at refcount 0 are evictable)
        self.refcount = np.zeros(self.n_pages, np.int64)
        # the prefix registry: radix tree of page blocks + host spill tier.
        # spill_pages: 0 disables the tier (pressure evicts), None leaves it
        # unbounded, > 0 caps resident payloads (LRU subtrees pruned past it)
        self.radix = PrefixRadix(self.page_size)
        self.spill_enabled = spill_pages is None or spill_pages > 0
        self.store = SpillStore(capacity=spill_pages
                                if self.spill_enabled else 0)
        # digests pinned against spill/eviction/pruning between share_chain
        # and unpin(): the partial boundary node is read by the suffix
        # prefill AFTER the pool ops that could otherwise reclaim it
        self._pinned: set[str] = set()
        # device-side page movers, registered by the owning engine; absent
        # (pure-host tests) the payload is a bookkeeping stub
        self._spill_save = None
        self._spill_load = None
        # (kind, digest) spill/restore events since the last drain -- the
        # engine turns them into trace spans under the triggering request
        self.events: list[tuple[str, str]] = []
        # slots paused by page-level preemption: all pages reclaimed, the
        # owning request waits queued for resume (check() pins emptiness)
        self.paused: set[int] = set()
        self._clock = 0
        # accounting (status + the fig7/fig9/fig11 benchmarks) lives in the
        # shared registry (the pod's when embedded, a private one
        # standalone); the old attribute names survive below as read-only
        # property shims. "pool_"-prefixed names keep pool prefix-hits/
        # evictions distinct from the engine-level counters of the same
        # concept.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        labels = {"replica": replica} if replica is not None else {}
        self._c_alloc = self.metrics.counter("pages_allocated", **labels)
        self._c_freed = self.metrics.counter("pages_freed", **labels)
        self._c_evict = self.metrics.counter("pool_evictions", **labels)
        self._c_cow = self.metrics.counter("cow_copies", **labels)
        self._c_phits = self.metrics.counter("pool_prefix_hits", **labels)
        self._c_paused = self.metrics.counter("pool_preemptions", **labels)
        self._c_spill = self.metrics.counter("pool_spills", **labels)
        self._c_restore = self.metrics.counter("pool_restores", **labels)
        self._g_in_use = self.metrics.gauge("pool_in_use", **labels)

    # registry-backed shims for the pre-registry attribute names
    @property
    def pages_allocated(self) -> int:
        return self._c_alloc.value

    @property
    def pages_freed(self) -> int:
        return self._c_freed.value

    @property
    def evictions(self) -> int:
        return self._c_evict.value

    @property
    def cow_copies(self) -> int:
        return self._c_cow.value

    @property
    def prefix_hits(self) -> int:
        return self._c_phits.value

    @property
    def spills(self) -> int:
        return self._c_spill.value

    @property
    def restores(self) -> int:
        return self._c_restore.value

    @property
    def peak_in_use(self) -> int:
        return self._g_in_use.high

    # -- device IO hooks ----------------------------------------------------
    def set_spill_io(self, save, load) -> None:
        """Register the device-side page movers: ``save(page) -> payload``
        copies a pool page to host, ``load(page, payload)`` writes one
        back. Without them (pure-host tests) spilled payloads are stubs --
        the bookkeeping is identical either way."""
        self._spill_save = save
        self._spill_load = load

    def drain_events(self) -> list[tuple[str, str]]:
        """Spill/restore events since the last drain, oldest first. The
        engine records them as trace spans attributed to the request whose
        allocation triggered the tier movement."""
        out = self.events
        self.events = []
        return out

    # -- capacity -----------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Allocatable pages (garbage page excluded)."""
        return self.n_pages - 1

    @property
    def total_reserved(self) -> int:
        return int(self.reserved.sum())

    @property
    def total_owned(self) -> int:
        return sum(len(o) for o in self.owned)

    @property
    def cached_pages(self) -> int:
        """Pages resident in the prefix registry (shared or warm)."""
        return sum(1 for n in self.radix.walk() if n.resident)

    @property
    def spilled_pages(self) -> int:
        """Registry nodes currently in the host spill tier."""
        return len(self.store)

    def _node_evictable(self, node: RadixNode) -> bool:
        return (node.resident and self.refcount[node.page] == 0
                and node.digest not in self._pinned)

    @property
    def evictable_pages(self) -> int:
        """Cached pages with no live sharers -- reclaimable under pressure.
        Counted as a SET of page ids: a page reachable through more than
        one node must not inflate the reclaimable headroom."""
        return len({n.page for n in self.radix.walk()
                    if self._node_evictable(n)})

    @property
    def free_unreserved(self) -> int:
        """Headroom for NEW reservations: free + evictable cached pages,
        minus pages already promised to admitted requests but not yet drawn
        (the promise invariant ``check`` pins)."""
        unfilled = self.total_reserved - self.total_owned
        return len(self.free) + self.evictable_pages - unfilled

    def pages_for(self, positions: int) -> int:
        """Pages needed to cover ``positions`` KV positions."""
        return -(-int(positions) // self.page_size)

    def can_reserve(self, n: int) -> bool:
        return n <= self.free_unreserved

    def pin_cost(self, m: PrefixMatch) -> int:
        """Extra headroom a ``share_chain`` of ``m`` consumes: pinning the
        currently-evictable nodes of the chain (partial boundary included)
        removes their pages from the evictable set, so admission must
        budget them like an allocation. Deduped BY PAGE ID -- a page
        referenced by more than one match node counts once, else admission
        under-admits under heavy sharing."""
        return len({n.page for n in m.all_nodes()
                    if self._node_evictable(n)})

    def restore_cost(self, m: PrefixMatch) -> int:
        """Free pages a ``share_chain`` of ``m`` must draw to pull spilled
        chain nodes (partial boundary included) back from the host tier."""
        return sum(1 for n in m.all_nodes() if not n.resident)

    # -- allocation ---------------------------------------------------------
    def reserve(self, slot: int, n: int) -> None:
        if self.reserved[slot] or self.owned[slot] or self.shared[slot]:
            raise RuntimeError(f"slot {slot} already holds a reservation")
        if not self.can_reserve(n):
            raise RuntimeError(
                f"cannot reserve {n} pages: {self.free_unreserved} unreserved")
        # a paused slot coming back through reserve IS the resume: the
        # suffix re-prefill re-books its worst case like a fresh admission
        self.paused.discard(slot)
        self.reserved[slot] = n

    def _victims(self) -> list[RadixNode]:
        """Reclaimable nodes in eviction order: resident, refcount 0,
        unpinned, with no resident children (leaf-first, so removing or
        spilling one never strands a resident descendant), sorted by
        (last_used, digest) -- the digest tie-break keeps eviction order
        deterministic when several nodes share a last-use tick."""
        out = [n for n in self.radix.walk()
               if self._node_evictable(n)
               and not any(c.resident for c in n.children.values())]
        out.sort(key=lambda n: (n.last_used, n.digest))
        return out

    def _take_page(self) -> int:
        """One page off the free-list, spilling (or, with the tier
        disabled, evicting) LRU refcount-0 registry nodes under pressure.
        Never touches a page with live refs or a pinned digest."""
        while not self.free:
            victims = self._victims()
            if not victims:
                raise RuntimeError(
                    "page pool exhausted: no free pages and every cached "
                    "prefix has live sharers")
            self._spill_or_evict(victims[0])
        return self.free.pop()

    def _spill_or_evict(self, node: RadixNode) -> None:
        if self.spill_enabled:
            payload = (self._spill_save(node.page)
                       if self._spill_save is not None
                       else ("stub", node.digest))
            self.store.put(node.digest, payload)
            self.free.append(node.page)
            node.page = None
            self._c_freed.inc()
            self._c_spill.inc()
            self.events.append(("spill", node.digest))
            self._g_in_use.set(self.in_use)
            self._enforce_store_capacity()
        else:
            self._evict_node(node)

    def _evict_node(self, node: RadixNode) -> None:
        """True eviction of a resident leaf node: page freed, node gone."""
        assert self._node_evictable(node), "evicting a live/pinned node"
        assert not node.children, "evicting an interior node"
        self.free.append(node.page)
        node.page = None
        self.radix.remove(node)
        self._c_freed.inc()
        self._c_evict.inc()
        self._g_in_use.set(self.in_use)

    def _restore_node(self, node: RadixNode) -> None:
        """Registry pull: draw a free page and re-materialize a spilled
        node's contents from the host tier by digest."""
        assert not node.resident, "restoring a resident node"
        page = self._take_page()
        payload = self.store.pop(node.digest)
        if self._spill_load is not None:
            self._spill_load(page, payload)
        node.page = page
        self._c_alloc.inc()
        self._c_restore.inc()
        self.events.append(("restore", node.digest))
        self._g_in_use.set(self.in_use)

    def _enforce_store_capacity(self) -> None:
        """Prune LRU spilled subtrees past the host-tier budget. A pruned
        node's descendants are all spilled too (resident needs a resident
        parent), so whole subtrees leave the registry together. Pinned
        chains are skipped -- they are mid-restore and will leave the
        store on their own."""
        while self.store.over_capacity:
            by_digest = {n.digest: n for n in self.radix.walk()
                         if not n.resident}
            pruned = False
            for d in self.store.lru_digests():
                node = by_digest[d]
                sub = self.radix.subtree(node)
                if any(n.digest in self._pinned for n in sub):
                    continue
                for n in reversed(sub):
                    assert not n.resident, "pruning a resident node"
                    self.store.discard(n.digest)
                    self.radix.remove(n)
                    self._c_evict.inc()
                pruned = True
                break
            if not pruned:
                break

    def alloc_upto(self, slot: int, hi: int) -> None:
        """Ensure pages cover logical positions [0, hi] for ``slot``.
        Shared rows count toward coverage; only private (owned) pages are
        drawn from the free-list."""
        need = self.pages_for(hi + 1)
        base = len(self.shared[slot])
        have = base + len(self.owned[slot])
        if need <= have:
            return
        if need - base > self.reserved[slot]:
            raise RuntimeError(
                f"slot {slot}: {need - base} private pages exceeds "
                f"reservation {int(self.reserved[slot])}")
        for j in range(have, need):
            page = self._take_page()
            self.owned[slot].append(page)
            self.table[slot, j] = page
            self._c_alloc.inc()
        self._g_in_use.set(self.in_use)

    def release(self, slot: int) -> None:
        """Full reclaim of PRIVATE state: owned pages and the remaining
        reservation return; shared pages are only decref'd -- they belong
        to the prefix registry and possibly to other sharers' table rows,
        so freeing them here would let a reallocation clobber a live
        prefix."""
        pages = self.owned[slot]
        self.free.extend(pages)
        self._c_freed.inc(len(pages))
        self.owned[slot] = []
        for p in self.shared[slot]:
            self.refcount[p] -= 1
        self.shared[slot] = []
        self.reserved[slot] = 0
        self.table[slot, :] = GARBAGE_PAGE
        self.paused.discard(slot)
        self._g_in_use.set(self.in_use)

    def pause(self, slot: int) -> int:
        """Page-level preemption of ``slot``: reclaim its private pages and
        unfilled reservation (and decref its shared mappings) exactly like
        ``release``, then mark the slot paused. Returns the number of pages
        returned to the free-list. The paused mark is bookkeeping for
        ``check()`` -- a paused slot must hold NOTHING until its resume
        re-reserves -- and clears on the next ``reserve`` or ``release``."""
        if not (self.reserved[slot] or self.owned[slot] or self.shared[slot]):
            raise RuntimeError(f"slot {slot} has nothing to preempt")
        freed = len(self.owned[slot])
        self.release(slot)
        self.paused.add(slot)
        self._c_paused.inc()
        return freed

    # -- prefix registry ----------------------------------------------------
    def match(self, tokens: np.ndarray, touch: bool = False) -> PrefixMatch:
        """Longest registered ancestry of ``tokens`` (see
        ``PrefixRadix.match``): fully-matched whole blocks plus an optional
        partial in-node boundary. Token blocks are compared byte-for-byte
        during the walk, so a chained-digest collision over different
        tokens stops the match instead of serving someone else's layer."""
        m = self.radix.match(tokens)
        if touch and m.all_nodes():
            for n in m.all_nodes():
                self._clock += 1
                n.last_used = self._clock
        return m

    def share_chain(self, slot: int, m: PrefixMatch) -> None:
        """Map the matched chain's pages into ``slot``'s leading table rows
        (refcount per mapping), pulling any spilled chain node back from
        the host tier first -- parents before children, so the resident
        subtree stays rooted. The partial boundary node (if any) is
        restored and PINNED but not mapped: the suffix prefill reads it as
        a merge operand and the engine calls ``unpin`` once that read is
        done. Must precede any private allocation for the slot."""
        if self.shared[slot] or self.owned[slot]:
            raise RuntimeError(f"slot {slot} already has mapped pages")
        chain = m.all_nodes()
        if not chain:
            raise ValueError("share_chain of an empty match")
        # pinning currently-evictable nodes shrinks the evictable set and
        # restores draw free pages: enforce the preempt-free promise HERE,
        # not just in the admission caller (can_start budgets pin_cost +
        # restore_cost before reserving; any other call path must too)
        need = self.pin_cost(m) + self.restore_cost(m)
        if need and self.free_unreserved < need:
            raise RuntimeError(
                f"sharing would pin/restore {need} pages promised to "
                f"outstanding reservations ({self.free_unreserved} "
                "unreserved)")
        self._pinned.update(n.digest for n in chain)
        pages: list[int] = []
        for n in m.nodes:
            if not n.resident:
                self._restore_node(n)
            self.refcount[n.page] += 1
            self.table[slot, len(pages)] = n.page
            pages.append(n.page)
            self._clock += 1
            n.last_used = self._clock
            n.hits += 1
        if m.partial is not None:
            if not m.partial.resident:
                self._restore_node(m.partial)
            self._clock += 1
            m.partial.last_used = self._clock
            m.partial.hits += 1
        self.shared[slot] = pages
        self._c_phits.inc()
        self._g_in_use.set(self.in_use)

    def unpin(self) -> None:
        """Release the spill/eviction pins taken by ``share_chain``. The
        engine calls this once the suffix prefill has consumed the chain
        (mapped rows stay protected by their refcounts; the partial
        boundary page becomes reclaimable again)."""
        self._pinned.clear()
        self._enforce_store_capacity()

    def promote_chain(self, slot: int, parent: RadixNode | None,
                      blocks: list[np.ndarray]) -> list[RadixNode]:
        """Register ``slot``'s leading owned pages as new registry nodes,
        one per complete block, chained under ``parent`` (None = tree
        root). The slot keeps using the pages -- as shared refs now -- and
        its reservation shrinks by one per promoted page since those rows
        no longer draw private pages. First writer wins: an existing child
        (or a digest collision) stops the promotion there, leaving the
        remaining pages private. Returns the nodes created."""
        parent = parent if parent is not None else self.radix.root
        if len(blocks) > len(self.owned[slot]):
            raise ValueError(
                f"promoting {len(blocks)} blocks but slot {slot} owns "
                f"{len(self.owned[slot])} pages")
        promoted: list[RadixNode] = []
        for blk in blocks:
            page = self.owned[slot][0]
            node = self.radix.insert(parent, blk, page)
            if node is None:
                break
            self.owned[slot].pop(0)
            self.shared[slot].append(page)
            self.refcount[page] += 1
            self.reserved[slot] -= 1
            self._clock += 1
            node.last_used = self._clock
            promoted.append(node)
            parent = node
        return promoted

    def spill_one(self) -> str | None:
        """Explicitly move the current eviction victim to the host tier
        (tests and proactive tiering). Returns the spilled node's digest,
        or None when nothing is reclaimable or the tier is disabled."""
        if not self.spill_enabled:
            return None
        victims = self._victims()
        if not victims:
            return None
        node = victims[0]
        digest = node.digest
        self._spill_or_evict(node)
        return digest

    def cow(self, slot: int) -> tuple[int, int]:
        """Copy-on-write the slot's LAST shared table row: remap it to a
        fresh private page and decref the shared one. Returns (old, new)
        physical ids -- the caller copies the device page contents before
        any write. Draws against the slot's reservation."""
        if not self.shared[slot]:
            raise RuntimeError(f"slot {slot} has no shared pages to COW")
        if len(self.owned[slot]) + 1 > self.reserved[slot]:
            raise RuntimeError(
                f"slot {slot}: COW would exceed reservation "
                f"{int(self.reserved[slot])}")
        old = self.shared[slot].pop()
        row = len(self.shared[slot])
        new = self._take_page()
        self.refcount[old] -= 1
        self.owned[slot].insert(0, new)
        self.table[slot, row] = new
        self._c_alloc.inc()
        self._c_cow.inc()
        self._g_in_use.set(self.in_use)
        return old, new

    def drop_prefixes(self) -> int:
        """Flush the registry (tests / explicit reset): every refcount-0
        node leaves, resident pages freed and spilled payloads discarded,
        children before parents. Nodes with live sharers survive (and so
        do their ancestors -- a parent's refcount bounds its children's).
        Returns nodes dropped."""
        n = 0
        for node in reversed(self.radix.walk()):
            if node.children or node.digest in self._pinned:
                continue
            if node.resident:
                if self.refcount[node.page] != 0:
                    continue
                self._evict_node(node)
            else:
                self.store.discard(node.digest)
                self.radix.remove(node)
                self._c_evict.inc()
            n += 1
        return n

    # -- introspection ------------------------------------------------------
    @property
    def in_use(self) -> int:
        """Non-free pages: privately owned + cached (shared or warm)."""
        return self.capacity - len(self.free)

    def check(self) -> None:
        """Invariants; raises AssertionError on any violation. Cheap enough
        to call after every operation in tests."""
        nodes = self.radix.walk()
        self.radix.check()
        owned_all = [p for o in self.owned for p in o]
        cached_all = [n.page for n in nodes if n.resident]
        spilled = [n for n in nodes if not n.resident]
        assert GARBAGE_PAGE not in owned_all, "garbage page was allocated"
        assert GARBAGE_PAGE not in cached_all, "garbage page was cached"
        assert GARBAGE_PAGE not in self.free, "garbage page on free-list"
        assert len(set(owned_all)) == len(owned_all), "page owned twice"
        assert len(set(cached_all)) == len(cached_all), \
            "page cached in two registry nodes"
        assert len(set(self.free)) == len(self.free), "free-list duplicate"
        assert not (set(owned_all) & set(self.free)), "page both owned+free"
        assert not (set(cached_all) & set(self.free)), "page both cached+free"
        assert not (set(owned_all) & set(cached_all)), \
            "page both owned and cached"
        # conservation across tiers: device pages split exactly into
        # free / owned / resident-cached, and the host tier holds exactly
        # the spilled node set (no payload without a node, no spilled node
        # without a payload, never both a page and a payload)
        assert len(self.free) + len(owned_all) + len(cached_all) \
            == self.capacity, "pages leaked or conjured"
        assert self.pages_allocated - self.pages_freed \
            == len(owned_all) + len(cached_all)
        assert self.store.digests() == {n.digest for n in spilled}, \
            "spill store out of sync with spilled registry nodes"
        if not self._pinned:
            assert self.store.over_capacity == 0, \
                "spill store exceeds its capacity with no pinned chains"
        # refcounts == shared-row occurrences, and every shared page is
        # backed by a resident registry node (reclaim requires refcount 0,
        # so a mapped page can never lose its node out from under a sharer)
        refs: dict[int, int] = {}
        for slot, sh in enumerate(self.shared):
            for p in sh:
                refs[p] = refs.get(p, 0) + 1
            assert set(sh) <= set(cached_all), \
                f"slot {slot} shares a page missing from the registry"
        for p in range(self.n_pages):
            assert self.refcount[p] == refs.get(p, 0), \
                f"page {p}: refcount {int(self.refcount[p])} != " \
                f"{refs.get(p, 0)} table occurrences"
        # tree refcount law: every sharer of a child also maps its parent
        # (chains are mapped root-first), so child refcounts sum under the
        # parent's; spilled nodes hold no device page and no sharers
        for n in nodes:
            rc = self.refcount[n.page] if n.resident else 0
            kid_rc = sum(int(self.refcount[c.page])
                         for c in n.children.values() if c.resident)
            assert kid_rc <= rc, \
                f"node {n.digest[:8]}: child refcounts {kid_rc} > {rc}"
            if not n.resident:
                assert n.page is None, "spilled node still holds a page"
        for slot in range(self.n_slots):
            rows = self.shared[slot] + self.owned[slot]
            assert len(self.owned[slot]) <= self.reserved[slot], \
                "allocation > reservation"
            for j, page in enumerate(rows):
                assert self.table[slot, j] == page, "table/rows mismatch"
            assert (self.table[slot, len(rows):] == GARBAGE_PAGE).all(), \
                "table maps unallocated positions"
        assert self.total_reserved <= self.capacity, "pool over-committed"
        # the preempt-free promise: every reserved-but-undrawn page must be
        # coverable by free + evictable pages RIGHT NOW
        unfilled = self.total_reserved - self.total_owned
        assert unfilled <= len(self.free) + self.evictable_pages, \
            "outstanding reservations exceed reclaimable pages"
        # paused (preempted) slots hold NOTHING: their pages were reclaimed
        # at pause time and nothing may creep back before resume re-reserves
        assert self.paused <= set(range(self.n_slots)), "phantom paused slot"
        for slot in sorted(self.paused):
            assert not self.owned[slot] and not self.shared[slot] \
                and not self.reserved[slot], \
                f"paused slot {slot} still holds pages or a reservation"

    def status(self) -> dict:
        return {
            "pages": self.capacity,
            "page_size": self.page_size,
            "in_use": self.in_use,
            "reserved": self.total_reserved,
            "free_unreserved": self.free_unreserved,
            "peak_in_use": self.peak_in_use,
            "cached_pages": self.cached_pages,
            "cached_prefixes": self.radix.node_count,
            "prefix_hits": self.prefix_hits,
            "evictions": self.evictions,
            "cow_copies": self.cow_copies,
            "preemptions": self._c_paused.value,
            "paused_slots": len(self.paused),
            "registry": {
                "nodes": self.radix.node_count,
                "resident_pages": self.cached_pages,
                "spilled_pages": self.spilled_pages,
                "max_depth": self.radix.max_depth,
                "spills": self.spills,
                "restores": self.restores,
                "spill_capacity": self.store.capacity
                if self.spill_enabled else 0,
            },
        }
