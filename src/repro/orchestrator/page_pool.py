"""PagePool: host-side block allocator for the paged KV cache.

The device holds one global pool per layer, ``(n_kv, n_pages, page_size,
hd)``; this class owns the free-list and the per-slot page table that maps
logical positions to physical pages. All bookkeeping is host numpy -- the
only device traffic it generates is the (n_slots, max_pages) int32 table
shipped with each decode dispatch.

Allocation protocol (reservation-based, preempt-free):

  * ``reserve(slot, n)`` at ADMISSION sets aside the request's worst-case
    page count (ceil((prompt + budget + chunk) / page_size)). Admission is
    gated on ``can_reserve`` -- the pool never over-commits, so a running
    request can never fail to get a page mid-decode and nothing is ever
    preempted. Backpressure = the scheduler simply stops admitting.
  * ``alloc_upto(slot, hi)`` is the lazy ALLOC-ON-WRITE: physical pages are
    pulled from the free-list only when decode is about to write position
    ``hi`` (prefill bulk-allocates the prompt's pages the same way). A
    request that exits early (EOS) therefore returns its never-written
    reserved pages without them ever leaving the free-list.
  * ``release(slot)`` at COMPLETION returns owned pages and the remaining
    reservation in one step and resets the table row.

Page 0 is reserved as the *garbage page*: table rows reset to 0, so device
scatters/gathers through free or not-yet-extended slots land on a real page
whose contents are never read unmasked. ``capacity`` excludes it.
"""

from __future__ import annotations

import numpy as np

GARBAGE_PAGE = 0


class PagePool:
    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 max_pages: int):
        if n_pages < 2:
            raise ValueError("PagePool needs >= 2 pages (page 0 is garbage)")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.n_slots = int(n_slots)
        self.max_pages = int(max_pages)
        self.free: list[int] = list(range(1, self.n_pages))
        self.table = np.full((self.n_slots, self.max_pages), GARBAGE_PAGE,
                             np.int32)
        self.owned: list[list[int]] = [[] for _ in range(self.n_slots)]
        self.reserved = np.zeros(self.n_slots, np.int64)
        # accounting (status + the fig7 benchmark)
        self.pages_allocated = 0
        self.pages_freed = 0
        self.peak_in_use = 0

    # -- capacity -----------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Allocatable pages (garbage page excluded)."""
        return self.n_pages - 1

    @property
    def total_reserved(self) -> int:
        return int(self.reserved.sum())

    @property
    def free_unreserved(self) -> int:
        """Pages neither owned nor promised to an admitted request."""
        return self.capacity - self.total_reserved

    def pages_for(self, positions: int) -> int:
        """Pages needed to cover ``positions`` KV positions."""
        return -(-int(positions) // self.page_size)

    def can_reserve(self, n: int) -> bool:
        return n <= self.free_unreserved

    # -- allocation ---------------------------------------------------------
    def reserve(self, slot: int, n: int) -> None:
        if self.reserved[slot] or self.owned[slot]:
            raise RuntimeError(f"slot {slot} already holds a reservation")
        if not self.can_reserve(n):
            raise RuntimeError(
                f"cannot reserve {n} pages: {self.free_unreserved} unreserved")
        self.reserved[slot] = n

    def alloc_upto(self, slot: int, hi: int) -> None:
        """Ensure pages cover logical positions [0, hi] for ``slot``."""
        need = self.pages_for(hi + 1)
        have = len(self.owned[slot])
        if need <= have:
            return
        if need > self.reserved[slot]:
            raise RuntimeError(
                f"slot {slot}: {need} pages exceeds reservation "
                f"{int(self.reserved[slot])}")
        for j in range(have, need):
            page = self.free.pop()
            self.owned[slot].append(page)
            self.table[slot, j] = page
            self.pages_allocated += 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)

    def release(self, slot: int) -> None:
        """Full reclaim: owned pages AND the remaining reservation."""
        pages = self.owned[slot]
        self.free.extend(pages)
        self.pages_freed += len(pages)
        self.owned[slot] = []
        self.reserved[slot] = 0
        self.table[slot, :] = GARBAGE_PAGE

    # -- introspection ------------------------------------------------------
    @property
    def in_use(self) -> int:
        return sum(len(o) for o in self.owned)

    def check(self) -> None:
        """Invariants; raises AssertionError on any violation. Cheap enough
        to call after every operation in tests."""
        owned_all = [p for o in self.owned for p in o]
        assert GARBAGE_PAGE not in owned_all, "garbage page was allocated"
        assert GARBAGE_PAGE not in self.free, "garbage page on free-list"
        assert len(set(owned_all)) == len(owned_all), "page owned twice"
        assert len(set(self.free)) == len(self.free), "free-list duplicate"
        assert not (set(owned_all) & set(self.free)), "page both owned+free"
        assert len(self.free) + len(owned_all) == self.capacity, \
            "pages leaked or conjured"
        assert self.pages_allocated - self.pages_freed == len(owned_all)
        for slot, o in enumerate(self.owned):
            assert len(o) <= self.reserved[slot], "allocation > reservation"
            for j, page in enumerate(o):
                assert self.table[slot, j] == page, "table/owned mismatch"
            assert (self.table[slot, len(o):] == GARBAGE_PAGE).all(), \
                "table maps unallocated positions"
        assert self.total_reserved <= self.capacity, "pool over-committed"

    def status(self) -> dict:
        return {
            "pages": self.capacity,
            "page_size": self.page_size,
            "in_use": self.in_use,
            "reserved": self.total_reserved,
            "free_unreserved": self.free_unreserved,
            "peak_in_use": self.peak_in_use,
        }
