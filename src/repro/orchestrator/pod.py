"""Pod: N Container replicas of one immutable EnvImage, served as a unit.

The kubernetes/docker-compose analog over the repo's docker analog: a Pod
resolves a Registry ref ONCE (so every replica runs the identical image
digest, the paper's reproducibility contract), runs one Container per
replica, and gives each a SlotEngine. Replicas share the Runtime's
CompileCache, so replica 0 pays the trace+lower+compile cost and replicas
1..N-1 deserialize the executable -- the paper's import-problem fix applied
to fleet bring-up.

Pod state is persisted under ``<runtime root>/pods/<pod_id>.json`` so
``repro ps`` can show serving fleets next to containers.
"""

from __future__ import annotations

import json
import os
import uuid
from pathlib import Path

from repro.core.image import EnvImage
from repro.orchestrator.obs.metrics import MetricsRegistry
from repro.orchestrator.obs.tracing import TraceBuffer
from repro.orchestrator.scheduler import SlotEngine


class Pod:
    def __init__(self, runtime, ref, *, replicas: int = 2, n_slots: int = 4,
                 max_len: int = 256, platform: str | None = None,
                 seed: int = 0, eos_id: int | None = None,
                 decode_chunk: int = 4, paged: bool = False,
                 page_size: int = 16, n_pages: int | None = None,
                 prefix_cache: bool = False,
                 spill_pages: int | None = 0,
                 pod_id: str | None = None):
        if replicas < 1:
            raise ValueError("a Pod needs at least one replica")
        self.runtime = runtime
        self.ref = ref if isinstance(ref, str) else None
        self.image: EnvImage = (ref if isinstance(ref, EnvImage)
                                else runtime.pull(ref))
        self.platform = platform
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.seed = int(seed)
        self.eos_id = eos_id
        self.decode_chunk = int(decode_chunk)
        # paged KV: every replica gets its own page pool of ``n_pages``
        # (None -> the HBM of a contiguous (n_slots, max_len) bank) and
        # max_len becomes the page-table span, not a memory reservation
        self.paged = bool(paged)
        self.page_size = int(page_size)
        self.n_pages = n_pages
        # copy-on-write prefix page sharing (paged only): each replica's
        # pool keeps a radix tree of shared prompt-prefix page blocks
        self.prefix_cache = bool(prefix_cache)
        # host-RAM spill tier for evicted prefix nodes: 0 disables (evict
        # outright), None is an unbounded store, >0 caps the store's pages
        self.spill_pages = spill_pages
        # callers may pin the id: the fabric assigns deterministic ids
        # (pod-0, pod-1, ...) so the consistent-hash ring and state files
        # are reproducible across worker processes and restarts
        self.pod_id = pod_id or f"pod-{uuid.uuid4().hex[:8]}"
        # one metrics registry + one span ring buffer per pod, shared by
        # every replica engine (labels keep the per-replica breakdown);
        # snapshots ride the state file so `ps`/`top` read live numbers
        self.metrics = MetricsRegistry()
        self.trace = TraceBuffer(name=self.pod_id)
        # pod-lifetime rejection counter, incremented by whichever scheduler
        # fronts this pod (a burst of rejections is a served-badly signal
        # `repro ps` must show even when no slot occupancy changed)
        self.rejected = 0
        # pod-lifetime QoS shed counter (admission-deadline misses charged
        # to this pod; router-tier overload sheds are counted at the router)
        self.shed = 0
        # router tier membership: PodRouter stamps its id here so `ps` can
        # read a fleet as one unit; None = standalone pod
        self.router: str | None = None
        self._params: dict[str, object] = {}   # image digest -> shared tree
        self.engines: list[SlotEngine] = [
            self.make_engine(self.image, i) for i in range(replicas)]
        self.retired: list[SlotEngine] = []
        self.write_state()

    def make_engine(self, image: EnvImage, index: int) -> SlotEngine:
        """One replica: container + slot engine over SHARED params.

        One logical checkpoint served N ways: the params tree is
        materialized once per image generation and shared by every replica
        (engines never mutate it), and the compiled steps come warm out of
        the shared CompileCache after the first replica."""
        c = self.runtime.run(image, platform=self.platform)
        params = self._params.get(image.digest)
        if params is None:
            params = self._params[image.digest] = c.init_params(self.seed)
        return SlotEngine(c, params, n_slots=self.n_slots,
                          max_len=self.max_len, eos_id=self.eos_id,
                          name=f"{self.pod_id}/r{index}",
                          decode_chunk=self.decode_chunk,
                          paged=self.paged, page_size=self.page_size,
                          n_pages=self.n_pages,
                          prefix_cache=self.prefix_cache,
                          spill_pages=self.spill_pages,
                          metrics=self.metrics, trace=self.trace)

    def drop_params(self, image_digest: str) -> None:
        """Release a retired generation's shared params (deployer calls
        this after the last blue replica of that image is swapped out)."""
        self._params.pop(image_digest, None)

    # -- capacity -----------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Admissible slot count. Draining/stopped replicas are excluded
        from BOTH capacity and free_slots: during a blue/green rollover a
        draining replica can take no new work, so counting its slots as
        capacity while free_slots reports 0 made `repro ps` overstate
        headroom by a full replica."""
        return sum(e.n_slots for e in self.engines
                   if not (e.draining or e.stopped))

    @property
    def free_slots(self) -> int:
        return sum(len(e.free) for e in self.engines if e.has_free())

    # -- state --------------------------------------------------------------
    def status(self) -> dict:
        return {
            "pod": self.pod_id,
            "ref": self.ref,
            "image": self.image.short_digest,
            "capacity": self.capacity,
            "free_slots": self.free_slots,
            "rejected": self.rejected,
            "shed": self.shed,
            "router": self.router,
            "phase": ("serving" if any(e.active for e in self.engines)
                      else "idle"),
            "pid": os.getpid(),     # lets `ps` tell live fleets from dead
            "replicas": [e.status() for e in self.engines],
            "metrics": self.metrics.snapshot(),
            "trace": self.trace.status(),
        }

    def write_state(self, final: bool = False) -> Path:
        """Persist status; ``final=True`` stamps a terminal phase so ``ps``
        never misreports the pod after OS pid reuse."""
        d = Path(self.runtime.root) / "pods"
        d.mkdir(parents=True, exist_ok=True)
        p = d / f"{self.pod_id}.json"
        status = self.status()
        if final:
            status["phase"] = "exited"
        # atomic: state refreshes every scheduler tick and a concurrent
        # `repro ps` must never see a half-written file
        tmp = p.with_suffix(".tmp")
        tmp.write_text(json.dumps(status, indent=2))
        os.replace(tmp, p)
        return p
