"""Router tier: one submit()/step()/run() surface over N Pods.

One Pod is one host's replicas; the paper's fleet story (Benedicic et al.'s
Shifter deployments, the HPE adaptive-containerization survey) needs many.
``PodRouter`` fronts N Pods -- each with its own ``ContinuousScheduler``
and ``RequestQueue`` -- behind the same interface a single scheduler
exposes, so drivers, benchmarks and the deployer scale from one pod to a
fleet without changing shape.

Placement is pluggable:

* ``shortest-queue`` (default): route to the pod with the least
  outstanding decode work (committed tokens not yet finished), tie-broken
  by pod order -- load-aware, keeps the fleet evenly packed.
* ``consistent-hash``: hash the request id onto a static ring of virtual
  nodes (session affinity). The ring never mutates: draining a pod just
  makes the walk skip it, so ONLY the drained pod's keys move (to their
  ring successors) and they return home when it un-drains.
* ``prefix-hash``: same ring, but the key is the request's prefix FAMILY
  anchor. The declared prefix is chunked into the same chained block
  digests the radix registry (``PrefixRadix``) uses; the router keeps a
  digest -> anchor map and routes on the deepest already-seen ancestor,
  so "system prompt" and "system prompt + few-shot variant k" all hash
  to one pod and share ancestor pages there instead of scattering
  per-variant. Falls back to the legacy whole-prefix digest
  (``GenRequest.prefix_digest``), then to the rid hash. Draining behaves
  like consistent-hash: a drained pod's anchors move to the ring
  successor, whose registry re-materializes the family on first miss,
  and they return home on undrain.

Both policies spill before they reject: if no engine in the preferred pod
can EVER fit a request (slab / page-table span / pool / frontend
mismatch), the router walks the remaining preference order and re-routes
-- draining pods included, as a last resort, so a request feasible only
on a pod that is transiently draining waits for it instead of dying. A
request is rejected only when EVERY pod agrees it is infeasible, with the
reasons aggregated across the fleet.

Draining a pod at the router (``drain_pod``) is the fleet-deployer hook:
new traffic routes around it, its queued + in-flight work finishes, and
fleet ``capacity`` drops by exactly that pod -- never below N-1 pods
during a rolling upgrade.

Router state persists next to pod state (``<root>/pods/<router_id>.json``,
``"kind": "router"``) so ``repro ps`` reads a fleet as one unit.
"""

from __future__ import annotations

import bisect
import hashlib
import json
import os
import uuid
from pathlib import Path
from typing import Iterable

from repro.orchestrator.obs.metrics import MetricsRegistry, merge_snapshots
from repro.orchestrator.obs.tracing import TraceBuffer
from repro.orchestrator.pod import Pod
from repro.orchestrator.prefix_registry import block_digests
from repro.orchestrator.request_queue import GenRequest
from repro.orchestrator.scheduler import ContinuousScheduler

PLACEMENT_POLICIES = ("shortest-queue", "consistent-hash", "prefix-hash")


def _hash64(key: str) -> int:
    # md5, not hash(): placement must be stable across processes (PYTHONHASHSEED)
    return int.from_bytes(hashlib.md5(key.encode()).digest()[:8], "big")


class PodRouter:
    STATE_EVERY = 8     # min ticks between router-state file refreshes

    def __init__(self, pods: Iterable[Pod], *,
                 policy: str = "shortest-queue", fairness_cap: int = 4,
                 vnodes: int = 64, shed_queue_depth: int | None = None,
                 shed_ttft_p99: int | None = None):
        self.pods: list[Pod] = list(pods)
        if not self.pods:
            raise ValueError("a PodRouter needs at least one pod")
        if policy not in PLACEMENT_POLICIES:
            raise ValueError(
                f"unknown placement policy {policy!r}; "
                f"choose from {PLACEMENT_POLICIES}")
        self.policy = policy
        self.router_id = f"router-{uuid.uuid4().hex[:8]}"
        self.runtime = self.pods[0].runtime
        # one scheduler+queue per pod: admission stays FIFO *within* a pod
        # (a pod's admission order is a subsequence of router submission
        # order), and pods tick independently -- the cross-host layout
        self.schedulers: list[ContinuousScheduler] = [
            ContinuousScheduler(p, fairness_cap=fairness_cap)
            for p in self.pods]
        self._sched = {p.pod_id: s
                       for p, s in zip(self.pods, self.schedulers)}
        # static hash ring: vnodes points per pod so key movement on drain
        # is ~1/N even with few pods
        ring = [(_hash64(f"{p.pod_id}#{v}"), p)
                for p in self.pods for v in range(vnodes)]
        self._ring = sorted(ring, key=lambda t: t[0])
        self._ring_keys = [h for h, _ in self._ring]
        self._draining: set[str] = set()
        self.tick = 0
        self._state_tick = -self.STATE_EVERY
        self.completed: list[GenRequest] = []
        self.rejected: list[GenRequest] = []    # router-level (no pod fits)
        self.shedded: list[GenRequest] = []     # QoS overload sheds
        # SLO shedding policy, driven by the LIVE registry: a pod is
        # overloaded when its queue_depth gauge or its merged ttft_ticks
        # p99 crosses the threshold. Batch submissions that only have
        # overloaded pods to land on are shed with a typed rejection
        # instead of enqueued to stall; interactive traffic is never shed
        # here. None disables that dimension (default: no shedding).
        self.shed_queue_depth = shed_queue_depth
        self.shed_ttft_p99 = shed_ttft_p99
        # router-tier observability: placement counters labelled by policy
        # (status renders them as "by_policy"), plus a span buffer for
        # route/reject events. ``requests_rejected`` mirrors the pod-level
        # counter name so the fleet rollup and the span-log recompute agree
        # on one total.
        self.metrics = MetricsRegistry()
        self.trace = TraceBuffer(name=self.router_id)
        self._c_routed = self.metrics.counter("routed", policy=policy)
        self._c_spilled = self.metrics.counter("spillover", policy=policy)
        self._c_rejected = self.metrics.counter("rejected", policy=policy)
        self._c_req_rejected = self.metrics.counter("requests_rejected")
        self._c_shed = self.metrics.counter("shed", policy=policy)
        self._c_req_shed = self.metrics.counter("requests_shed")
        # prefix-hash family anchors: chained block digest -> the digest
        # the whole FAMILY routes on. The radix registry shares ancestor
        # pages across prefix variants, so per-variant digests must not
        # scatter a family across pods -- every chain member maps to the
        # anchor of the first family it overlaps (deepest registered
        # ancestor at first sight). Grows with distinct prefix blocks seen;
        # host-side bookkeeping only.
        self._family_anchor: dict[str, str] = {}
        self._page_size = next(
            (e.page_size for p in self.pods for e in p.engines
             if getattr(e, "paged", False)), None)
        # incremental outstanding-work ledger (tokens committed, not yet
        # finished) so shortest-queue placement is O(P log P) per request
        # instead of rescanning every queue and slot bank
        self._outstanding = {p.pod_id: 0 for p in self.pods}
        self._rejected_seen = [0] * len(self.schedulers)
        self._shedded_seen = [0] * len(self.schedulers)
        for p in self.pods:
            p.router = self.router_id
            p.write_state()
        self.write_state()

    # registry-backed shims for the pre-registry attribute names
    @property
    def routed(self) -> int:
        return self._c_routed.value

    @property
    def spilled(self) -> int:
        return self._c_spilled.value

    def trace_buffers(self) -> list[TraceBuffer]:
        """Every span buffer in the fleet (router first, then pods) --
        what ``export_chrome`` and the report decomposition consume."""
        return [self.trace] + [p.trace for p in self.pods]

    # -- placement -----------------------------------------------------------
    def is_draining(self, pod: Pod) -> bool:
        return pod.pod_id in self._draining

    def load(self, pod: Pod) -> int:
        """Shortest-queue metric: outstanding decode WORK committed to the
        pod, in tokens (budgets routed there and not yet finished). A plain
        request count is blind to budgets (a trace whose long requests
        correlate with submit order then piles every long request onto one
        pod); weighting by tokens keeps the backlog balanced. Maintained
        incrementally -- credited at routing, debited at completion/
        rejection -- so placement never rescans queues or slot banks."""
        return self._outstanding[pod.pod_id]

    def scheduler_for(self, pod: Pod) -> ContinuousScheduler:
        return self._sched[pod.pod_id]

    def _prefix_key(self, req: GenRequest) -> str:
        """Ring key for prefix-hash placement: the DEEPEST already-seen
        ancestor's family anchor. A request's declared prefix is chunked
        into chained block digests (the same addressing the radix registry
        uses); if any of them was seen before, the request adopts that
        family's anchor -- so "system prompt" and "system prompt +
        few-shot" land on the same pod and the radix can share the
        ancestor pages. A brand-new family anchors on its own deepest
        digest. Requests with no usable prefix fall back to the legacy
        whole-prefix digest, then to rid session affinity."""
        chain: list[str] = []
        if self._page_size is not None and req.prefix_len \
                and req.frontend is None:
            cap = min(req.prefix_len, req.prompt_len - 1)
            if cap >= 1:
                chain = block_digests(req.prompt[:cap], self._page_size)
        if not chain:
            return (f"px:{req.prefix_digest}" if req.prefix_digest
                    else f"rid:{req.rid}")
        anchor = None
        for d in reversed(chain):
            a = self._family_anchor.get(d)
            if a is not None:
                anchor = a
                break
        if anchor is None:
            anchor = chain[-1]
        for d in chain:
            self._family_anchor.setdefault(d, anchor)
        return f"px:{anchor}"

    def _candidates(self, req: GenRequest) -> list[Pod]:
        """Every pod in placement-preference order for ``req``: live pods
        by policy first, draining pods as a LAST resort -- a request
        feasible only on a pod that is transiently draining (a rolling
        upgrade) waits in its queue rather than being terminally rejected.
        The first entry is the policy's choice; the rest spill over."""
        if self.policy in ("consistent-hash", "prefix-hash"):
            # prefix-hash: place on the request's FAMILY ANCHOR digest so
            # every prefix variant sharing any radix ancestor walks to the
            # pod whose pool holds those chain pages; digest-less requests
            # degrade to plain rid session affinity
            key = (self._prefix_key(req) if self.policy == "prefix-hash"
                   else f"rid:{req.rid}")
            i = bisect.bisect_right(self._ring_keys, _hash64(key))
            order, seen = [], set()
            for k in range(len(self._ring)):
                p = self._ring[(i + k) % len(self._ring)][1]
                if p.pod_id not in seen:
                    seen.add(p.pod_id)
                    order.append(p)
                    if len(order) == len(self.pods):
                        break
        else:
            order = sorted(self.pods, key=lambda p: (self.load(p),
                                                     self.pods.index(p)))
        return ([p for p in order if p.pod_id not in self._draining]
                + [p for p in order if p.pod_id in self._draining])

    def _first_fit(self, req: GenRequest, order: list[Pod]) -> Pod | None:
        return next(
            (p for p in order if any(e.fits(req) for e in p.engines)), None)

    def overloaded(self, pod: Pod) -> bool:
        """The shedding policy's overload read, straight off the pod's
        live registry: the ``queue_depth`` gauge (set by its scheduler on
        every submit and tick) or the merged ``ttft_ticks`` p99 over the
        configured threshold. False when no threshold is set."""
        if (self.shed_queue_depth is not None
                and pod.metrics.gauge("queue_depth").value
                >= self.shed_queue_depth):
            return True
        if self.shed_ttft_p99 is not None:
            h = pod.metrics.merged_histogram("ttft_ticks")
            if h is not None and h.count \
                    and h.percentile(99) >= self.shed_ttft_p99:
                return True
        return False

    def _shed(self, req: GenRequest) -> None:
        """Typed shed rejection at the router tier: every pod that could
        fit this batch request is over the overload threshold."""
        req.state, req.finish_reason = "shed", "shed"
        req.error = ("shed: fleet overloaded (queue_depth >= "
                     f"{self.shed_queue_depth}, ttft p99 >= "
                     f"{self.shed_ttft_p99})")
        req.done_tick = self.tick
        self.shedded.append(req)
        self._c_shed.inc()
        self._c_req_shed.inc()
        self.trace.record(req.rid, "shed", self.tick, reason="overload",
                          priority=req.priority, policy=self.policy)

    def place(self, req: GenRequest) -> Pod | None:
        """The pod ``req`` would route to right now (spillover applied);
        None if no pod can ever fit it. Pure query -- no submission."""
        return self._first_fit(req, self._candidates(req))

    def submit(self, reqs: Iterable[GenRequest] | GenRequest) -> None:
        if isinstance(reqs, GenRequest):
            reqs = [reqs]
        refresh_before = len(self.rejected) + len(self.shedded)
        shedding = (self.shed_queue_depth is not None
                    or self.shed_ttft_p99 is not None)
        for req in reqs:
            order = self._candidates(req)
            chosen = self._first_fit(req, order)
            if (chosen is not None and shedding
                    and req.priority == "batch"):
                # overload-spill before shed: a batch request prefers the
                # policy's pod but takes any fitting non-overloaded pod
                # over stalling; only when EVERY fitting pod is over the
                # threshold is it shed. Interactive traffic bypasses this
                # entirely -- the lanes + preemption downstream protect it.
                under = next(
                    (p for p in order
                     if any(e.fits(req) for e in p.engines)
                     and not self.overloaded(p)), None)
                if under is None:
                    self._shed(req)
                    continue
                chosen = under
            if chosen is None:
                # EVERY pod agrees (draining ones included): infeasible
                # fleet-wide. Reject at the router -- never enqueue a
                # request that can only stall -- with the per-engine
                # reasons aggregated across pods.
                req.state, req.finish_reason = "rejected", "oversized"
                reasons = sorted({e.reject_reason(req)
                                  for p in order for e in p.engines})
                req.error = ("; ".join(reasons) if reasons
                             else "router has no pods")
                req.done_tick = self.tick
                self.rejected.append(req)
                self._c_rejected.inc()
                self._c_req_rejected.inc()
                self.trace.record(req.rid, "reject", self.tick,
                                  reason="infeasible", policy=self.policy)
                continue
            req.spilled = chosen is not order[0]
            if req.spilled:
                self._c_spilled.inc()
            req.pod = chosen.pod_id
            self._c_routed.inc()
            # router-tier spans live in the ROUTER's buffer: recording the
            # route into the chosen pod's buffer meant a dying pod took the
            # placement record down with it and fleet-wide span closure
            # could no longer prove the request was ever routed
            self.trace.record(req.rid, "route", self.tick,
                              pod=chosen.pod_id, policy=self.policy,
                              spilled=req.spilled)
            self._outstanding[chosen.pod_id] += req.max_new_tokens
            self._sched[chosen.pod_id].submit(req)
        if len(self.rejected) + len(self.shedded) != refresh_before:
            # router-level rejections and sheds happen BETWEEN ticks
            # (submit time), so the step() throttle would never see them:
            # one refresh per rejecting submit batch keeps `repro ps` honest
            self.write_state()

    # -- drain control (the fleet-deployer hook) -----------------------------
    def drain_pod(self, pod: Pod) -> None:
        """Route new traffic around ``pod``. Already-queued and in-flight
        requests on it still run to completion via its own scheduler."""
        self._draining.add(pod.pod_id)
        self.write_state()

    def undrain_pod(self, pod: Pod) -> None:
        self._draining.discard(pod.pod_id)
        self.write_state()

    # -- the global tick -----------------------------------------------------
    def step(self) -> list[GenRequest]:
        """One fleet tick: every pod's scheduler advances once. Pods are
        independent hosts -- a tick is the lockstep abstraction of them
        decoding concurrently, so fleet throughput is tokens per ROUTER
        tick (what fig8 measures)."""
        done: list[GenRequest] = []
        rejected = admitted = 0
        for i, s in enumerate(self.schedulers):
            adm0 = s.queue.admitted
            done.extend(s.step())
            admitted += s.queue.admitted - adm0
            # debit post-placement scheduler rejections from the ledger
            # (rare: geometry changed under a routed request, e.g. upgrade)
            for req in s.rejected[self._rejected_seen[i]:]:
                if req.pod in self._outstanding:
                    self._outstanding[req.pod] -= req.max_new_tokens
                rejected += 1
            self._rejected_seen[i] = len(s.rejected)
            # deadline sheds terminate a routed request at the SCHEDULER
            # tier just like rejections do -- without this debit a shed
            # burst permanently over-counts the pod and shortest-queue
            # placement routes around it forever
            for req in s.shedded[self._shedded_seen[i]:]:
                if req.pod in self._outstanding:
                    self._outstanding[req.pod] -= req.max_new_tokens
                rejected += 1
            self._shedded_seen[i] = len(s.shedded)
        for req in done:
            # guard: a request submitted to a member scheduler directly
            # (bypassing the router) was never credited to the ledger
            if req.pod in self._outstanding:
                self._outstanding[req.pod] -= req.max_new_tokens
        self.completed.extend(done)
        self.tick += 1
        # same refresh rule the pod scheduler follows (admissions count:
        # a saturated fleet must not read as idle in `repro ps`)
        if (done or admitted or rejected) and (
                self.tick - self._state_tick >= self.STATE_EVERY):
            self.write_state()
            self._state_tick = self.tick
        return done

    @property
    def busy(self) -> bool:
        return any(s.busy for s in self.schedulers)

    def run(self, max_ticks: int | None = None) -> list[GenRequest]:
        start = self.tick
        while self.busy:
            if max_ticks is not None and self.tick - start >= max_ticks:
                break
            self.step()
        # final snapshots for the router AND every member pod: step() calls
        # the schedulers' step directly, so nothing else flushes a pod's
        # state after its last throttled write
        self.write_state()
        for p in self.pods:
            p.write_state()
        return self.completed

    # -- fleet accounting ----------------------------------------------------
    @property
    def capacity(self) -> int:
        """Admissible slots fleet-wide; drained pods contribute nothing
        (the N-1 invariant the deployer tests pin)."""
        return sum(p.capacity for p in self.pods
                   if p.pod_id not in self._draining)

    @property
    def free_slots(self) -> int:
        return sum(p.free_slots for p in self.pods
                   if p.pod_id not in self._draining)

    @property
    def pending(self) -> int:
        return sum(s.queue.pending for s in self.schedulers)

    @property
    def rejected_total(self) -> int:
        """Router-level (no pod fits at placement) + per-pod scheduler
        rejections (post-placement geometry changes, e.g. an upgrade)."""
        return (len(self.rejected)
                + sum(len(s.rejected) for s in self.schedulers))

    @property
    def shed_total(self) -> int:
        """Router-tier overload sheds + per-pod admission-deadline sheds."""
        return (len(self.shedded)
                + sum(len(s.shedded) for s in self.schedulers))

    def status(self) -> dict:
        return {
            "kind": "router",
            "router": self.router_id,
            "policy": self.policy,
            "pods": [p.pod_id for p in self.pods],
            "draining": sorted(self._draining),
            "capacity": self.capacity,
            "free_slots": self.free_slots,
            "pending": self.pending,
            "routed": self.routed,
            "spilled": self.spilled,
            "completed": len(self.completed),
            "rejected": self.rejected_total,
            "shed": self.shed_total,
            "shed_thresholds": {"queue_depth": self.shed_queue_depth,
                                "ttft_p99": self.shed_ttft_p99},
            "by_policy": {self.policy: {
                "routed": self._c_routed.value,
                "spillover": self._c_spilled.value,
                "rejected": self._c_rejected.value,
                "shed": self._c_shed.value,
            }},
            "metrics": merge_snapshots(
                [self.metrics.snapshot()]
                + [p.metrics.snapshot() for p in self.pods]),
            "trace": self.trace.status(),
            "pid": os.getpid(),
            "members": [{
                "pod": p.pod_id,
                "image": p.image.short_digest,
                "capacity": p.capacity,
                "free_slots": p.free_slots,
                "pending": self._sched[p.pod_id].queue.pending,
                "active": sum(len(e.active) for e in p.engines),
                "rejected": p.rejected,
                "shed": p.shed,
                "overloaded": self.overloaded(p),
                "draining": p.pod_id in self._draining,
            } for p in self.pods],
        }

    def write_state(self, final: bool = False) -> Path:
        """Same dir + atomic protocol as ``Pod.write_state`` so ``repro
        ps`` discovers routers and pods in one glob."""
        d = Path(self.runtime.root) / "pods"
        d.mkdir(parents=True, exist_ok=True)
        p = d / f"{self.router_id}.json"
        status = self.status()
        status["phase"] = ("exited" if final
                          else "serving" if any(
                              e.active for pod in self.pods
                              for e in pod.engines)
                          else "idle")
        if final:
            for pod in self.pods:
                pod.write_state(final=True)
        tmp = p.with_suffix(".tmp")
        tmp.write_text(json.dumps(status, indent=2))
        os.replace(tmp, p)
        return p
