"""Continuous batching: slot engines + the interleaved prefill/decode loop.

``SlotEngine`` owns one Container replica's serving state: a bank of
``n_slots`` KV-cache slots (one in-flight request per slot, free slots on a
free-list), compiled prefill/decode executables (via the Container's
CompileCache -- replicas after the first warm-start), and per-slot host
bookkeeping (position, last token, owning request).

``ContinuousScheduler`` drives a Pod of engines: each global *tick* first
admits queued requests FIFO into free slots (bounded by ``fairness_cap``
prefills per tick so admission never starves decode), then runs ONE decode
step per engine in which every active slot advances by one token at its own
depth. Requests exit early on EOS or their token budget; their slot returns
to the free-list the same tick and can be refilled on the next -- the
Orca-style iteration-level scheduling loop.
"""

from __future__ import annotations

import time
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.orchestrator.obs.metrics import MetricsRegistry
from repro.orchestrator.obs.report import (ITL_HIST, TICK_HIST,
                                           observe_completion)
from repro.orchestrator.obs.tracing import TraceBuffer
from repro.orchestrator.page_pool import PagePool
from repro.orchestrator.prefix_registry import PrefixMatch
from repro.orchestrator.request_queue import GenRequest, RequestQueue

_PREFILL_BUCKETS = (16, 32, 64, 128, 256, 512, 1024, 2048)


def _insert_slot(big, small, slot):
    """Write one request's (batch=1) cache into row ``slot`` of the bank."""
    def leaf(b, s):
        starts = (jnp.int32(0), slot) + (jnp.int32(0),) * (b.ndim - 2)
        return jax.lax.dynamic_update_slice(b, s.astype(b.dtype), starts)
    return jax.tree.map(leaf, big, small)


def _insert_pages(big, small, row):
    """Scatter one request's page-major prefill cache into the pool.

    ``small`` leaves: (count, n_kv, n_prompt_pages, ps, hd);
    ``row``: (n_prompt_pages,) physical page ids for the slot. Entries past
    the allocated prefix are the garbage page 0 -- the prompt's right-pad
    pages land there and are never read unmasked."""
    def leaf(b, s):
        return b.at[:, :, row].set(s.astype(b.dtype))
    return jax.tree.map(leaf, big, small)


def _gather_pages(big, rows):
    """Copy the pool pages at ``rows`` OUT of the live cache (the spill
    save path). Read-only: the cache is not donated -- the caller syncs the
    result to host and the buffer stays live for the next dispatch."""
    def leaf(b):
        return jnp.take(b, rows, axis=2)
    return jax.tree.map(leaf, big)


# jitted ONCE at module level: jax's trace cache keys on function identity,
# so a per-engine jit wrapper would re-trace the full-cache update for every
# replica and every blue/green rollover
_insert_slot_jit = jax.jit(_insert_slot, donate_argnums=0)
_insert_pages_jit = jax.jit(_insert_pages, donate_argnums=0)
_gather_pages_jit = jax.jit(_gather_pages)


class SlotEngine:
    def __init__(self, container, params, *, n_slots: int, max_len: int,
                 eos_id: int | None = None, name: str | None = None,
                 decode_chunk: int = 4, paged: bool = False,
                 page_size: int = 16, n_pages: int | None = None,
                 prefix_cache: bool = False,
                 spill_pages: int | None = 0,
                 metrics: MetricsRegistry | None = None,
                 trace: TraceBuffer | None = None):
        self.container = container
        self.params = params
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.eos_id = eos_id
        self.name = name or container.container_id
        self.chunk = max(1, int(decode_chunk))
        self.paged = bool(paged)
        # copy-on-write prefix page cache: requests declaring a shared
        # leading token block (GenRequest.prefix_len) reuse each other's
        # prefix KV pages instead of re-prefilling them
        self.prefix_cache = bool(prefix_cache)
        if self.prefix_cache and not self.paged:
            raise ValueError("prefix_cache requires paged=True "
                             "(prefix sharing is page-granular)")

        # ring-buffer (windowed) and recurrent caches are not right-pad safe
        # (see ServeStepBuilder.build_prefill_slot): use exact-length prefill
        kinds = {k for st in container.model.stages for k in st.unit}
        cfg = container.arch
        # frontend-embedding archs (musicgen/internvl2): every prefill
        # executable carries a static (1, fe_len, d_model) prefix buffer;
        # requests supply up to fe_len real rows (packed ahead of the prompt)
        self.fe_len = cfg.frontend_len if cfg.frontend else 0
        self.d_model = cfg.d_model
        self.fe_dtype = container.cache_dtype
        self.exact_prefill = bool(
            kinds & {"ssm", "rec", "local"}
            or (cfg.window and cfg.attn_kind == "local"))

        # observability: the owning Pod shares its registry + span buffer
        # across replicas; a standalone engine (unit test, single-replica
        # benchmark) gets private ones
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.trace = trace if trace is not None else TraceBuffer(name=self.name)

        if self.paged:
            if self.exact_prefill:
                raise NotImplementedError(
                    "paged KV serving supports full-attention archs only "
                    "(windowed/recurrent caches stay contiguous)")
            self.page_size = int(page_size)
            # max_len becomes the page-TABLE span (per-request position
            # ceiling), decoupled from per-slot memory: pages are the budget
            self.max_pages = -(-self.max_len // self.page_size)
            # default pool = the HBM a contiguous bank of the same
            # (n_slots, max_len) geometry would pin, + the garbage page
            self.n_pages = int(n_pages) if n_pages else (
                self.n_slots * self.max_pages + 1)
            self.pool = PagePool(self.n_pages, self.page_size,
                                 self.n_slots, self.max_pages,
                                 metrics=self.metrics, replica=self.name,
                                 spill_pages=spill_pages)
            shapes = dict(batch=self.n_slots, n_pages=self.n_pages,
                          page_size=self.page_size, max_pages=self.max_pages)
            one_kind, chunk_kind = "decode_slots_paged", "decode_chunk_paged"
        else:
            self.pool = None
            shapes = dict(batch=self.n_slots, cache_len=self.max_len)
            one_kind, chunk_kind = "decode_slots", "decode_chunk"
        if self.chunk == 1:
            # single-tick primitive: same semantics, no scan wrapper
            # (*extra = the page table in paged mode, nothing otherwise)
            one = container.compile_serve_step(one_kind, **shapes)

            def decode(params, cache, toks, pos, *extra):
                nxt, cache = one(params, cache, toks, pos, *extra)
                return nxt[:, None], nxt[:, None], pos + 1, cache

            self.decode = decode
        else:
            self.decode = container.compile_serve_step(
                chunk_kind, gen_steps=self.chunk, **shapes)
        self._prefills: dict[int, object] = {}      # bucket len -> executable
        self._insert = _insert_slot_jit

        self.cache = (container.init_paged_cache(self.n_pages, self.page_size)
                      if self.paged
                      else container.init_slot_cache(self.n_slots, self.max_len))
        if self.paged:
            # device side of the registry's spill tier: the pool calls
            # these to move page contents pool <-> host RAM. Both run
            # BEFORE any dispatch that donates the cache (the engine
            # sequences pool bookkeeping ahead of prefill/decode).
            self.pool.set_spill_io(self._spill_save, self._spill_load)
        self.pos = np.zeros(self.n_slots, np.int32)
        self.cur_tok = np.zeros(self.n_slots, np.int32)
        self.free: list[int] = list(range(self.n_slots))
        self.active: dict[int, GenRequest] = {}
        self.draining = False
        self.stopped = False

        # accounting (for ps/status + the fig6/fig9 benchmarks): tick-clocked
        # counts live in the shared registry, labelled per replica; the old
        # attribute names survive below as read-only property shims. Wall
        # timings (prefill_s/decode_s) stay plain attributes ON PURPOSE --
        # the registry must snapshot bitwise-identically for identical
        # request traces, so wall-clock state never enters it.
        lab = dict(replica=self.name)
        self._c_slots_alloc = self.metrics.counter("slots_allocated", **lab)
        self._c_slots_freed = self.metrics.counter("slots_freed", **lab)
        self._c_decode_ticks = self.metrics.counter("decode_ticks", **lab)
        self._c_tokens = self.metrics.counter("tokens_generated", **lab)
        self._c_positions = self.metrics.counter("prefill_positions", **lab)
        self._c_phits = self.metrics.counter("prefix_hits", **lab)
        self._c_pmiss = self.metrics.counter("prefix_misses", **lab)
        self._c_psaved = self.metrics.counter("prefix_tokens_saved", **lab)
        # radix-registry hit taxonomy: ANCESTOR hits matched fewer complete
        # blocks than the request declared (sharing a shorter family
        # prefix), PARTIAL hits matched only a mid-block boundary (the
        # front-partial merge with no whole shared row)
        self._c_pancestor = self.metrics.counter("prefix_ancestor_hits",
                                                 **lab)
        self._c_ppartial = self.metrics.counter("prefix_partial_hits", **lab)
        # decode-chunk overshoot discards (bounded, counted waste): the
        # visible cost signal for decode_chunk tuning
        self._c_wasted = self.metrics.counter("tokens_wasted", **lab)
        self._c_prefill_disp = self.metrics.counter("prefill_dispatches",
                                                    **lab)
        self._c_decode_disp = self.metrics.counter("decode_dispatches", **lab)
        # page-level preemption: pauses (pages released mid-decode) and
        # resumes (suffix re-prefill of prompt + generated-so-far)
        self._c_preempted = self.metrics.counter("preemptions", **lab)
        self._c_resumed = self.metrics.counter("resumes", **lab)
        self.prefill_s = 0.0
        self.decode_s = 0.0

    # registry-backed shims for the pre-registry attribute names
    @property
    def slots_allocated(self) -> int:
        return self._c_slots_alloc.value

    @property
    def slots_freed(self) -> int:
        return self._c_slots_freed.value

    @property
    def decode_ticks(self) -> int:
        return self._c_decode_ticks.value

    @property
    def tokens_generated(self) -> int:
        return self._c_tokens.value

    @property
    def prefill_positions(self) -> int:
        return self._c_positions.value

    @property
    def prefix_hits(self) -> int:
        return self._c_phits.value

    @property
    def prefix_misses(self) -> int:
        return self._c_pmiss.value

    @property
    def prefix_tokens_saved(self) -> int:
        return self._c_psaved.value

    @property
    def prefix_ancestor_hits(self) -> int:
        return self._c_pancestor.value

    @property
    def prefix_partial_hits(self) -> int:
        return self._c_ppartial.value

    @property
    def tokens_wasted(self) -> int:
        return self._c_wasted.value

    @property
    def preemptions(self) -> int:
        return self._c_preempted.value

    @property
    def resumes(self) -> int:
        return self._c_resumed.value

    # -- admission ----------------------------------------------------------
    def has_free(self) -> bool:
        return bool(self.free) and not (self.draining or self.stopped)

    def supports(self, req: GenRequest) -> bool:
        """Arch compatibility: a frontend prefix needs a frontend arch with
        a wide-enough prefix buffer and a matching embedding width."""
        if req.frontend is None:
            return True
        return (req.frontend_len <= self.fe_len
                and req.frontend.shape[1] == self.d_model)

    def span(self, req: GenRequest) -> int:
        """KV positions the request occupies on THIS engine: the STATIC
        frontend-buffer width (not the request's own prefix length) because
        the prefill executable's cache covers fe_len + bucket rows no
        matter how many prefix rows are real."""
        return self.fe_len + req.prompt_len + req.max_new_tokens

    def pages_needed(self, req: GenRequest) -> int:
        """Worst-case page footprint: chunked decode can write up to
        ``chunk`` positions past the final token (overshoot discard)."""
        return self.pool.pages_for(self.span(req) + self.chunk)

    def fits(self, req: GenRequest) -> bool:
        """Permanent feasibility: could this request EVER run here?

        ``max_len`` is the authoritative per-request span in BOTH modes
        (the page table rounds it up to whole pages, but prefill buckets
        clamp at max_len, so admitting into the rounding slack would
        crash prefill); paged mode additionally needs the footprint to
        fit the pool."""
        if not self.supports(req):
            return False
        if self.span(req) + self.chunk > self.max_len:
            return False
        return (not self.paged
                or self.pages_needed(req) <= self.pool.capacity)

    # -- prefix registry -----------------------------------------------------
    def _prefix_tokens(self, req: GenRequest):
        """The declared-prefix tokens this request could SHARE through the
        radix registry, or None. Capped at prompt_len - 1 so the suffix
        prefill always keeps >= 1 real token to sample the first output
        from. Frontend requests/archs bypass the registry: their leading KV
        rows are per-request embeddings, not shareable prompt pages."""
        if not (self.prefix_cache and self.paged) or self.fe_len:
            return None
        if req.frontend is not None or not req.prefix_len:
            return None
        cap = min(req.prefix_len, req.prompt_len - 1)
        if cap < 1:
            return None
        return req.prompt[:cap]

    def prefix_hit(self, req: GenRequest, touch: bool = False):
        """The request's longest registered ancestry as a ``PrefixMatch``
        (whole shared blocks root-first, plus an optional mid-block partial
        boundary), or None when nothing matches. The radix walk compares
        token blocks byte-for-byte, so a chained-digest collision over
        different tokens is a MISS at that depth, never a wrong share."""
        toks = self._prefix_tokens(req)
        if toks is None:
            return None
        m = self.pool.match(toks, touch=touch)
        if not m.all_nodes():
            return None
        return m

    def can_start(self, req: GenRequest) -> bool:
        """Right-now feasibility: a free slot AND (paged) enough unreserved
        pool pages to cover the request's worst case. False here is
        *backpressure*, not rejection -- the scheduler retries next tick.
        A registry hit shrinks the footprint to the suffix pages, plus the
        one-time cost of pinning currently-evictable chain nodes and of the
        free pages any spilled chain node needs to restore into."""
        if not (self.has_free() and self.fits(req)):
            return False
        if not self.paged:
            return True
        hit = self.prefix_hit(req)
        if hit is not None:
            return self.pool.can_reserve(
                self.pages_needed(req) - len(hit.nodes)
                + self.pool.pin_cost(hit) + self.pool.restore_cost(hit))
        return self.pool.can_reserve(self.pages_needed(req))

    def _spill_save(self, page: int):
        """Device -> host: copy one pool page out of the live cache (per
        layer/stage) and sync it to numpy. The gather does NOT donate the
        cache -- the pool only spills during host-side bookkeeping, before
        the next donating dispatch."""
        small = _gather_pages_jit(self.cache,
                                  jnp.asarray([page], dtype=jnp.int32))
        return jax.tree.map(np.asarray, jax.block_until_ready(small))

    def _spill_load(self, page: int, payload) -> None:
        """Host -> device: scatter a restored payload back into ``page``
        (the registry pull). Reuses the prefill scatter with a one-page
        row."""
        self.cache = _insert_pages_jit(
            self.cache, jax.tree.map(jnp.asarray, payload),
            jnp.asarray([page], dtype=jnp.int32))

    def _drain_tier_events(self, rid: int, tick: int) -> None:
        """Record the pool's spill/restore movements since the last drain
        as spans under the request whose allocation triggered them."""
        for kind, digest in self.pool.drain_events():
            if kind == "spill":
                self.trace.record(rid, "spill", tick, replica=self.name,
                                  digest=digest)
            else:
                self.trace.record(rid, "restore", tick, replica=self.name,
                                  digest=digest)

    def reject_reason(self, req: GenRequest) -> str:
        """Why ``fits`` is False -- the oversized-rejection error path."""
        if not self.supports(req):
            if not self.fe_len:
                return (f"frontend prefix ({req.frontend_len} rows) on "
                        f"text-only arch {self.container.arch.name}")
            if req.frontend_len > self.fe_len:
                return (f"frontend prefix {req.frontend_len} exceeds arch "
                        f"frontend_len {self.fe_len}")
            return (f"frontend embedding width {req.frontend.shape[1]} != "
                    f"d_model {self.d_model}")
        what = "frontend+prompt+gen" if self.fe_len else "prompt+gen"
        if self.paged:
            if self.span(req) + self.chunk > self.max_len:
                return (f"{what}+chunk {self.span(req) + self.chunk} "
                        f"exceeds page-table span {self.max_len} "
                        f"({self.max_pages} pages x {self.page_size})")
            return (f"{what}+chunk {self.span(req) + self.chunk} needs "
                    f"{self.pages_needed(req)} pages; pool capacity is "
                    f"{self.pool.capacity}")
        return (f"{what} {self.span(req)} exceeds slot capacity "
                f"{self.max_len - self.chunk}")

    def bucket(self, prompt_len: int) -> int:
        # the cache row budget left for tokens after the frontend buffer
        cap = self.max_len - self.fe_len
        if self.exact_prefill:
            return prompt_len
        for b in _PREFILL_BUCKETS:
            if b >= prompt_len:
                return min(b, cap)
        return prompt_len

    def start(self, req: GenRequest, tick: int) -> bool:
        """Prefill ``req`` into a free slot. Returns True if the request
        already finished at prefill (budget of one token, or instant EOS).

        A PREEMPTED request resumes here through the same path: its pages
        were released at preemption, so the prefill recomputes KV for the
        prompt plus every token generated before the pause except the last
        -- that one stays the decode cursor, exactly where the unpreempted
        run left it, so the continuation is token-for-token identical."""
        # chunked decode can overshoot a finished request by chunk-1 writes;
        # the scheduler pre-screens, so tripping this is an internal bug
        if not self.fits(req):
            raise ValueError(f"request {req.rid}: {self.reject_reason(req)}")
        resuming = req.state == "preempted"
        slot = self.free.pop(0)
        self._c_slots_alloc.inc()
        req.slot, req.replica, req.state = slot, self.name, "running"
        if req.admit_tick < 0:
            # FIRST admission only: a resume never moves the TTFT anchor
            req.admit_tick = tick
        if resuming:
            self._c_resumed.inc()
            self.trace.record(req.rid, "resume", tick, replica=self.name,
                              slot=slot, tokens_done=len(req.tokens))
            seq = np.concatenate(
                [req.prompt, np.asarray(req.tokens[:-1], np.int32)])
        else:
            self.trace.record(req.rid, "admit", tick, replica=self.name,
                              slot=slot, priority=req.priority)
            seq = req.prompt

        P = int(seq.shape[0])
        hit = self.prefix_hit(req) if self.paged else None
        if hit is not None:
            # HIT: map the matched radix chain's pages read-only into the
            # slot's leading table rows and prefill ONLY the unmatched
            # suffix, positions offset past the match (which may end
            # MID-page: the boundary node's page rides along as the
            # front-partial merge operand). ALL pool bookkeeping --
            # reservation, chain mapping, spill-tier restores, private
            # allocation -- runs BEFORE the dispatch because the suffix
            # prefill READS the live pool at the chain's pages.
            k = len(hit.nodes)                  # whole shared table rows
            L = hit.tokens_matched              # includes the partial frac
            frac = hit.partial_len
            sfx = seq[L:]
            S = int(sfx.shape[0])              # >= 1 by _prefix_tokens' cap
            # clamp so shared rows + merged suffix pages never outrun the
            # page table
            bucket = min(self.bucket(S), self.max_len - L)
            key = (bucket, L)
            prefill = self._prefills.get(key)
            if prefill is None:
                prefill = self.container.compile_serve_step(
                    "prefill_slot_paged", prompt_len=bucket,
                    page_size=self.page_size, prefix_len=L,
                    n_pages=self.n_pages)
                self._prefills[key] = prefill
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :S] = sfx
            self.pool.reserve(slot, self.pages_needed(req) - k)
            self.pool.share_chain(slot, hit)    # restores spilled nodes
            self.pool.alloc_upto(slot, P - 1)   # private suffix pages
            self._drain_tier_events(req.rid, tick)
            t0 = time.perf_counter()
            first, small = prefill(
                self.params, self.cache, jnp.asarray(toks), jnp.int32(S),
                jnp.asarray([n.page for n in hit.all_nodes()],
                            dtype=jnp.int32))
            # the suffix prefill READS the live pool and the scatter below
            # DONATES it: force completion of BOTH outputs (small reads the
            # chain pages too) before re-using the buffer
            first, small = jax.block_until_ready((first, small))
            first = int(first[0])
            self.pool.unpin()   # partial boundary page consumed by small
            np_ = -(-(frac + bucket) // self.page_size)
            row = jnp.asarray(self.pool.table[slot, k:k + np_])
            self.cache = _insert_pages_jit(self.cache, small, row)
            start_pos = P
            toks_p = self._prefix_tokens(req)
            kc = len(toks_p) // self.page_size  # declared complete blocks
            if k >= 1:
                self._c_phits.inc()
                if k < kc:
                    # shared a shorter family's ancestor chain, not the
                    # whole declared prefix -- the radix win over the flat
                    # index, accounted apart for fig11
                    self._c_pancestor.inc()
            else:
                self._c_ppartial.inc()
            self.metrics.counter("prefix_hit_depth", replica=self.name,
                                 depth=str(k)).inc()
            self._c_psaved.inc(L)
            self._c_positions.inc(S)
            self._c_prefill_disp.inc()
            if kc > k:
                # ancestor hit: deepen the family by registering the
                # freshly-written complete declared blocks BELOW the
                # matched chain (interior promotion; a partial boundary
                # implies kc == k, nothing to register)
                ps = self.page_size
                self.pool.promote_chain(
                    slot, hit.nodes[-1] if hit.nodes else None,
                    [toks_p[i * ps:(i + 1) * ps] for i in range(k, kc)])
            self.prefill_s += time.perf_counter() - t0
            self.trace.record(req.rid, "prefill", tick, replica=self.name,
                              slot=slot, positions=S, bucket=bucket,
                              pages=self.pages_needed(req) - k,
                              prefix_hit=True, tokens_saved=L,
                              depth=k, partial=frac)
        else:
            bucket = self.bucket(P)
            prefill = self._prefills.get(bucket)
            if prefill is None:
                shapes = ({"page_size": self.page_size} if self.paged
                          else {"cache_len": self.max_len})
                if self.fe_len:
                    shapes["frontend_len"] = self.fe_len
                prefill = self.container.compile_serve_step(
                    *(("prefill_slot_paged",) if self.paged
                      else ("prefill_slot",)),
                    prompt_len=bucket, **shapes)
                self._prefills[bucket] = prefill
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :P] = seq
            fe_args = ()
            if self.fe_len:
                # static-width prefix buffer; real rows packed ahead of the
                # prompt by Model.forward (fe_len=0 -> pure-token request)
                fe = np.zeros((1, self.fe_len, self.d_model), np.float32)
                if req.frontend is not None:
                    fe[0, :req.frontend_len] = req.frontend
                fe_args = (jnp.asarray(fe, self.fe_dtype),
                           jnp.int32(req.frontend_len))

            t0 = time.perf_counter()
            first, small = prefill(self.params, jnp.asarray(toks),
                                   jnp.int32(P), *fe_args)
            start_pos = req.frontend_len + P
            if self.paged:
                # bulk prefix+prompt allocation, then one page-major scatter
                self.pool.reserve(slot, self.pages_needed(req))
                self.pool.alloc_upto(slot, start_pos - 1)
                np_ = -(-(bucket + self.fe_len) // self.page_size)
                row = jnp.asarray(self.pool.table[slot, :np_])
                self.cache = _insert_pages_jit(self.cache, small, row)
            else:
                self.cache = self._insert(self.cache, small, jnp.int32(slot))
            first = int(jax.block_until_ready(first)[0])
            self.prefill_s += time.perf_counter() - t0
            self._c_positions.inc(req.frontend_len + P)
            self._c_prefill_disp.inc()
            if self.paged:
                # spills triggered by this allocation, recorded BEFORE the
                # prefill span (spill precedes prefill in SPAN_TRANSITIONS)
                self._drain_tier_events(req.rid, tick)
            self.trace.record(req.rid, "prefill", tick, replica=self.name,
                              slot=slot, positions=req.frontend_len + P,
                              bucket=bucket,
                              pages=(self.pages_needed(req) if self.paged
                                     else 0),
                              prefix_hit=False)
            toks_p = self._prefix_tokens(req)
            if toks_p is not None:
                # MISS: promote the freshly-written, fully-covered leading
                # prompt pages into the registry as a chain of nodes -- one
                # per complete declared block -- so later requests share
                # ANY ancestor of them (first writer wins; an existing
                # child or digest collision stops the chain there).
                # _prefix_tokens caps at prompt_len - 1, so the page
                # holding the first suffix token stays private: promoting
                # an uncapped prefix_len // page_size used to cache a page
                # no match could ever reach, pinned until eviction (leak)
                ps = self.page_size
                kc = len(toks_p) // ps
                if kc >= 1:
                    self._c_pmiss.inc()
                    self.pool.promote_chain(
                        slot, None,
                        [toks_p[i * ps:(i + 1) * ps] for i in range(kc)])

        if resuming:
            # the prefill re-sampled the token after seq's last element --
            # a recomputation of tokens[-1]. The original sample is
            # authoritative; keeping it as the decode cursor makes the
            # resumed run bitwise-continue the unpreempted one.
            self.pos[slot] = start_pos
            self.cur_tok[slot] = req.tokens[-1]
            self.active[slot] = req
            return False
        req.tokens.append(first)
        self._c_tokens.inc()
        self.pos[slot] = start_pos      # next decode writes here
        self.cur_tok[slot] = first
        self.active[slot] = req
        if self._finished(req, first):
            self._complete(req, tick)
            return True
        return False

    # -- decode -------------------------------------------------------------
    def tick(self, tick: int) -> list[GenRequest]:
        """One decode *chunk* (``self.chunk`` model ticks in one dispatch)
        over the whole slot bank; returns requests that completed. A slot
        finishing mid-chunk decodes to the chunk boundary; its surplus
        tokens are discarded here (bounded, counted waste)."""
        if not self.active:
            return []
        t0 = time.perf_counter()
        if self.paged:
            # alloc-on-write, one chunk ahead: every write position of this
            # dispatch (pos..pos+chunk-1) must be mapped before the kernel
            # runs; pages come out of the request's admission reservation,
            # so this can never fail mid-flight
            for slot in self.active:
                self.pool.alloc_upto(slot, int(self.pos[slot]) + self.chunk - 1)
                self._drain_tier_events(self.active[slot].rid, tick)
            toks, _, _, self.cache = self.decode(
                self.params, self.cache,
                jnp.asarray(self.cur_tok[:, None]), jnp.asarray(self.pos),
                jnp.asarray(self.pool.table))
        else:
            toks, _, _, self.cache = self.decode(
                self.params, self.cache,
                jnp.asarray(self.cur_tok[:, None]), jnp.asarray(self.pos))
        toks = np.asarray(jax.block_until_ready(toks))   # (n_slots, chunk)
        self.decode_s += time.perf_counter() - t0
        self._c_decode_ticks.inc(self.chunk)
        self._c_decode_disp.inc()

        finished = []
        # advance ACTIVE rows only: free slots stay parked at 0, so an
        # engine idling for hours never walks a row position past max_len
        # (in paged mode pos // page_size would index past the page-table
        # span -- silently clamped by XLA, out-of-bounds for the real
        # scalar-prefetch kernel)
        for slot in self.active:
            self.pos[slot] += self.chunk
        for slot, req in list(self.active.items()):
            self.cur_tok[slot] = int(toks[slot, -1])
            self.trace.record(req.rid, "decode_chunk", tick,
                              replica=self.name, slot=slot, chunk=self.chunk)
            for k in range(self.chunk):
                tok = int(toks[slot, k])
                req.tokens.append(tok)
                self._c_tokens.inc()
                if self._finished(req, tok):
                    # the rest of the chunk decoded past the finish: those
                    # tokens are discarded -- count the waste
                    self._c_wasted.inc(self.chunk - 1 - k)
                    self._complete(req, tick)
                    finished.append(req)
                    break
        return finished

    def _finished(self, req: GenRequest, tok: int) -> bool:
        eos = req.eos_id if req.eos_id is not None else self.eos_id
        if eos is not None and tok == eos:
            req.finish_reason = "eos"
            return True
        if len(req.tokens) >= req.max_new_tokens:
            req.finish_reason = "length"
            return True
        return False

    def _complete(self, req: GenRequest, tick: int) -> None:
        req.state, req.done_tick = "done", tick
        self.trace.record(req.rid, "complete", tick, replica=self.name,
                          slot=req.slot, tokens=len(req.tokens),
                          reason=req.finish_reason)
        self.active.pop(req.slot)
        self.free.append(req.slot)
        self._c_slots_freed.inc()
        # park the freed row at position 0: free slots are still dispatched
        # every chunk (their output is discarded), so an unbounded position
        # would drift past the cache span while the slot sits idle
        self.pos[req.slot] = 0
        self.cur_tok[req.slot] = 0
        if self.paged:
            # full reclaim the same tick: owned pages + unused reservation
            self.pool.release(req.slot)

    def preempt(self, req: GenRequest, tick: int) -> int:
        """Page-level preemption: pause ``req`` mid-decode and reclaim its
        slot plus every private page and unfilled reservation, making room
        for a higher-priority admission. The generated-so-far tokens stay
        on the request; ``start`` later resumes it by re-prefilling them as
        a suffix. Returns the number of owned pages freed."""
        if not self.paged:
            raise RuntimeError(
                f"engine {self.name}: preemption is page-granular "
                "(paged mode only)")
        slot = req.slot
        if self.active.get(slot) is not req:
            raise RuntimeError(
                f"request {req.rid} is not running on engine {self.name}")
        freed = self.pool.pause(slot)
        self.active.pop(slot)
        self.free.append(slot)
        self._c_slots_freed.inc()
        self.pos[slot] = 0              # park like _complete: free slots
        self.cur_tok[slot] = 0          # are still dispatched every chunk
        req.state, req.slot, req.replica = "preempted", None, None
        req.preemptions += 1
        self._c_preempted.inc()
        self.trace.record(req.rid, "preempt", tick, replica=self.name,
                          slot=slot, pages_freed=freed,
                          tokens_done=len(req.tokens))
        return freed

    def release(self) -> None:
        """Drop device state (params, slot cache, executables). Called at
        retirement so upgraded-away fleets do not pin a whole generation of
        params+KV in device memory."""
        self.stopped = True
        self.params = None
        self.cache = None
        self.decode = None
        self._prefills.clear()

    def status(self) -> dict:
        out = {
            "container": self.container.container_id,
            "image": self.container.image.short_digest,
            "slots": self.n_slots,
            "active": len(self.active),
            "free": len(self.free),
            "draining": self.draining,
            "stopped": self.stopped,
            "decode_ticks": self.decode_ticks,
            "tokens_generated": self.tokens_generated,
            "tokens_wasted": self.tokens_wasted,
            "preemptions": self.preemptions,
            "resumes": self.resumes,
            # one compiled prefill per distinct bucket -- bounded for
            # pow2-bucketed archs, per distinct prompt length in
            # exact-prefill mode (watch this in `ps` for unbounded growth)
            "prefill_execs": len(self._prefills),
        }
        compile_stats = getattr(self.container, "serve_compile_stats", None)
        if compile_stats:
            out["compile"] = dict(compile_stats)
        if self.paged:
            out["pool"] = self.pool.status()
            if self.prefix_cache:
                out["prefix_cache"] = {
                    "hits": self.prefix_hits,
                    "misses": self.prefix_misses,
                    "tokens_saved": self.prefix_tokens_saved,
                    "shared_pages": self.pool.cached_pages,
                    "ancestor_hits": self.prefix_ancestor_hits,
                    "partial_hits": self.prefix_partial_hits,
                    "nodes": self.pool.radix.node_count,
                    "max_depth": self.pool.radix.max_depth,
                    "spilled_pages": self.pool.spilled_pages,
                    "spills": self.pool.spills,
                    "restores": self.pool.restores,
                }
        return out


class ContinuousScheduler:
    """Iteration-level scheduling over a Pod's engines."""

    STATE_EVERY = 8     # min ticks between pod-state file refreshes

    def __init__(self, pod, queue: RequestQueue | None = None,
                 fairness_cap: int = 4):
        self.pod = pod
        self.queue = queue or RequestQueue()
        self.fairness_cap = int(fairness_cap)
        self.tick = 0
        self._state_tick = -self.STATE_EVERY
        self.completed: list[GenRequest] = []
        self.rejected: list[GenRequest] = []
        self.shedded: list[GenRequest] = []
        self.admission_order: list[int] = []
        # pod-level completion metrics, registered eagerly so an idle pod
        # still snapshots the full (empty) shape; geometry shared with
        # obs.report so the span-log recompute compares field-for-field
        self.metrics = getattr(pod, "metrics", None) or MetricsRegistry()
        self.trace = getattr(pod, "trace", None) or TraceBuffer()
        self._c_completed = self.metrics.counter("requests_completed")
        self._c_rejected = self.metrics.counter("requests_rejected")
        self._c_shed = self.metrics.counter("requests_shed")
        self._c_tokens_out = self.metrics.counter("tokens_out")
        self._g_queue = self.metrics.gauge("queue_depth")
        self.metrics.histogram("latency_ticks", **TICK_HIST)
        self.metrics.histogram("ttft_ticks", **TICK_HIST)
        self.metrics.histogram("itl_milliticks", **ITL_HIST)

    def submit(self, reqs: Iterable[GenRequest] | GenRequest) -> None:
        if isinstance(reqs, GenRequest):
            reqs = [reqs]
        for r in reqs:
            self.queue.submit(r, self.tick)
            self.trace.record(r.rid, "submit", self.tick, arrival=r.arrival)
        self._g_queue.set(self.queue.pending)

    def reject(self, req: GenRequest) -> None:
        """Terminal rejection: record the per-engine reasons and count it
        where ``Pod.status`` / ``repro ps`` can see it."""
        req.state, req.finish_reason = "rejected", "oversized"
        req.error = "; ".join(sorted(
            {e.reject_reason(req) for e in self.pod.engines}))
        req.done_tick = self.tick
        self.rejected.append(req)
        self.pod.rejected += 1
        self._c_rejected.inc()
        self.trace.record(req.rid, "reject", self.tick, reason="oversized")

    def shed(self, req: GenRequest, reason: str) -> None:
        """Typed QoS shed: terminal like a rejection, but counted apart --
        the request was servable, the SLO policy chose not to serve it."""
        req.state, req.finish_reason = "shed", reason
        req.error = (f"shed: admission deadline of {req.deadline_ticks} "
                     f"ticks missed" if reason == "deadline"
                     else f"shed: {reason}")
        req.done_tick = self.tick
        self.shedded.append(req)
        self.pod.shed += 1
        self._c_shed.inc()
        self.trace.record(req.rid, "shed", self.tick, reason=reason,
                          priority=req.priority)

    # -- one global tick ------------------------------------------------------
    def step(self) -> list[GenRequest]:
        done: list[GenRequest] = []
        # admission: FIFO across the pod, capped prefills per tick
        admitted = rejected = 0
        while admitted < self.fairness_cap and self.queue.has_ready(self.tick):
            req = self.queue.peek_ready(self.tick)
            # permanent infeasibility is screened BEFORE the free-slot gate:
            # a request that exceeds every engine's slab / page-table span /
            # pool can NEVER run, so it must be rejected even when all slots
            # are busy -- gating on occupancy let an un-servable head stall
            # every feasible request behind it until a slot freed
            if not any(e.fits(req) for e in self.pod.engines):
                self.queue.pop_ready(self.tick)
                self.reject(req)
                rejected += 1
                continue
            # admission-deadline SLO: a queued head that can no longer be
            # admitted in time is shed, not served uselessly late. Resumes
            # are exempt -- their first token already left on time.
            if (req.state == "queued" and req.deadline_ticks is not None
                    and self.tick > max(req.arrival, req.submit_tick)
                    + req.deadline_ticks):
                self.queue.pop_ready(self.tick)
                self.shed(req, "deadline")
                rejected += 1
                continue
            engines = [e for e in self.pod.engines if e.has_free()]
            ready = [e for e in engines if e.can_start(req)]
            if not ready:
                # feasible but no slot / no pages free right now: hold the
                # head -- unless it is an interactive head blocked behind
                # running batch work, in which case page-level preemption
                # pauses the youngest batch request to make room (strict
                # QoS; equal-priority work is never preempted)
                if self._try_preempt(req):
                    continue
                break
            # least-loaded engine keeps replica occupancy balanced without
            # breaking FIFO (the *request* order is still queue order);
            # an engine whose registry already holds the request's prefix
            # wins ties-or-better, DEEPEST match first (prefix affinity
            # WITHIN the pod -- each replica's page pool is its own)
            def _affinity(e):
                m = e.prefix_hit(req)
                return (-m.tokens_matched if m is not None else 0,
                        len(e.active))
            eng = min(ready, key=_affinity)
            self.queue.pop_ready(self.tick)
            if req.state == "queued":   # resumes were already counted
                self.queue.admitted += 1
                self.admission_order.append(req.rid)
            if eng.start(req, self.tick):
                done.append(req)
            admitted += 1
        # decode: every engine advances its active slots by one token
        for eng in self.pod.engines:
            done.extend(eng.tick(self.tick))
        self.completed.extend(done)
        for req in done:
            self._observe(req)
        self._g_queue.set(self.queue.pending)
        self.tick += 1
        # keep `repro ps` honest without putting file I/O in every tick:
        # refresh on occupancy OR rejection changes, at most once per
        # STATE_EVERY ticks -- a burst of pure rejections used to leave the
        # state file (queue depth, rejected counter) stale indefinitely
        if (admitted or done or rejected) and (
                self.tick - self._state_tick >= self.STATE_EVERY):
            self.pod.write_state()
            self._state_tick = self.tick
        return done

    def _try_preempt(self, req: GenRequest) -> bool:
        """Page-level preemption on behalf of a blocked interactive head:
        pause ONE running batch request (on a paged engine that could fit
        ``req``), releasing its slot, private pages and reservation, and
        requeue it at the front of the batch lane for a later resume.
        Victim choice is deterministic: the most recently admitted batch
        request (ties by rid) -- the least decode progress thrown away.
        Returns True if a victim was paused (the admission loop retries the
        head), False if there is nothing to preempt."""
        if req.priority != "interactive":
            return False
        victims = [(e, r) for e in self.pod.engines
                   if e.paged and not e.draining and e.fits(req)
                   for r in e.active.values() if r.priority == "batch"]
        if not victims:
            return False
        eng, victim = max(victims,
                          key=lambda t: (t[1].admit_tick, t[1].rid))
        eng.preempt(victim, self.tick)
        self.queue.requeue(victim)
        return True

    def _observe(self, req: GenRequest) -> None:
        """Feed one completion into the pod registry. Shares the formulas
        with ``obs.report.observe_completion`` so metrics recomputed from
        the span log bitwise-match this registry's snapshot."""
        observe_completion(
            self.metrics, arrival=req.arrival, submit_tick=req.submit_tick,
            admit_tick=req.admit_tick, done_tick=req.done_tick,
            n_tokens=len(req.tokens), rid=req.rid)

    @property
    def busy(self) -> bool:
        return (self.queue.pending > 0
                or any(e.active for e in self.pod.engines))

    def run(self, max_ticks: int | None = None) -> list[GenRequest]:
        """Serve until queue + slots are empty (or ``max_ticks``)."""
        start = self.tick
        while self.busy:
            if max_ticks is not None and self.tick - start >= max_ticks:
                break
            self.step()
        self.pod.write_state()      # final snapshot (throttle may have skipped)
        return self.completed

    def drain(self, engine: SlotEngine, max_ticks: int = 100_000,
              tick_fn=None) -> int:
        """Tick until ``engine`` has no in-flight requests. The engine is
        marked draining (no new admissions) but its active requests run to
        completion; other engines keep serving. ``tick_fn`` overrides the
        tick driver -- the fleet deployer passes ``PodRouter.step`` so the
        OTHER pods keep admitting and decoding while this one drains."""
        engine.draining = True
        tick_fn = tick_fn or self.step
        ticks = 0
        while engine.active and ticks < max_ticks:
            tick_fn()
            ticks += 1
        if engine.active:
            raise RuntimeError(
                f"drain of {engine.name} did not converge in {max_ticks} ticks")
        return ticks
