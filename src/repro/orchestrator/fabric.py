"""Cross-host serving fabric: a message transport between router and pods.

``PodRouter`` ticks N in-process pods through direct method calls -- fine
for one host, but nothing about a real multi-host deployment (request
serialization, dead hosts, membership churn, elastic capacity) is
exercised. This module makes the router speak to pods over a framed
message protocol instead:

* **Codec** -- ``encode_request``/``decode_request`` serialize a
  ``GenRequest`` (prompt as base64 int32, frontend embeddings as base64
  float32, plus the resume state: generated tokens, admit tick,
  preemption count) and ``encode_frame``/``decode_frame`` wrap messages
  as ``\\x1e`` + JSON + newline, so a byte stream with interleaved stray
  output (library prints on a worker's stdout) still parses.
* **PodWorker** -- the pod side: one ``Pod`` + ``ContinuousScheduler``
  behind a ``handle(msg) -> reply`` dispatch (submit / step / hb /
  retire). Stateless about its peers: everything it knows arrives in
  messages, so the same worker runs in-process or as a subprocess.
  Terminal results are delivered at-least-once: a final payload rides
  every reply until the router acks its rid on a later step, so a reply
  lost to a flapping link never loses a completion (the router applies
  each rid once, duplicates are no-ops).
* **LoopbackTransport** -- in-memory, synchronous, deterministic: frames
  are encoded and decoded exactly as on a pipe (the codec is always
  exercised) but delivery is immediate. The unit-test and parity
  harness; also supports fault injection (``kill`` simulates SIGKILL,
  ``muted`` drops replies to simulate a flapping link).
* **ProcTransport** -- process-per-pod over stdin/stdout pipes: the
  headline harness. A reader thread pumps frames into a queue; EOF or a
  broken pipe marks the transport dead, so a kill -9'd worker is
  detected without waiting out a timeout.
* **FabricRouter** -- the router side: consistent-hash / shortest-queue
  placement over REMOTE capability descriptors, heartbeats with
  dead-pod eviction from the ring, exactly-once re-routing of a dead
  pod's in-flight work to survivors (requests with committed tokens
  resume via the preemption machinery's suffix re-prefill -- greedy
  decode makes the continuation bitwise-token-identical), and an
  elastic fleet: spawn pods when the outstanding-token backlog per pod
  crosses a threshold, drain + retire them when the fleet idles.

What stays lockstep-tick vs. wall-clock: *scheduling* is tick-clocked
everywhere -- the router's ``step`` fans one logical tick out to every
worker, and placement/eviction/scaling decisions depend only on message
contents, so a loopback fleet is bit-for-bit deterministic. *Liveness*
is wall-clock -- heartbeat/step reply timeouts, the ``wall`` timestamp
riding fabric spans in proc mode -- and never feeds back into token
results, only into failover timing.
"""

from __future__ import annotations

import base64
import bisect
import json
import os
import signal
import subprocess
import sys
import threading
import time
import uuid
from collections import deque
from pathlib import Path
from queue import Empty, Queue
from typing import Callable, Iterable

import numpy as np

from repro.orchestrator.obs.metrics import MetricsRegistry, merge_snapshots
from repro.orchestrator.obs.tracing import TraceBuffer, dump_span_log
from repro.orchestrator.pod import Pod
from repro.orchestrator.request_queue import GenRequest
from repro.orchestrator.router import _hash64
from repro.orchestrator.scheduler import ContinuousScheduler

# frame marker: ASCII record separator. A worker's stdout may carry stray
# library output; only lines opening with the marker are protocol frames.
FRAME = b"\x1e"

FABRIC_POLICIES = ("shortest-queue", "consistent-hash")

# <runtime root>/spans/<name>.spans.json -- per-process span files, the
# cross-process half of the fleet-wide lifecycle closure check
SPAN_DIR = "spans"


def span_path(root, name: str) -> Path:
    return Path(root) / SPAN_DIR / f"{name}.spans.json"


# -- codec --------------------------------------------------------------------

def encode_frame(msg: dict) -> bytes:
    return FRAME + json.dumps(
        msg, separators=(",", ":"), sort_keys=True).encode() + b"\n"


def decode_frame(raw: bytes | str) -> dict | None:
    """The message in ``raw`` if it is a protocol frame, else None."""
    if isinstance(raw, str):
        raw = raw.encode()
    if not raw.startswith(FRAME):
        return None
    try:
        msg = json.loads(raw[len(FRAME):].decode())
    except (UnicodeDecodeError, json.JSONDecodeError):
        return None
    return msg if isinstance(msg, dict) else None


def _b64(arr: np.ndarray) -> str:
    return base64.b64encode(np.ascontiguousarray(arr).tobytes()).decode()


def _unb64(s: str, dtype) -> np.ndarray:
    return np.frombuffer(base64.b64decode(s), dtype=dtype).copy()


def encode_request(req: GenRequest) -> dict:
    """Wire form of a GenRequest, INCLUDING its resume state: a request
    re-routed off a dead pod ships its committed tokens so the survivor
    can suffix-re-prefill (prompt + tokens[:-1], cursor on tokens[-1])
    and continue token-for-token where the fleet last saw it."""
    return {
        "rid": req.rid,
        "prompt": _b64(np.asarray(req.prompt, np.int32)),
        "max_new_tokens": req.max_new_tokens,
        "eos_id": req.eos_id,
        "arrival": req.arrival,
        "frontend": None if req.frontend is None else {
            "shape": [int(d) for d in req.frontend.shape],
            "data": _b64(np.asarray(req.frontend, np.float32))},
        "prefix_len": req.prefix_len,
        "priority": req.priority,
        "deadline_ticks": req.deadline_ticks,
        "state": req.state,
        "tokens": [int(t) for t in req.tokens],
        "submit_tick": req.submit_tick,
        "admit_tick": req.admit_tick,
        "preemptions": req.preemptions,
        "reroutes": req.reroutes,
    }


def decode_request(doc: dict) -> GenRequest:
    fe = doc.get("frontend")
    if fe is not None:
        fe = _unb64(fe["data"], np.float32).reshape(fe["shape"])
    req = GenRequest(
        rid=int(doc["rid"]),
        prompt=_unb64(doc["prompt"], np.int32),
        max_new_tokens=int(doc["max_new_tokens"]),
        eos_id=doc.get("eos_id"),
        arrival=int(doc.get("arrival", 0)),
        frontend=fe,
        prefix_len=int(doc.get("prefix_len", 0)),
        priority=doc.get("priority", "interactive"),
        deadline_ticks=doc.get("deadline_ticks"))
    # resume state rides outside the constructor: these fields are owned
    # by the scheduler/engine at runtime, the codec just moves them
    req.state = doc.get("state", "queued")
    req.tokens = [int(t) for t in doc.get("tokens", [])]
    req.submit_tick = int(doc.get("submit_tick", -1))
    req.admit_tick = int(doc.get("admit_tick", -1))
    req.preemptions = int(doc.get("preemptions", 0))
    req.reroutes = int(doc.get("reroutes", 0))
    return req


def encode_final(req: GenRequest) -> dict:
    """Terminal-state payload streamed back to the router: authoritative
    final fields for the CALLER's request object."""
    return {
        "rid": req.rid,
        "state": req.state,
        "tokens": [int(t) for t in req.tokens],
        "finish_reason": req.finish_reason,
        "error": req.error,
        "submit_tick": req.submit_tick,
        "admit_tick": req.admit_tick,
        "done_tick": req.done_tick,
        "replica": req.replica,
        "slot": req.slot,
        "preemptions": req.preemptions,
    }


# -- pod side -----------------------------------------------------------------

class PodWorker:
    """One pod behind the message protocol.

    Owns a ``Pod`` + ``ContinuousScheduler`` and answers the router's
    frames; runs unchanged in-process (LoopbackTransport) or as the body
    of a worker subprocess (``python -m repro.orchestrator.fabric
    --worker``). Joins the fleet's tick domain at ``start_tick`` so a
    pod spawned mid-run (elastic scale-up) stamps admits/completions on
    the same clock as the rest of the fleet."""

    def __init__(self, runtime, image, *, pod_id: str,
                 start_tick: int = 0, fairness_cap: int = 4,
                 pod_kwargs: dict | None = None, wall_clock: bool = False):
        self.runtime = runtime
        self.pod = Pod(runtime, image, pod_id=pod_id,
                       **dict(pod_kwargs or {}))
        self.sched = ContinuousScheduler(self.pod,
                                         fairness_cap=fairness_cap)
        self.sched.tick = int(start_tick)
        self.wall_clock = bool(wall_clock)
        self._inflight: dict[int, GenRequest] = {}
        self._tok_sent: dict[int, int] = {}
        self._adm_sent: set[int] = set()
        # at-least-once finals: a terminal payload stays here (and rides
        # every subsequent events reply) until the router acks the rid on
        # a later step message -- a reply lost to a flapping link must
        # not lose a completion, and duplicate finals are idempotent on
        # the router side
        self._finals: dict[int, dict] = {}
        self.span_file = span_path(runtime.root, pod_id)

    def _caps(self) -> list[dict]:
        """Engine capability descriptors: everything the router needs to
        answer ``fits`` remotely (mirrors ``SlotEngine.fits``)."""
        return [{
            "n_slots": e.n_slots,
            "fe_len": e.fe_len,
            "d_model": e.d_model,
            "max_len": e.max_len,
            "chunk": e.chunk,
            "paged": e.paged,
            "page_size": e.page_size if e.paged else 0,
            "capacity": e.pool.capacity if e.paged else 0,
        } for e in self.pod.engines]

    def _wall(self) -> float | None:
        return time.time() if self.wall_clock else None

    def flush(self) -> None:
        """State file + span file refresh: what `repro top --watch` and
        the cross-process closure check read while the run is live."""
        self.pod.write_state()
        dump_span_log(self.pod.trace, self.span_file)

    def handle(self, msg: dict) -> dict | None:
        t = msg.get("t")
        if t == "hello":
            return {"t": "ready", "pod": self.pod.pod_id,
                    "tick": self.sched.tick, "pid": os.getpid(),
                    "caps": self._caps()}
        if t == "submit":
            req = decode_request(msg["req"])
            self._inflight[req.rid] = req
            self._tok_sent[req.rid] = len(req.tokens)
            if req.state == "preempted" and req.tokens:
                # re-routed mid-decode: enters through the resume path
                # (front of its lane, suffix re-prefill at admission)
                self.sched.queue.requeue(req)
            else:
                submit0 = req.submit_tick
                req.state, req.tokens = "queued", []
                self.sched.submit(req)
                if submit0 >= 0:
                    # a fresh RE-submission after a pod death keeps its
                    # original submit stamp so queue-latency accounting
                    # spans the whole fleet-level wait, not the failover
                    req.submit_tick = submit0
            return None
        if t == "step":
            for rid in msg.get("ack", ()):
                self._finals.pop(int(rid), None)
            for _ in range(int(msg.get("n", 1))):
                self.sched.step()
            events = self._events()
            if events["done"]:
                # flush BEFORE replying: once the router learns a request
                # reached a terminal state, that terminal span is already
                # on disk -- a kill between flush and reply just leaves
                # the request assigned, and re-routing covers it
                self.flush()
            return events
        if t == "hb":
            self.flush()
            return {"t": "beat", "pod": self.pod.pod_id,
                    "tick": self.sched.tick,
                    "pending": self.sched.queue.pending,
                    "active": sum(len(e.active)
                                  for e in self.pod.engines),
                    "wall": self._wall(),
                    "metrics": self.pod.metrics.snapshot()}
        if t == "retire":
            self.pod.write_state(final=True)
            dump_span_log(self.pod.trace, self.span_file)
            return {"t": "bye", "pod": self.pod.pod_id}
        return {"t": "error", "pod": self.pod.pod_id,
                "error": f"unknown message type {t!r}"}

    def _events(self) -> dict:
        """Everything that changed since the last report: new tokens per
        in-flight request (the token stream), first-admission ticks, and
        full final payloads for requests that reached a terminal state."""
        toks: dict[str, list[int]] = {}
        adm: list[list[int]] = []
        for rid in sorted(self._inflight):
            req = self._inflight[rid]
            sent = self._tok_sent[rid]
            if len(req.tokens) > sent:
                toks[str(rid)] = [int(x) for x in req.tokens[sent:]]
                self._tok_sent[rid] = len(req.tokens)
            if req.admit_tick >= 0 and rid not in self._adm_sent:
                adm.append([rid, req.admit_tick])
                self._adm_sent.add(rid)
        for rid in sorted(self._inflight):
            req = self._inflight[rid]
            if req.state in ("done", "rejected", "shed"):
                self._finals[rid] = encode_final(req)
                del self._inflight[rid]
                del self._tok_sent[rid]
                self._adm_sent.discard(rid)
        # every unacked final rides every reply (at-least-once delivery)
        done = [self._finals[rid] for rid in sorted(self._finals)]
        return {"t": "events", "pod": self.pod.pod_id,
                "tick": self.sched.tick, "toks": toks, "adm": adm,
                "done": done, "pending": self.sched.queue.pending,
                "active": sum(len(e.active) for e in self.pod.engines)}


# -- transports ---------------------------------------------------------------

class LoopbackTransport:
    """In-memory transport: frames round-trip through the codec exactly
    as on a pipe, delivery is synchronous, and everything is
    deterministic. ``kill`` simulates SIGKILL (dead + inbox gone);
    ``muted`` drops the next N replies (the worker still processes the
    message -- a flapping network link, not a dead host)."""

    def __init__(self, worker: PodWorker):
        self.worker = worker
        self.alive = True
        self.muted = 0
        self._inbox: deque[dict] = deque()

    @property
    def pid(self) -> int | None:
        return None

    def send(self, msg: dict) -> None:
        if not self.alive:
            raise BrokenPipeError("loopback transport is dead")
        reply = self.worker.handle(decode_frame(encode_frame(msg)))
        if reply is None:
            return
        if self.muted > 0:
            self.muted -= 1
            return
        self._inbox.append(decode_frame(encode_frame(reply)))

    def recv(self, timeout: float | None = None) -> dict | None:
        if self._inbox:
            return self._inbox.popleft()
        return None

    def kill(self) -> None:
        self.alive = False
        self._inbox.clear()

    def close(self) -> None:
        self.alive = False


class ProcTransport:
    """Process-per-pod transport over stdin/stdout pipes.

    A daemon reader thread pumps protocol frames off the worker's stdout
    into a queue (non-frame lines -- stray library prints -- are
    skipped). EOF or a broken pipe flips ``alive`` immediately, so a
    kill -9'd worker is detected the moment the pipe collapses instead
    of after a timeout."""

    def __init__(self, argv: list[str], env: dict | None = None):
        self.proc = subprocess.Popen(
            argv, stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            env=env)
        self.alive = True
        self._q: Queue = Queue()
        self._reader = threading.Thread(target=self._pump, daemon=True)
        self._reader.start()

    @property
    def pid(self) -> int:
        return self.proc.pid

    def _pump(self) -> None:
        for raw in self.proc.stdout:
            msg = decode_frame(raw)
            if msg is not None:
                self._q.put(msg)
        self._q.put(None)       # EOF sentinel: the worker is gone

    def send(self, msg: dict) -> None:
        try:
            self.proc.stdin.write(encode_frame(msg))
            self.proc.stdin.flush()
        except (BrokenPipeError, OSError):
            self.alive = False
            raise BrokenPipeError(f"worker pid {self.proc.pid} is gone")

    def recv(self, timeout: float | None = None) -> dict | None:
        try:
            msg = self._q.get(timeout=timeout)
        except Empty:
            return None
        if msg is None:
            self.alive = False
            return None
        return msg

    def kill(self) -> None:
        """SIGKILL -- the fault-injection primitive: no cleanup, no
        flush, the worker's state is simply gone."""
        self.alive = False
        try:
            os.kill(self.proc.pid, signal.SIGKILL)
        except (ProcessLookupError, OSError):
            pass

    def close(self) -> None:
        self.alive = False
        try:
            self.proc.stdin.close()
        except OSError:
            pass
        try:
            self.proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            self.kill()


def loopback_spawner(runtime, image, *, pod_kwargs: dict | None = None,
                     fairness_cap: int = 4) -> Callable:
    """Spawn callable for an in-process fleet (tests, parity baselines)."""
    def spawn(pod_id: str, start_tick: int) -> LoopbackTransport:
        return LoopbackTransport(PodWorker(
            runtime, image, pod_id=pod_id, start_tick=start_tick,
            fairness_cap=fairness_cap, pod_kwargs=pod_kwargs))
    return spawn


def proc_spawner(root, *, imagefile: str | None = None,
                 ref: str | None = None,
                 pod_kwargs: dict | None = None, fairness_cap: int = 4,
                 python: str | None = None) -> Callable:
    """Spawn callable launching one worker PROCESS per pod. The worker
    re-opens the same runtime root (registry, compile cache, state dir)
    and resolves the image itself: an ``imagefile`` text is rebuilt
    (content-addressed -- every worker lands on the identical digest the
    parent built), a registry ``ref`` is pulled."""
    if (imagefile is None) == (ref is None):
        raise ValueError("proc_spawner needs exactly one of imagefile=/"
                         "ref=")
    def spawn(pod_id: str, start_tick: int) -> ProcTransport:
        cfg = {"root": str(root), "imagefile": imagefile, "ref": ref,
               "pod_id": pod_id, "start_tick": int(start_tick),
               "fairness_cap": int(fairness_cap),
               "pod": dict(pod_kwargs or {})}
        argv = [python or sys.executable, "-m",
                "repro.orchestrator.fabric_worker", "--worker",
                "--config", json.dumps(cfg)]
        env = dict(os.environ)
        src = str(Path(__file__).resolve().parents[2])
        pp = env.get("PYTHONPATH", "")
        if src not in pp.split(os.pathsep):
            env["PYTHONPATH"] = src + (os.pathsep + pp if pp else "")
        return ProcTransport(argv, env=env)
    return spawn


# -- router side --------------------------------------------------------------

class FabricMember:
    """Router-side record of one remote pod: transport + capability
    descriptors + the liveness/load state the router tracks for it."""

    def __init__(self, pod_id: str, ordinal: int, transport):
        self.pod_id = pod_id
        self.ordinal = ordinal
        self.transport = transport
        self.caps: list[dict] = []
        self.outstanding = 0            # routed token budgets not finished
        self.missed = 0                 # consecutive unanswered probes
        self.assigned: dict[int, GenRequest] = {}
        self.to_ack: set[int] = set()   # finals to ack on the next step
        self.draining = False
        self.last_beat = -1             # router tick of the last beat
        self.last_wall: float | None = None
        self.tick = 0                   # worker tick at last reply
        self.pending = 0
        self.active = 0
        self.metrics_snapshot: dict | None = None

    @property
    def srid(self) -> int:
        """Synthetic rid for this member's heartbeat/evict span log:
        negative so it can never collide with user requests."""
        return -1 - self.ordinal

    @property
    def alive(self) -> bool:
        return self.transport.alive

    @property
    def capacity(self) -> int:
        return sum(c["n_slots"] for c in self.caps)


class FabricRouter:
    """PodRouter's surface (submit/step/run/drain_pod/status) over a
    fleet of transport-connected workers.

    One router ``step()`` = one fleet tick: probe heartbeats (every
    ``heartbeat_every`` ticks), evict members whose transport died or
    that missed ``miss_limit`` consecutive probes, heal/scale the fleet,
    route arrived requests, then fan the tick out to every live worker
    and fold their event streams back into the caller's request objects.

    Eviction re-routes the dead member's in-flight requests EXACTLY once
    each: requests with committed tokens are shipped to a survivor as
    preempted (the resume path re-prefills prompt + tokens[:-1] and
    continues from tokens[-1] -- greedy decode makes the continuation
    bitwise-identical to an unkilled run), token-less ones are
    re-submitted fresh. A flapping member (missed < miss_limit, then a
    beat) is never evicted, so its work is never duplicated."""

    STATE_EVERY = 8

    def __init__(self, spawn: Callable, *, runtime, pods: int = 2,
                 min_pods: int = 1, max_pods: int | None = None,
                 policy: str = "shortest-queue", fleet: str = "fab",
                 vnodes: int = 64, heartbeat_every: int = 4,
                 miss_limit: int = 2, hb_timeout: float = 10.0,
                 rpc_timeout: float = 120.0, boot_timeout: float = 300.0,
                 scale_up_tokens: int | None = None,
                 scale_idle_ticks: int | None = None,
                 wall_clock: bool = False):
        if policy not in FABRIC_POLICIES:
            raise ValueError(f"unknown fabric policy {policy!r}; "
                             f"choose from {FABRIC_POLICIES}")
        if pods < 1 or min_pods < 1:
            raise ValueError("a fabric needs at least one pod")
        self.spawn = spawn
        self.runtime = runtime
        self.policy = policy
        self.fleet = fleet
        self.min_pods = int(min_pods)
        self.max_pods = int(max_pods) if max_pods else max(pods, min_pods)
        self.vnodes = int(vnodes)
        self.heartbeat_every = int(heartbeat_every)
        self.miss_limit = int(miss_limit)
        self.hb_timeout = float(hb_timeout)
        self.rpc_timeout = float(rpc_timeout)
        self.boot_timeout = float(boot_timeout)
        self.scale_up_tokens = scale_up_tokens
        self.scale_idle_ticks = scale_idle_ticks
        self.wall_clock = bool(wall_clock)
        self.router_id = f"fabric-{uuid.uuid4().hex[:8]}"
        self.tick = 0
        self._state_tick = -self.STATE_EVERY
        self._ordinal = 0
        self._idle_streak = 0
        self.members: dict[str, FabricMember] = {}
        self._ring: list[tuple[int, str]] = []
        self._ring_keys: list[int] = []
        self._staged: list[GenRequest] = []
        self._reroute: deque[tuple[GenRequest, str]] = deque()
        # loopback only: evicted/retired workers' span buffers, retained
        # so the fleet closure check sees terminals recorded before the
        # death (proc workers persist the same spans as FILES at each
        # heartbeat -- this is the in-process analog, not extra state)
        self._dead_buffers: list[TraceBuffer] = []
        self.completed: list[GenRequest] = []
        self.rejected: list[GenRequest] = []
        self.shedded: list[GenRequest] = []
        self.metrics = MetricsRegistry()
        self.trace = TraceBuffer(name=self.router_id)
        self._c_routed = self.metrics.counter("routed", policy=policy)
        self._c_spilled = self.metrics.counter("spillover", policy=policy)
        self._c_rejected = self.metrics.counter("rejected", policy=policy)
        self._c_req_rejected = self.metrics.counter("requests_rejected")
        self._c_shed = self.metrics.counter("shed", policy=policy)
        self._c_req_shed = self.metrics.counter("requests_shed")
        self._c_heartbeats = self.metrics.counter("fabric_heartbeats")
        self._c_evictions = self.metrics.counter("fabric_evictions")
        self._c_reroutes = self.metrics.counter("fabric_reroutes")
        self._c_spawned = self.metrics.counter("fabric_pods_spawned")
        self._c_retired = self.metrics.counter("fabric_pods_retired")
        # span files are per-FLEET state: wipe this fleet's leftovers from
        # a previous run in the same root, or a stale router file's routes
        # (whose terminals lived in since-overwritten worker files) would
        # fail the closure check. Concurrent fleets in one root must use
        # distinct ``fleet`` names.
        spans_dir = Path(self.runtime.root) / SPAN_DIR
        if spans_dir.exists():
            for p in spans_dir.glob(f"{self.fleet}-*.spans.json"):
                p.unlink()
        # boot the initial fleet: spawn all transports first (worker
        # processes import/build in parallel), then handshake each
        fresh = [self._new_member() for _ in range(int(pods))]
        for m in fresh:
            m.transport.send({"t": "hello"})
        for m in fresh:
            self._handshake(m)
        self._rebuild_ring()
        self.write_state()

    # -- membership ----------------------------------------------------------
    def _now(self) -> float | None:
        return time.time() if self.wall_clock else None

    def _new_member(self) -> FabricMember:
        pod_id = f"{self.fleet}-{self._ordinal}"
        m = FabricMember(pod_id, self._ordinal,
                         self.spawn(pod_id, self.tick))
        self._ordinal += 1
        self.members[pod_id] = m
        self._c_spawned.inc()
        return m

    def _handshake(self, m: FabricMember) -> None:
        ready = None
        while ready is None:
            reply = m.transport.recv(self.boot_timeout)
            if reply is None:
                break
            if reply.get("t") == "ready" and reply.get("pod") == m.pod_id:
                ready = reply
        if ready is None:
            raise RuntimeError(
                f"fabric member {m.pod_id} never answered hello "
                f"(boot timeout {self.boot_timeout}s)")
        m.caps = ready["caps"]
        m.tick = ready["tick"]

    def _spawn_member(self) -> FabricMember:
        m = self._new_member()
        m.transport.send({"t": "hello"})
        self._handshake(m)
        self._rebuild_ring()
        self.write_state()
        return m

    def _rebuild_ring(self) -> None:
        ring = [(_hash64(f"{pod_id}#{v}"), pod_id)
                for pod_id in self.members for v in range(self.vnodes)]
        self._ring = sorted(ring, key=lambda t: t[0])
        self._ring_keys = [h for h, _ in self._ring]

    def drain_pod(self, pod_id: str) -> None:
        """Route new traffic around a member; its in-flight work finishes
        normally. The retire path (elastic scale-down) goes through here
        first, mirroring ``PodRouter.drain_pod``."""
        self.members[pod_id].draining = True
        self.write_state()

    def undrain_pod(self, pod_id: str) -> None:
        self.members[pod_id].draining = False
        self.write_state()

    # -- rpc ------------------------------------------------------------------
    def _rpc(self, m: FabricMember, msg: dict, want: str,
             timeout: float) -> dict | None:
        """Send + await the matching reply. Stale frames from an earlier
        timed-out exchange are not lost: late ``events`` are applied (the
        token stream must never drop), anything else is drained."""
        try:
            m.transport.send(msg)
        except (BrokenPipeError, OSError):
            return None
        while True:
            reply = m.transport.recv(timeout)
            if reply is None:
                return None
            if reply.get("pod") != m.pod_id:
                continue
            if reply.get("t") == want:
                return reply
            if reply.get("t") == "events":
                self.completed.extend(self._apply_events(m, reply))

    # -- heartbeats + eviction ------------------------------------------------
    def _heartbeat_all(self) -> None:
        for m in list(self.members.values()):
            if not m.alive:
                continue
            beat = self._rpc(m, {"t": "hb", "tick": self.tick}, "beat",
                             self.hb_timeout)
            if beat is None:
                m.missed += 1
                continue
            m.missed = 0
            m.last_beat = self.tick
            m.last_wall = beat.get("wall")
            m.tick = beat["tick"]
            m.pending = beat["pending"]
            m.active = beat["active"]
            m.metrics_snapshot = beat.get("metrics")
            self._c_heartbeats.inc()
            self.trace.record(m.srid, "heartbeat", self.tick,
                              wall=self._now(), pod=m.pod_id,
                              pending=m.pending, active=m.active)

    def _evict_dead(self) -> None:
        for m in list(self.members.values()):
            if not m.alive or m.missed >= self.miss_limit:
                self._evict(m)

    def _evict(self, m: FabricMember) -> None:
        """Remove a dead member from ring + ledger and queue its in-flight
        requests for exactly-once re-routing to survivors."""
        self.trace.record(m.srid, "evict", self.tick, wall=self._now(),
                          pod=m.pod_id, missed=m.missed,
                          inflight=len(m.assigned),
                          outstanding=m.outstanding)
        self._c_evictions.inc()
        self._keep_buffer(m)
        del self.members[m.pod_id]
        self._rebuild_ring()
        m.transport.kill()
        for rid in sorted(m.assigned):
            req = m.assigned[rid]
            req.reroutes += 1
            req.pod = req.replica = None
            req.slot = None
            # committed tokens -> the survivor resumes via suffix
            # re-prefill; nothing committed -> plain re-submission
            req.state = "preempted" if req.tokens else "queued"
            self._reroute.append((req, m.pod_id))
        m.assigned.clear()
        m.outstanding = 0
        self.write_state()

    # -- elastic fleet --------------------------------------------------------
    def _autoscale(self) -> None:
        live = [m for m in self.members.values() if not m.draining]
        # heal: never serve below the floor (or with zero routable pods)
        while len(self.members) < self.min_pods or not live:
            live.append(self._spawn_member())
        arrived = sum(r.max_new_tokens for r in self._staged
                      if r.arrival <= self.tick)
        backlog = sum(m.outstanding for m in live) + arrived \
            + sum(r.max_new_tokens for r, _ in self._reroute)
        if (self.scale_up_tokens and len(self.members) < self.max_pods
                and backlog > self.scale_up_tokens * len(live)):
            self._spawn_member()
        if backlog == 0 and not self._staged:
            self._idle_streak += 1
        else:
            self._idle_streak = 0
        if (self.scale_idle_ticks
                and self._idle_streak >= self.scale_idle_ticks
                and len(self.members) > self.min_pods):
            victim = max((m for m in self.members.values()
                          if not m.draining), default=None,
                         key=lambda m: m.ordinal)
            if victim is not None:
                self.drain_pod(victim.pod_id)
        for m in list(self.members.values()):
            if m.draining and not m.assigned \
                    and len(self.members) > self.min_pods:
                self._retire(m)

    def _retire(self, m: FabricMember) -> None:
        """Graceful scale-down: final state/span flush, then goodbye."""
        self._rpc(m, {"t": "retire"}, "bye", self.hb_timeout)
        m.transport.close()
        self._keep_buffer(m)
        del self.members[m.pod_id]
        self._rebuild_ring()
        self._c_retired.inc()
        self.write_state()

    # -- placement ------------------------------------------------------------
    @staticmethod
    def _cap_fits(cap: dict, req: GenRequest) -> bool:
        """Remote ``SlotEngine.fits``, answered from the capability
        descriptor the worker sent at hello."""
        if req.frontend is not None:
            if not cap["fe_len"] or req.frontend_len > cap["fe_len"] \
                    or req.frontend.shape[1] != cap["d_model"]:
                return False
        span = cap["fe_len"] + req.prompt_len + req.max_new_tokens
        if span + cap["chunk"] > cap["max_len"]:
            return False
        if cap["paged"]:
            pages = -(-(span + cap["chunk"]) // cap["page_size"])
            if pages > cap["capacity"]:
                return False
        return True

    def _member_fits(self, m: FabricMember, req: GenRequest) -> bool:
        return m.alive and any(self._cap_fits(c, req) for c in m.caps)

    def _candidates(self, req: GenRequest) -> list[FabricMember]:
        if self.policy == "consistent-hash":
            i = (bisect.bisect_right(self._ring_keys,
                                     _hash64(f"rid:{req.rid}"))
                 if self._ring else 0)
            order, seen = [], set()
            for k in range(len(self._ring)):
                pod_id = self._ring[(i + k) % len(self._ring)][1]
                if pod_id not in seen:
                    seen.add(pod_id)
                    order.append(self.members[pod_id])
                    if len(order) == len(self.members):
                        break
        else:
            order = sorted(self.members.values(),
                           key=lambda m: (m.outstanding, m.ordinal))
        return ([m for m in order if not m.draining]
                + [m for m in order if m.draining])

    def _route_one(self, req: GenRequest, src: str | None) -> None:
        order = self._candidates(req)
        chosen = next((m for m in order if self._member_fits(m, req)),
                      None)
        if chosen is None:
            req.state, req.finish_reason = "rejected", "oversized"
            req.error = "no fabric member can ever fit this request"
            req.done_tick = self.tick
            self.rejected.append(req)
            self._c_rejected.inc()
            self._c_req_rejected.inc()
            self.trace.record(req.rid, "reject", self.tick,
                              wall=self._now(), reason="infeasible",
                              policy=self.policy)
            return
        req.pod = chosen.pod_id
        if src is None:
            req.spilled = chosen is not order[0]
            if req.spilled:
                self._c_spilled.inc()
            self._c_routed.inc()
            self.trace.record(req.rid, "route", self.tick,
                              wall=self._now(), pod=chosen.pod_id,
                              policy=self.policy, spilled=req.spilled)
        else:
            self._c_reroutes.inc()
            self.trace.record(req.rid, "reroute", self.tick,
                              wall=self._now(), src=src,
                              pod=chosen.pod_id,
                              tokens_done=len(req.tokens))
        try:
            chosen.transport.send({"t": "submit",
                                   "req": encode_request(req)})
        except (BrokenPipeError, OSError):
            # died between probe and placement: park the request for the
            # next pass, the eviction sweep will reclaim the member
            req.reroutes += 1
            self._reroute.append((req, chosen.pod_id))
            return
        chosen.assigned[req.rid] = req
        chosen.outstanding += req.max_new_tokens

    def _route_staged(self) -> None:
        work: list[tuple[GenRequest, str | None]] = []
        while self._reroute:
            work.append(self._reroute.popleft())
        still: list[GenRequest] = []
        for req in self._staged:
            if req.arrival <= self.tick:
                work.append((req, None))
            else:
                still.append(req)
        self._staged = still
        for req, src in work:
            self._route_one(req, src)

    # -- submit / step / run --------------------------------------------------
    def submit(self, reqs: Iterable[GenRequest] | GenRequest) -> None:
        """Stage requests for routing; placement happens at the tick
        their ``arrival`` is due, against the LIVE membership -- a pod
        spawned by scale-up takes arrivals a static router would have
        piled onto the original fleet."""
        if isinstance(reqs, GenRequest):
            reqs = [reqs]
        self._staged.extend(reqs)

    def _step_all(self) -> list[GenRequest]:
        done: list[GenRequest] = []
        for m in sorted(self.members.values(), key=lambda m: m.ordinal):
            if not m.alive:
                continue
            msg = {"t": "step", "n": 1, "ack": sorted(m.to_ack)}
            m.to_ack.clear()
            r = self._rpc(m, msg, "events", self.rpc_timeout)
            if r is None:
                m.missed += 1
                continue
            m.missed = 0
            m.tick = r["tick"]
            m.pending = r["pending"]
            m.active = r["active"]
            done.extend(self._apply_events(m, r))
        return done

    def _apply_events(self, m: FabricMember, r: dict) -> list[GenRequest]:
        """Fold one worker's event stream into the caller's request
        objects: append streamed tokens (the router's view IS the
        fleet's committed state -- what a survivor resumes from), stamp
        first admissions, finalize terminal requests and settle the
        outstanding-token ledger."""
        for rid_s in sorted(r["toks"], key=int):
            req = m.assigned.get(int(rid_s))
            if req is not None:
                req.tokens.extend(int(t) for t in r["toks"][rid_s])
        for rid, adm in r["adm"]:
            req = m.assigned.get(int(rid))
            if req is not None and req.admit_tick < 0:
                req.admit_tick = int(adm)
        finished: list[GenRequest] = []
        for fin in r["done"]:
            # at-least-once finals: ack every delivery (the worker keeps
            # re-sending until acked) and apply each rid exactly once
            m.to_ack.add(int(fin["rid"]))
            req = m.assigned.pop(int(fin["rid"]), None)
            if req is None:
                continue
            m.outstanding -= req.max_new_tokens
            req.tokens[:] = [int(t) for t in fin["tokens"]]
            req.state = fin["state"]
            req.finish_reason = fin["finish_reason"]
            req.error = fin["error"]
            req.admit_tick = int(fin["admit_tick"])
            req.done_tick = int(fin["done_tick"])
            req.replica = fin["replica"]
            req.slot = fin["slot"]
            req.preemptions = int(fin["preemptions"])
            if req.state == "done":
                finished.append(req)
            elif req.state == "rejected":
                self.rejected.append(req)
                self._c_rejected.inc()
                self._c_req_rejected.inc()
            elif req.state == "shed":
                self.shedded.append(req)
                self._c_shed.inc()
                self._c_req_shed.inc()
        return finished

    def step(self) -> list[GenRequest]:
        """One fleet tick: probe -> evict -> heal/scale -> route -> fan
        the tick out and fold the event streams back."""
        if self.heartbeat_every and self.tick % self.heartbeat_every == 0:
            self._heartbeat_all()
        self._evict_dead()
        self._autoscale()
        self._route_staged()
        done = self._step_all()
        self.completed.extend(done)
        self.tick += 1
        # unconditional cadence (not activity-gated like PodRouter): a
        # live `repro top --watch` must see the fleet move even when no
        # request completed this window
        if self.tick - self._state_tick >= self.STATE_EVERY:
            self.write_state()
            self._state_tick = self.tick
        return done

    @property
    def busy(self) -> bool:
        return bool(self._staged or self._reroute
                    or any(m.assigned for m in self.members.values()))

    def run(self, max_ticks: int | None = None) -> list[GenRequest]:
        start = self.tick
        while self.busy:
            if max_ticks is not None and self.tick - start >= max_ticks:
                break
            self.step()
        self.write_state()
        return self.completed

    def close(self) -> None:
        """Graceful shutdown: retire every member (final state + span
        flush on each), then stamp the router's own terminal state."""
        for m in sorted(self.members.values(), key=lambda m: m.ordinal):
            if m.alive:
                self._rpc(m, {"t": "retire"}, "bye", self.hb_timeout)
            m.transport.close()
            self._keep_buffer(m)
        self.members.clear()
        self._rebuild_ring()
        self.write_state(final=True)

    # -- accounting / state ---------------------------------------------------
    @property
    def outstanding_total(self) -> int:
        """Ledger sum: token budgets routed and not yet finished. After a
        drained run this is exactly 0 -- the conservation invariant the
        ledger regression test pins."""
        return sum(m.outstanding for m in self.members.values())

    @property
    def capacity(self) -> int:
        return sum(m.capacity for m in self.members.values()
                   if not m.draining)

    @property
    def live(self) -> int:
        return sum(1 for m in self.members.values() if m.alive)

    @property
    def pending(self) -> int:
        return (len(self._staged) + len(self._reroute)
                + sum(m.pending for m in self.members.values()))

    def _keep_buffer(self, m: FabricMember) -> None:
        w = getattr(m.transport, "worker", None)
        if w is not None:
            self._dead_buffers.append(w.pod.trace)

    def trace_buffers(self) -> list[TraceBuffer]:
        """Router buffer + every LOCAL (loopback) worker's pod buffer,
        including evicted/retired members'. Proc-mode worker spans live
        in their span files instead -- see ``load_fleet_spans``."""
        out = [self.trace]
        for m in sorted(self.members.values(), key=lambda m: m.ordinal):
            w = getattr(m.transport, "worker", None)
            if w is not None:
                out.append(w.pod.trace)
        return out + list(self._dead_buffers)

    def status(self) -> dict:
        return {
            "kind": "router",
            "router": self.router_id,
            "fabric": {
                "fleet": self.fleet,
                "live": self.live,
                "min_pods": self.min_pods,
                "max_pods": self.max_pods,
                "heartbeat_every": self.heartbeat_every,
                "miss_limit": self.miss_limit,
                "evictions": self._c_evictions.value,
                "reroutes": self._c_reroutes.value,
                "spawned": self._c_spawned.value,
                "retired": self._c_retired.value,
            },
            "policy": self.policy,
            "pods": list(self.members),
            "draining": sorted(m.pod_id for m in self.members.values()
                               if m.draining),
            "capacity": self.capacity,
            "free_slots": max(
                0, self.capacity - sum(m.active
                                       for m in self.members.values())),
            "pending": self.pending,
            "routed": self._c_routed.value,
            "spilled": self._c_spilled.value,
            "completed": len(self.completed),
            "rejected": len(self.rejected),
            "shed": len(self.shedded),
            "by_policy": {self.policy: {
                "routed": self._c_routed.value,
                "spillover": self._c_spilled.value,
                "rejected": self._c_rejected.value,
                "shed": self._c_shed.value,
            }},
            "metrics": merge_snapshots(
                [self.metrics.snapshot()]
                + [m.metrics_snapshot for m in self.members.values()
                   if m.metrics_snapshot]),
            "trace": self.trace.status(),
            "pid": os.getpid(),
            "members": [{
                "pod": m.pod_id,
                "live": m.alive,
                "missed": m.missed,
                "last_beat": m.last_beat,
                "last_wall": m.last_wall,
                "worker_pid": m.transport.pid,
                "capacity": m.capacity,
                "outstanding": m.outstanding,
                "inflight": len(m.assigned),
                "pending": m.pending,
                "active": m.active,
                "draining": m.draining,
            } for m in sorted(self.members.values(),
                              key=lambda m: m.ordinal)],
        }

    def write_state(self, final: bool = False) -> Path:
        """Same dir + atomic protocol as ``Pod.write_state``; also
        flushes the router's span file so the cross-process closure
        check always has the router-tier half."""
        d = Path(self.runtime.root) / "pods"
        d.mkdir(parents=True, exist_ok=True)
        p = d / f"{self.router_id}.json"
        status = self.status()
        status["phase"] = ("exited" if final
                          else "serving" if any(
                              m.active for m in self.members.values())
                          else "idle")
        tmp = p.with_suffix(".tmp")
        tmp.write_text(json.dumps(status, indent=2))
        os.replace(tmp, p)
        dump_span_log(self.trace,
                      span_path(self.runtime.root,
                                f"{self.fleet}-router"))
        return p


def load_fleet_spans(root, fleet: str | None = None) -> list[TraceBuffer]:
    """Every per-process span file under ``<root>/spans/`` (router's own
    included), rehydrated -- the input to ``validate_fleet_closure`` for
    a proc-mode run. ``fleet`` narrows to one fleet's files (worker files
    are ``<fleet>-<ordinal>``, the router's is ``<fleet>-router``)."""
    from repro.orchestrator.obs.tracing import load_span_log
    d = Path(root) / SPAN_DIR
    if not d.exists():
        return []
    pat = f"{fleet}-*.spans.json" if fleet else "*.spans.json"
    return [load_span_log(p) for p in sorted(d.glob(pat))]


# -- worker entry point -------------------------------------------------------

def worker_main(cfg: dict) -> int:
    """Body of a worker subprocess: resolve the image (content-addressed
    rebuild of the imagefile, or a registry pull -- either way the digest
    the parent serves), serve the pod, answer frames on stdin until
    retire/EOF."""
    from repro.core.runtime import Runtime
    rt = Runtime(cfg["root"])
    image = (rt.build(cfg["imagefile"]) if cfg.get("imagefile")
             else rt.pull(cfg["ref"]))
    worker = PodWorker(rt, image, pod_id=cfg["pod_id"],
                       start_tick=int(cfg.get("start_tick", 0)),
                       fairness_cap=int(cfg.get("fairness_cap", 4)),
                       pod_kwargs=cfg.get("pod") or {},
                       wall_clock=True)
    out = sys.stdout.buffer
    for raw in sys.stdin.buffer:
        msg = decode_frame(raw)
        if msg is None:
            continue
        reply = worker.handle(msg)
        if reply is not None:
            out.write(encode_frame(reply))
            out.flush()
        if msg.get("t") == "retire":
            return 0
    # EOF without retire: the router went away; flush and exit cleanly
    worker.flush()
    return 0


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="repro.orchestrator.fabric")
    ap.add_argument("--worker", action="store_true",
                    help="run as a pod worker (stdin/stdout frames)")
    ap.add_argument("--config", required=True,
                    help="worker config JSON (root, imagefile, pod_id, "
                         "start_tick, fairness_cap, pod kwargs)")
    args = ap.parse_args(argv)
    if not args.worker:
        ap.error("only --worker mode is runnable; the router side is "
                 "driven by serve/benchmarks")
    return worker_main(json.loads(args.config))


if __name__ == "__main__":
    raise SystemExit(main())
