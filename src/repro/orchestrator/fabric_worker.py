"""Entry point for fabric worker processes.

A separate module (rather than ``-m repro.orchestrator.fabric``) because
the orchestrator package imports :mod:`repro.orchestrator.fabric` at
init: executing that same module as ``__main__`` would shadow it in
``sys.modules`` and trip runpy's double-import warning. This shim is
imported by nothing, so it is always clean to run::

    python -m repro.orchestrator.fabric_worker --worker --config '<json>'
"""

from repro.orchestrator.fabric import main

if __name__ == "__main__":
    raise SystemExit(main())
