"""PrefixRadix: a radix tree over page-aligned token blocks, plus the
host-RAM spill tier behind it.

This is the container-registry model applied to KV pages, one level down
from the flat prefix index it replaces. A container image is a stack of
content-addressed layers; N images sharing a base store its layers once,
and a registry pull re-materializes an evicted layer by digest. Here:

  * one radix NODE = one page-size token block, keyed by a CHAINED digest
    (``md5(parent_digest + block_bytes)``) -- the same scheme image
    manifests use, so a node's digest commits to its whole ancestry and
    two different paths can never alias;
  * a request's declared prefix walks the tree root-down
    (``PrefixRadix.match``): every fully-matched node is a shared layer,
    and when the declared prefix ends MID-block the walk finishes with a
    partial in-node match -- the first ``partial_len`` tokens of some
    registered child. KV at those positions depends only on the (identical)
    preceding tokens, so the boundary page can be merged read-only into the
    new request's first private page (the front-partial COW merge);
  * eviction under pool pressure prefers SPILL over discard: the page's
    contents move to the host-RAM ``SpillStore`` keyed by node digest, the
    device page returns to the free-list, and the node stays in the tree
    with ``page=None``. A later match "pulls" the layer back by digest
    (``PagePool`` restore) instead of re-prefilling it.

Tree invariants (``PagePool.check`` enforces them after every op in the
property tests):

  * a resident node's parent is resident (the resident subtree is rooted),
    so a chain restore is always parents-first and a spilled interior node
    never strands live descendants on device;
  * sum of child refcounts <= parent refcount (every sharer maps its whole
    root chain, sharers of different children are disjoint);
  * spilled nodes hold no device page and exactly mirror the spill store
    (conservation across tiers).

The tree itself is pure host bookkeeping -- it never touches a device
buffer. ``PagePool`` owns the page/refcount accounting and the actual
spill/restore data movement; ``SlotEngine`` registers the device-side
save/load callbacks.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np


def chained_digest(parent_digest: str, block: np.ndarray) -> str:
    """Content address of one page block GIVEN its ancestry: the parent's
    digest is folded into the hash, so equal blocks under different
    prefixes get different digests (exactly how image-layer chain ids
    work). Root ancestry is the empty string."""
    block = np.ascontiguousarray(np.asarray(block, np.int32))
    return hashlib.md5(parent_digest.encode() + block.tobytes()).hexdigest()


def block_digests(tokens: np.ndarray, page_size: int) -> list[str]:
    """Chained digests of every COMPLETE page block of ``tokens`` (the
    trailing partial block has no digest -- partial matches compare tokens
    directly). Shared by the pool (tree keys), the engine (promotion) and
    the router (family-anchor keys), so all three tiers address the same
    layer the same way."""
    tokens = np.asarray(tokens, np.int32)
    out: list[str] = []
    parent = ""
    for i in range(len(tokens) // page_size):
        parent = chained_digest(parent, tokens[i * page_size:
                                               (i + 1) * page_size])
        out.append(parent)
    return out


@dataclass
class RadixNode:
    """One page-aligned block in the prefix tree. ``page`` is the physical
    device page when resident, ``None`` while spilled to the host tier.
    Refcounts live in the pool's per-page array (single source of truth);
    a spilled node by construction has no sharers."""
    digest: str
    tokens: np.ndarray                  # (page_size,) int32 block
    parent: "RadixNode | None"
    depth: int                          # blocks from root (root = 0)
    children: dict[str, "RadixNode"] = field(default_factory=dict)
    page: int | None = None
    last_used: int = 0
    hits: int = 0

    @property
    def resident(self) -> bool:
        return self.page is not None

    def chain(self) -> list["RadixNode"]:
        """Root-first path from the tree root to this node (exclusive of
        the sentinel root)."""
        out: list[RadixNode] = []
        node = self
        while node.parent is not None:
            out.append(node)
            node = node.parent
        out.reverse()
        return out


@dataclass
class PrefixMatch:
    """Longest registered ancestry of a declared prefix: ``nodes`` are the
    fully-matched blocks root-first; ``partial`` is the boundary node whose
    first ``partial_len`` tokens extend the match mid-block (merge
    operand), or None when the boundary is page-aligned."""
    nodes: list[RadixNode]
    partial: RadixNode | None = None
    partial_len: int = 0

    @property
    def tokens_matched(self) -> int:
        ps = len(self.nodes[0].tokens) if self.nodes else (
            len(self.partial.tokens) if self.partial else 0)
        return len(self.nodes) * ps + self.partial_len

    def all_nodes(self) -> list[RadixNode]:
        """Chain plus the partial boundary node (everything that must be
        device-resident before the suffix prefill reads the pool)."""
        return self.nodes + ([self.partial] if self.partial else [])


class SpillStore:
    """Host-RAM tier of the page registry: evicted node payloads keyed by
    digest, LRU-ordered. ``capacity`` bounds resident payloads (None =
    unbounded); the POOL enforces it -- dropping a payload may require
    pruning a whole spilled subtree, which needs tree context this store
    does not have."""

    def __init__(self, capacity: int | None = None):
        if capacity is not None and capacity < 0:
            raise ValueError("SpillStore capacity must be >= 0 or None")
        self.capacity = capacity
        self._data: OrderedDict[str, object] = OrderedDict()

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, digest: str) -> bool:
        return digest in self._data

    @property
    def over_capacity(self) -> int:
        """Payloads beyond capacity (0 when unbounded or within bounds)."""
        if self.capacity is None:
            return 0
        return max(0, len(self._data) - self.capacity)

    def put(self, digest: str, payload) -> None:
        if digest in self._data:
            raise RuntimeError(f"spill store already holds {digest!r}")
        self._data[digest] = payload

    def pop(self, digest: str):
        """Remove and return a payload (the restore path)."""
        return self._data.pop(digest)

    def discard(self, digest: str) -> None:
        self._data.pop(digest, None)

    def lru_digests(self) -> list[str]:
        """Digests oldest-first (insertion order = spill order; restores
        pop, so re-spills re-insert at the young end)."""
        return list(self._data.keys())

    def digests(self) -> set[str]:
        return set(self._data.keys())


class PrefixRadix:
    """The tree structure itself: match/insert/remove plus deterministic
    victim ordering. Pure host bookkeeping -- pages, refcounts and the
    spill data movement belong to ``PagePool``."""

    def __init__(self, page_size: int):
        self.page_size = int(page_size)
        self.root = RadixNode(digest="", tokens=np.empty(0, np.int32),
                              parent=None, depth=0)
        self.node_count = 0

    # -- lookup -------------------------------------------------------------
    def match(self, tokens: np.ndarray) -> PrefixMatch:
        """Longest-prefix walk: consume whole page blocks while a child
        with the chained digest AND byte-identical tokens exists (a digest
        collision over different tokens stops the walk -- a miss at that
        depth, never a wrong share). Leftover tokens (< one page) try a
        PARTIAL in-node match against the children at the boundary;
        resident children win over spilled ones (no restore needed), ties
        break on digest so the choice is deterministic."""
        tokens = np.asarray(tokens, np.int32)
        ps = self.page_size
        nodes: list[RadixNode] = []
        cur = self.root
        k = 0
        while (k + 1) * ps <= len(tokens):
            block = tokens[k * ps:(k + 1) * ps]
            child = cur.children.get(chained_digest(cur.digest, block))
            if child is None or not np.array_equal(child.tokens, block):
                break
            nodes.append(child)
            cur = child
            k += 1
        rem = tokens[k * ps:]
        partial, plen = None, 0
        if len(rem) >= 1 and len(rem) < ps:
            for digest in sorted(cur.children,
                                 key=lambda d: (not cur.children[d].resident,
                                                d)):
                child = cur.children[digest]
                if np.array_equal(child.tokens[:len(rem)], rem):
                    partial, plen = child, len(rem)
                    break
        return PrefixMatch(nodes=nodes, partial=partial, partial_len=plen)

    # -- structure ----------------------------------------------------------
    def insert(self, parent: RadixNode, block: np.ndarray,
               page: int) -> RadixNode | None:
        """Register one complete block as a child of ``parent``. Returns
        None on a digest collision (an existing child under the digest
        with DIFFERENT tokens): first writer wins, the new block simply
        stays uncached -- the tree is never corrupted."""
        block = np.asarray(block, np.int32)
        if block.shape != (self.page_size,):
            raise ValueError(f"block must be exactly {self.page_size} "
                             f"tokens, got {block.shape}")
        digest = chained_digest(parent.digest, block)
        existing = parent.children.get(digest)
        if existing is not None:
            return None
        node = RadixNode(digest=digest, tokens=np.array(block, copy=True),
                         parent=parent, depth=parent.depth + 1, page=page)
        parent.children[digest] = node
        self.node_count += 1
        return node

    def remove(self, node: RadixNode) -> None:
        """Unlink a childless node (eviction discards leaf-first)."""
        if node.children:
            raise RuntimeError("removing a radix node with children")
        del node.parent.children[node.digest]
        node.parent = None
        self.node_count -= 1

    # -- iteration (deterministic order everywhere) -------------------------
    def walk(self) -> list[RadixNode]:
        """Every node, depth-first with children in digest order --
        deterministic for eviction scans and ``check``."""
        out: list[RadixNode] = []
        stack = [self.root.children[d]
                 for d in sorted(self.root.children, reverse=True)]
        while stack:
            node = stack.pop()
            out.append(node)
            stack.extend(node.children[d]
                         for d in sorted(node.children, reverse=True))
        return out

    def subtree(self, node: RadixNode) -> list[RadixNode]:
        """``node`` and every descendant, deepest-last."""
        out = [node]
        stack = [node.children[d]
                 for d in sorted(node.children, reverse=True)]
        while stack:
            n = stack.pop()
            out.append(n)
            stack.extend(n.children[d] for d in sorted(n.children,
                                                       reverse=True))
        return out

    @property
    def max_depth(self) -> int:
        return max((n.depth for n in self.walk()), default=0)

    def check(self) -> None:
        """Structural invariants of the tree alone (the pool layers page
        and refcount conservation on top): parent links consistent, chained
        digests honest, depths correct, resident subtree rooted."""
        seen = 0
        for node in self.walk():
            seen += 1
            assert node.parent is not None, "walked node lost its parent"
            assert node.parent.children.get(node.digest) is node, \
                "parent/child link broken"
            assert node.depth == node.parent.depth + 1, "depth drift"
            assert node.digest == chained_digest(node.parent.digest,
                                                 node.tokens), \
                "stored digest does not match chained content"
            if node.resident:
                assert node.parent is self.root or node.parent.resident, \
                    f"resident node {node.digest[:8]} under spilled parent"
        assert seen == self.node_count, "node_count drift"
