"""stablelm-3b: LayerNorm, MHA (kv=32), partial rotary 25%.

[hf:stabilityai/stablelm-2-1_6b; unverified] 32L d_model=2560 32H (kv=32)
d_ff=6912 vocab=50304.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50_304,
    mlp="swiglu",
    norm="layernorm",
    rope_theta=10_000.0,
    rope_pct=0.25,
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
)
