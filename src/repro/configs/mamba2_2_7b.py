"""mamba2-2.7b: attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified] 64L d_model=2560, d_inner=5120 (expand 2),
headdim 64 (80 ssm heads), state 128, vocab 50280.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    norm="rmsnorm",
    tie_embeddings=True,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    conv_kernel=4,
    source="arXiv:2405.21060; unverified",
)
