"""nemotron-4-15b: dense, GQA, squared-ReLU MLP, partial rotary 50%.

[arXiv:2402.16819; unverified] 32L d_model=6144 48H (kv=8) d_ff=24576
vocab=256000.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256_000,
    mlp="relu2",
    norm="layernorm",
    rope_theta=10_000.0,
    rope_pct=0.5,
    source="arXiv:2402.16819; unverified",
)
