"""Architecture config registry: one module per assigned architecture."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, ShapeCell, SHAPE_CELLS, get_shape_cell

ARCH_IDS = (
    "recurrentgemma-2b",
    "deepseek-67b",
    "nemotron-4-15b",
    "llama3.2-3b",
    "stablelm-3b",
    "llama4-scout-17b-a16e",
    "moonshot-v1-16b-a3b",
    "mamba2-2.7b",
    "musicgen-medium",
    "internvl2-2b",
)

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str, **overrides) -> ModelConfig:
    if arch.endswith("-smoke"):
        return get_config(arch[: -len("-smoke")], **overrides).reduced()
    if arch not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch]}")
    cfg: ModelConfig = mod.CONFIG
    return cfg.with_overrides(**overrides) if overrides else cfg


def list_archs() -> tuple[str, ...]:
    return ARCH_IDS


__all__ = ["ARCH_IDS", "get_config", "list_archs", "ModelConfig", "ShapeCell",
           "SHAPE_CELLS", "get_shape_cell"]
