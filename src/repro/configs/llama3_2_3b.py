"""llama3.2-3b: small llama3, tied embeddings.

[hf:meta-llama/Llama-3.2-1B; unverified] 28L d_model=3072 24H (kv=8)
d_ff=8192 vocab=128256.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128_256,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=500_000.0,
    tie_embeddings=True,
    source="hf:meta-llama/Llama-3.2-1B; unverified",
)
