"""recurrentgemma-2b: RG-LRU + local attention hybrid, 2:1 cycle.

[arXiv:2402.19427; hf] -- Griffin architecture, 26L d_model=2560, 10 heads
(MQA kv=1, head_dim 256), GeGLU d_ff=7680, vocab 256000, window 2048.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    mlp="geglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
    embed_scale=True,
    attn_kind="local",
    window=2048,
    block_pattern=("rec", "rec", "attn"),
    rnn_width=2560,
    conv_kernel=4,
    source="arXiv:2402.19427; hf",
)
