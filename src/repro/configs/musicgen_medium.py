"""musicgen-medium: decoder-only over EnCodec tokens; audio frontend is a
stub providing precomputed frame embeddings (per assignment).

[arXiv:2306.05284; hf] 48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    mlp="gelu",
    norm="layernorm",
    rope_theta=10_000.0,
    frontend="audio",
    frontend_len=64,
    source="arXiv:2306.05284; hf",
)
