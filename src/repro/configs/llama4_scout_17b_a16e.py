"""llama4-scout-17b-a16e: MoE 16 experts top-1 + shared expert, every layer.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] 48L d_model=5120 40H (kv=8)
expert d_ff=8192 vocab=202048.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=500_000.0,
    n_experts=16,
    top_k=1,
    n_shared_experts=1,
    moe_every=1,
    moe_d_ff=8192,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
