"""moonshot-v1-16b-a3b: Moonlight-style fine-grained MoE, 64e top-6,
2 shared experts, first layer dense.

[hf:moonshotai/Moonlight-16B-A3B; hf] 48L d_model=2048 16H (kv=16)
expert d_ff=1408 vocab=163840.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163_840,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=50_000.0,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    first_k_dense=1,
    moe_every=1,
    moe_d_ff=1408,
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
)
