"""internvl2-2b: InternLM2 decoder backbone; InternViT vision frontend is a
stub providing precomputed patch embeddings (per assignment).

[arXiv:2404.16821; hf] 24L d_model=2048 16H (kv=8) d_ff=8192 vocab=92553.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92_553,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    frontend="vision",
    frontend_len=256,
    source="arXiv:2404.16821; hf",
)
