"""Blocked matmul Pallas TPU kernel -- the repo's HPGMG-FE analog.

The paper uses HPGMG-FE (a highly tuned, AVX-dependent benchmark) to prove
containers do not eat tuned-kernel performance (their Fig. 5) and to make
the point that host-specific codegen must happen at run time, not bake time.
This kernel plays that role here: a hand-blocked MXU matmul whose block
table is selected per PLATFORM at container-run time (core/container binds
it), never baked into the image.

Schedule: grid (M/bm, N/bn, K/bk), K innermost; f32 accumulator in VMEM
scratch across K steps; A/B tiles stream through the implicit Pallas
double-buffered pipeline. Blocks default to 512x512x512:
  A 512x512x2B + B 512x512x2B + acc 512x512x4B = 2 MiB (+ double buffering)
against ~16 MiB v5e VMEM; all dims multiples of the 128x128 MXU tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_scr, *, n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _store():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def matmul_pallas(a: jax.Array, b: jax.Array, *,
                  block_m: int = 512, block_n: int = 512, block_k: int = 512,
                  interpret: bool = False) -> jax.Array:
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    block_m, block_n, block_k = (min(block_m, M), min(block_n, N),
                                 min(block_k, K))
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    grid = (M // block_m, N // block_n, K // block_k)
    kernel = functools.partial(_mm_kernel, n_k=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b)
