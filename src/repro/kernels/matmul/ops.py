"""jit'd wrapper with the per-platform block table (run-time binding --
the kernel-analog of the paper's 'compile HPGMG on the host, inside the
container' guidance)."""

from __future__ import annotations

import jax

from repro.kernels.matmul.kernel import matmul_pallas

# platform -> (block_m, block_n, block_k); chosen for VMEM size & MXU shape
BLOCK_TABLE = {
    "tpu-v5e": (512, 512, 512),
    "tpu-v4": (512, 1024, 512),
    "cpu-interpret": (128, 128, 128),   # keep interpret-mode tests fast
}


def _platform() -> str:
    return "tpu-v5e" if jax.default_backend() == "tpu" else "cpu-interpret"


def matmul(a: jax.Array, b: jax.Array, platform: str | None = None) -> jax.Array:
    bm, bn, bk = BLOCK_TABLE[platform or _platform()]
    while a.shape[0] % bm:
        bm //= 2
    while b.shape[1] % bn:
        bn //= 2
    while a.shape[1] % bk:
        bk //= 2
    return matmul_pallas(a, b, block_m=max(bm, 8), block_n=max(bn, 8),
                         block_k=max(bk, 8),
                         interpret=jax.default_backend() != "tpu")
