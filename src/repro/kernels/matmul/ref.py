"""Oracle for the blocked matmul kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """(M,K) @ (K,N) with f32 accumulation, result in a.dtype."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)
