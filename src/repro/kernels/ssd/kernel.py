"""Chunked SSD (Mamba-2 state-space duality) Pallas TPU kernel.

The SSD insight: within a chunk the recurrence is a (masked, decayed)
attention-like QUADRATIC form -- i.e. matmuls the MXU loves -- and across
chunks only the (H, N, P) boundary state needs the serial recurrence. The
CUDA reference pipelines chunk GEMMs through tensor cores; the TPU mapping:

* grid = (B, H/block_h, S/chunk) with the CHUNK dim innermost; the running
  state (block_h, N, P) sits in VMEM scratch and carries across chunk steps
  (sequential grid on a TPU core);
* per chunk per head: three MXU matmuls
    scores   = C B^T                  (Q x N @ N x Q  -> Q x Q, head-shared)
    y_intra  = (scores . L_h) @ x_h   (Q x Q @ Q x P)
    y_inter  = (C . e^cum_h) @ S_h    (Q x N @ N x P)
    S_h'     = g_h S_h + (wts_h . B)^T @ x_h   (N x Q @ Q x P)
  with Q=chunk=256, N=128, P=64 all MXU-aligned;
* the head loop inside a block is a static python unroll (block_h small);
* decays are clipped at exp(-60) like the XLA model path.

VMEM at defaults (chunk 256, block_h 8, N 128, P 64):
  x tile 256x8x64x4 + L 256x256x8x4 + state 8x128x64x4  ~ 3.2 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, s_scr, *,
                chunk: int, block_h: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, bh, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, bh)
    A = a_ref[0]                              # (bh,)
    Bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)         # (Q, N)

    da = dt * A                               # (Q, bh), negative
    cum = jnp.cumsum(da, axis=0)
    seg = cum[-1, :]                          # (bh,)

    scores = jax.lax.dot_general(             # (Q, Q), head-shared
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = ii >= jj

    new_state = []
    outs = []
    for h in range(block_h):                  # static unroll, MXU per head
        cum_h = cum[:, h]
        # intra-chunk decay matrix L[i,j] = exp(cum_i - cum_j) dt_j (i>=j)
        L = jnp.exp(jnp.clip(cum_h[:, None] - cum_h[None, :], -60.0, 0.0))
        L = jnp.where(causal, L * dt[None, :, h], 0.0)
        m1 = scores * L                                        # (Q, Q)
        xh = x[:, h, :]                                        # (Q, P)
        y = jax.lax.dot_general(m1, xh, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        # inter-chunk: incoming state contribution
        cin = Cm * jnp.exp(jnp.clip(cum_h, -60.0, 0.0))[:, None]  # (Q, N)
        y = y + jax.lax.dot_general(cin, s_scr[h],
                                    (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        outs.append(y)
        # state update
        wts = jnp.exp(jnp.clip(seg[h] - cum_h, -60.0, 0.0)) * dt[:, h]
        bw = Bm * wts[:, None]                                 # (Q, N)
        s_new = jax.lax.dot_general(bw, xh, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        g = jnp.exp(jnp.clip(seg[h], -60.0, 0.0))
        new_state.append(g * s_scr[h] + s_new)

    for h in range(block_h):
        s_scr[h] = new_state[h]
        o_ref[0, :, h, :] = outs[h].astype(o_ref.dtype)


def ssd_pallas(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
               C: jax.Array, *, chunk: int = 256, block_h: int = 8,
               interpret: bool = False) -> jax.Array:
    """Chunked SSD. Shapes as ssd_ref; S % chunk == 0, H % block_h == 0."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    block_h = min(block_h, H)
    assert S % chunk == 0 and H % block_h == 0, (S, H, chunk, block_h)
    nc, nh = S // chunk, H // block_h

    kernel = functools.partial(_ssd_kernel, chunk=chunk, block_h=block_h)
    return pl.pallas_call(
        kernel,
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_h, P),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, block_h),
                         lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, block_h), lambda bi, hi, ci: (0, hi)),
            pl.BlockSpec((1, chunk, N), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_h, P),
                               lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, S, H, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_h, N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.reshape(1, H), B, C)
