"""jit'd wrapper for the SSD kernel (fwd Pallas, bwd via the chunked XLA
formulation in models/ssm.py -- same algorithm, autodiff-friendly)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_pallas
from repro.models.ssm import _ssd_chunked


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@jax.custom_vjp
def ssd(x, dt, A, B, C):
    """Chunked SSD; shapes as ssd_ref. Returns y (b,S,H,P) f32."""
    return _fwd(x, dt, A, B, C)


def _fwd(x, dt, A, B, C):
    S, H = x.shape[1], x.shape[2]
    chunk = 256
    while S % chunk:
        chunk //= 2
    bh = 8
    while H % bh:
        bh //= 2
    return ssd_pallas(x, dt, A, B, C, chunk=max(chunk, 1),
                      block_h=max(bh, 1), interpret=not _on_tpu())


def _fwd_vjp(x, dt, A, B, C):
    return _fwd(x, dt, A, B, C), (x, dt, A, B, C)


def _bwd_vjp(res, g):
    x, dt, A, B, C = res
    chunk = min(256, x.shape[1])

    def xla_path(x_, dt_, A_, B_, C_):
        y, _ = _ssd_chunked(x_.astype(jnp.float32), dt_.astype(jnp.float32),
                            A_, B_.astype(jnp.float32),
                            C_.astype(jnp.float32), chunk)
        return y

    _, vjp = jax.vjp(xla_path, x, dt, A, B, C)
    return vjp(g)


ssd.defvjp(_fwd_vjp, _bwd_vjp)
