"""Pure-jnp oracle for the SSD (Mamba-2) kernel: naive sequential recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
            C: jax.Array) -> jax.Array:
    """Sequential state-space recurrence (ground truth, O(S·H·N·P)).

    x: (b,S,H,P); dt: (b,S,H) >0; A: (H,) <0; B,C: (b,S,N).
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t (x) x_t ;  y_t = C_t . h_t
    Returns y: (b,S,H,P) f32.
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp
        a = jnp.exp(dtt * A)                                   # (b,H)
        h = a[..., None, None] * h + jnp.einsum(
            "bh,bn,bhp->bhnp", dtt, Bt, xt)
        y = jnp.einsum("bn,bhnp->bhp", Ct, h)
        return h, y

    h0 = jnp.zeros((b, H, N, P), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
         Bf.swapaxes(0, 1), Cf.swapaxes(0, 1)))
    return ys.swapaxes(0, 1)
