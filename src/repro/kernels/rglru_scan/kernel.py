"""RG-LRU linear-recurrence Pallas TPU kernel.

The Griffin paper ships a custom (GPU) scan kernel because the recurrence
h_t = a_t h_{t-1} + b_t is memory-bound and tiny per step. TPU adaptation:

* grid = (B, R/block_r, S/block_s) with the TIME dimension innermost;
  the hidden state h (1, block_r) lives in VMEM scratch and carries across
  time-block grid steps (sequential on a TPU core);
* within a block, the time loop is a `fori_loop` over block_s steps of pure
  VPU work on (1, block_r) lanes -- block_r is a multiple of 128 so each
  step is full-lane;
* all loads/stores are (block_s, block_r) tiles: HBM traffic is exactly
  2 reads + 1 write of the sequence, the memory-bound optimum; the Pallas
  pipeline overlaps the next tile's DMA with the current tile's scan.

VMEM: 3 tiles x block_s x block_r x 4B; defaults (256, 256) use 768 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, o_ref, h_scr, *, block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, h):
        # h: (1, block_r); rows are time steps within the tile
        at = a_ref[0, t, :][None, :]
        bt = b_ref[0, t, :][None, :]
        h = at * h + bt
        o_ref[0, t, :] = h[0]
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_scr[...])
    h_scr[...] = h


def rglru_scan_pallas(a: jax.Array, b: jax.Array, *,
                      block_r: int = 256, block_s: int = 256,
                      interpret: bool = False) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t, axis 1. a, b: (B, S, R) f32 -> (B, S, R)."""
    B, S, R = a.shape
    block_r = min(block_r, R)
    block_s = min(block_s, S)
    assert R % block_r == 0 and S % block_s == 0, (R, S, block_r, block_s)
    nr, ns = R // block_r, S // block_s

    kernel = functools.partial(_rglru_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=(B, nr, ns),
        in_specs=[
            pl.BlockSpec((1, block_s, block_r), lambda bi, ri, si: (bi, si, ri)),
            pl.BlockSpec((1, block_s, block_r), lambda bi, ri, si: (bi, si, ri)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_r),
                               lambda bi, ri, si: (bi, si, ri)),
        out_shape=jax.ShapeDtypeStruct((B, S, R), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_r), jnp.float32)],
        interpret=interpret,
    )(a, b)
