"""Pure-jnp oracle for the RG-LRU blocked linear-recurrence kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jax.Array, b: jax.Array,
                   h0: jax.Array | None = None) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t along axis 1.

    a, b: (B, S, R) f32;  h0: (B, R) initial state (zeros if None).
    Sequential scan in f32 -- the ground truth the blocked kernel and the
    associative-scan model path are both checked against.
    """
    B, S, R = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, R), a.dtype)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)
