"""jit'd wrapper: Pallas RG-LRU scan with associative-scan backward."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
from repro.kernels.rglru_scan.ref import rglru_scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@jax.custom_vjp
def rglru_scan(a, b):
    return _fwd(a, b)


def _fwd(a, b):
    B, S, R = a.shape
    br = 256
    while R % br:
        br //= 2
    bs = 256
    while S % bs:
        bs //= 2
    return rglru_scan_pallas(a, b, block_r=max(br, 8), block_s=max(bs, 1),
                             interpret=not _on_tpu())


def _fwd_vjp(a, b):
    h = _fwd(a, b)
    return h, (a, h)


def _bwd_vjp(res, g):
    """Reverse recurrence: dh_t = g_t + a_{t+1} dh_{t+1};
    da_t = dh_t * h_{t-1}; db_t = dh_t."""
    a, h = res
    a_next = jnp.concatenate([a[:, 1:], jnp.zeros_like(a[:, :1])], axis=1)
    # reverse-time linear recurrence -> reuse the forward scan on flipped data
    gr = jnp.flip(g, axis=1)
    ar = jnp.flip(a_next, axis=1)
    dh = jnp.flip(rglru_scan_ref(ar, gr), axis=1)
    h_prev = jnp.concatenate([jnp.zeros_like(h[:, :1]), h[:, :-1]], axis=1)
    return dh * h_prev, dh


rglru_scan.defvjp(_fwd_vjp, _bwd_vjp)
