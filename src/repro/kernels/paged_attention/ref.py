"""Pure-jnp oracle for the paged-attention decode kernel.

Layout contract (shared with kernel.py / ops.py and the PagePool):

  * the KV cache of one layer is a global *page pool*
    ``k_pages/v_pages: (n_kv, n_pages, page_size, head_dim)`` -- kv heads
    major so the (page_size, head_dim) minor dims ride the TPU tiling;
  * each slot owns an ordered list of pages through its page-table row
    ``page_table: (n_slots, max_pages)`` -- logical position ``p`` of slot
    ``b`` lives at ``(page_table[b, p // page_size], p % page_size)``;
  * page 0 is the pool's reserved *garbage page*: unmapped table entries
    point at it, so gathers/scatters through a free or short slot stay in
    bounds and the mask (not the allocator) is what hides the junk;
  * ``lengths[b]`` = number of valid KV positions for slot ``b`` (the
    decode position + 1: the current token attends to itself).

The mask/softmax arithmetic deliberately mirrors
``models.attention._sdpa_dense`` (same einsum contractions, same additive
NEG_INF bias, f32 scores) so the paged decode path reproduces the
contiguous slot-decode path token-for-token on lockstep batches.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gather_pages(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """(n_kv, n_pages, ps, hd) + (B, max_pages) -> contiguous (B, L, n_kv, hd)
    with L = max_pages * ps. Unmapped entries gather the garbage page."""
    n_kv, _, ps, hd = pages.shape
    B, mp = page_table.shape
    g = pages[:, page_table]                   # (n_kv, B, mp, ps, hd)
    return g.reshape(n_kv, B, mp * ps, hd).transpose(1, 2, 0, 3)


def paged_attention_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        page_table: jax.Array, lengths: jax.Array,
                        *, window: int = 0,
                        scale: float | None = None) -> jax.Array:
    """One decode tick of attention over paged KV.

    q: (B, Hq, hd) -- one query token per slot;
    k_pages/v_pages: (n_kv, n_pages, page_size, hd);
    page_table: (B, max_pages) int32; lengths: (B,) int32.
    Returns (B, Hq, hd).
    """
    n_kv, _, ps, hd = k_pages.shape
    B, Hq, _ = q.shape
    g = Hq // n_kv
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    k = gather_pages(k_pages, page_table)          # (B, L, n_kv, hd)
    v = gather_pages(v_pages, page_table)
    L = k.shape[1]

    # identical formulation to models.attention._sdpa_dense on a (B,1,..)
    # query so XLA emits the same reduction order as the contiguous path
    qg = q.reshape(B, 1, n_kv, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    k_pos = jnp.broadcast_to(jnp.arange(L), (B, L))
    q_pos = (lengths - 1)[:, None].astype(jnp.int32)
    ok = k_pos[:, None, :] <= q_pos[:, :, None]
    if window:
        ok &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    s = s + bias[:, None, None]
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)    # (B,1,n_kv,g,hd)
    return out.reshape(B, Hq, hd).astype(q.dtype)
