"""Paged-attention decode Pallas TPU kernel.

One decode tick: each batch row is an independent request slot whose KV
history lives in non-contiguous *pages* of a global pool. The kernel
gathers the pages at attention time through the page table instead of ever
materialising a contiguous per-slot cache -- the block-allocation idea
(vLLM-style PagedAttention) expressed in the repo's kernel idiom.

Schedule (vs flash_attention/kernel.py):

* grid = (B, n_kv, max_pages) with the PAGE dimension innermost: grid steps
  run sequentially on a TPU core, so VMEM scratch (m, l, acc) carries the
  online-softmax state across a slot's pages exactly like the flash kernel
  carries it across KV blocks.
* the page table and lengths ride in as SCALAR-PREFETCH operands
  (PrefetchScalarGridSpec): BlockSpec index maps read ``tbl[b, p]`` to pick
  which physical page the next grid step DMAs -- the gather happens in the
  pipeline's index computation, so KV pages stream HBM->VMEM without a
  host-side or XLA-side copy into contiguous form.
* pages past a slot's length are skipped with ``pl.when`` (no MXU work).
  Their blocks still resolve to a valid page id (unmapped entries point at
  the pool's garbage page 0), so the prefetched DMA stays in bounds; a
  production follow-up could fold the skip into the index map to also
  elide the DMA.
* GQA: the q block is the (group, head_dim) tile of one kv head; kv pages
  are fetched once per kv head, never replicated per q head.

Tiling note: the q tile's sublane dim is the GQA group size (often < 8) --
legal but sub-tile on real TPU; the CI oracle runs interpret=True where
tiling does not apply.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pa_kernel(tbl_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
               m_scr, l_scr, acc_scr, *,
               page_size: int, window: int, scale: float, n_page_blocks: int):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lens_ref[b]                  # valid kv positions for this slot
    k_lo = p * page_size
    live = k_lo < length
    if window:
        live &= (k_lo + page_size - 1) > length - 1 - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (g, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (page_size, hd)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (g, page_size)

        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = cols < length                          # causal incl. self
        if window:
            mask &= cols > length - 1 - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        pr = jnp.exp(s - m_new)
        pr = jnp.where(mask, pr, 0.0)
        l_scr[...] = l_scr[...] * alpha + pr.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            pr.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(p == n_page_blocks - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def paged_attention_pallas(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           lengths: jax.Array, *, window: int = 0,
                           scale: float | None = None,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Hq, hd); k/v_pages: (n_kv, n_pages, page_size, hd);
    page_table: (B, max_pages) int32; lengths: (B,) int32 -> (B, Hq, hd)."""
    n_kv, n_pages, ps, hd = k_pages.shape
    B, Hq, _ = q.shape
    assert Hq % n_kv == 0, (Hq, n_kv)
    g = Hq // n_kv
    mp = page_table.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(B, n_kv, g, hd)
    kernel = functools.partial(
        _pa_kernel, page_size=ps, window=window, scale=scale,
        n_page_blocks=mp)

    # index maps see the scalar-prefetch refs as trailing args: the page id
    # for grid step (b, h, p) is read straight out of the table; clamping
    # keeps even hostile tables in bounds (unmapped entries are already 0)
    def kv_map(b, h, p, tbl, lens):
        return (h, jnp.clip(tbl[b, p], 0, n_pages - 1), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_kv, mp),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b, h, p, tbl, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd), kv_map),
            pl.BlockSpec((1, 1, ps, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b, h, p, tbl, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),      # m (running max)
            pltpu.VMEM((g, 1), jnp.float32),      # l (running denom)
            pltpu.VMEM((g, hd), jnp.float32),     # acc (numerator)
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, n_kv, g, hd), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(B, Hq, hd)
