"""Public entry point for paged decode attention.

On TPU the Pallas kernel streams KV pages through the scalar-prefetch
pipeline; elsewhere (this container: CPU) the XLA oracle runs instead --
NOT the interpreted kernel, which would put an interpreter in the decode
hot loop of every serving tick. The oracle gathers pages into contiguous
form inside the jitted step, which XLA fuses; numerics are identical to
``models.attention._sdpa_dense`` so paged and contiguous slot decode agree
token-for-token (tests/test_paged_attention.py pins all three against each
other).
"""

from __future__ import annotations

import jax

from repro.kernels.paged_attention.kernel import paged_attention_pallas
from repro.kernels.paged_attention.ref import paged_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_table: jax.Array, lengths: jax.Array,
                    *, window: int = 0,
                    scale: float | None = None) -> jax.Array:
    """q: (B, Hq, hd); k/v_pages: (n_kv, n_pages, page_size, hd);
    page_table: (B, max_pages); lengths: (B,) -> (B, Hq, hd)."""
    if _on_tpu():
        return paged_attention_pallas(q, k_pages, v_pages, page_table,
                                      lengths, window=window, scale=scale)
    return paged_attention_ref(q, k_pages, v_pages, page_table, lengths,
                               window=window, scale=scale)
