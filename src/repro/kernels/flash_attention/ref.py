"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True, window: int = 0,
                        scale: float | None = None) -> jax.Array:
    """q: (B, Hq, Sq, d); k, v: (B, Hkv, Sk, d); Hq % Hkv == 0.

    Returns (B, Hq, Sq, d). Full-softmax reference in f32.
    """
    B, Hq, Sq, d = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    if scale is None:
        scale = d ** -0.5
    qg = q.reshape(B, Hkv, g, Sq, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf) * scale
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        # q position i attends to k positions <= i + (Sk - Sq)
        mask &= ki <= qi + (Sk - Sq)
    if window:
        mask &= ki > qi + (Sk - Sq) - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)        # fully-masked rows -> 0
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(B, Hq, Sq, d).astype(q.dtype)
