"""jit'd public wrapper for the flash-attention kernel.

Selects block shapes from a small per-(head_dim, seq) tuning table sized for
v5e VMEM, falls back to interpret mode off-TPU (this container), and exposes
a custom-vjp whose backward is the XLA oracle under recompute -- the fwd
kernel is the production hot path (decode/prefill); training backward reuses
the chunked XLA formulation until a bwd kernel lands.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pick_blocks(seq_q: int, seq_k: int, head_dim: int) -> tuple[int, int]:
    """v5e VMEM-sized blocks: s-block 512 fits all d<=256 comfortably;
    shrink for short sequences (blocks must tile the sequence)."""
    bq = 512
    while bq > 1 and seq_q % bq:
        bq //= 2
    bk = 512
    while bk > 1 and seq_k % bk:
        bk //= 2
    if head_dim > 128:          # d=256 (recurrentgemma): halve score tile
        while bq > 256 and seq_q % (bq // 2) == 0:
            bq //= 2
        while bk > 256 and seq_k % (bk // 2) == 0:
            bk //= 2
    return bq, bk


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, causal: bool = True, window: int = 0):
    """q: (B,Hq,S,d); k/v: (B,Hkv,S,d). Fwd = Pallas kernel, bwd = oracle."""
    return _fwd_impl(q, k, v, causal, window)


def _fwd_impl(q, k, v, causal, window):
    bq, bk = pick_blocks(q.shape[2], k.shape[2], q.shape[3])
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=bq, block_k=bk,
                               interpret=not _on_tpu())


def _fwd_vjp(q, k, v, causal, window):
    out = _fwd_impl(q, k, v, causal, window)
    return out, (q, k, v)


def _bwd_vjp(causal, window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: flash_attention_ref(q_, k_, v_, causal=causal,
                                               window=window), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd_vjp, _bwd_vjp)
