"""Flash-attention forward Pallas TPU kernel (FlashAttention-2 schedule).

TPU adaptation (vs the CUDA original):

* The grid is (batch*q_heads, q_blocks, kv_blocks) with the KV dimension
  INNERMOST: on TPU, grid steps execute sequentially on a core, so VMEM
  scratch (m, l, acc) carries the online-softmax state across KV blocks --
  the role warp-level registers play on GPU.
* Block shapes are MXU/VPU aligned: q/kv blocks are multiples of 128 in the
  sequence dim; head_dim rides the 128-lane minor axis. For v5e (~16 MiB
  VMEM/core) the default 512x512 blocks use
      q 512xd*2B + k,v 512xd*2B*2 + s 512x512x4B + acc 512xd*4B  ~ 2.3 MiB
  at d=128 -- leaving headroom for double-buffered pipelines.
* GQA is expressed in the BlockSpec index maps: the kv block index ignores
  the intra-group component of the head index, so KV is never physically
  replicated (bandwidth, not copies).
* Causality/window are handled two ways, mirroring the XLA oracle:
  fully-masked (future) KV blocks are skipped by `pl.when` (no MXU work),
  diagonal blocks apply the elementwise mask.

Backward is delegated to XLA autodiff over the oracle in ops.py (recompute
policy); a hand-written bwd kernel is a possible follow-up and is noted in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, block_q: int, block_k: int, causal: bool,
               window: int, sq: int, sk: int, n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # trace-level static skip is impossible (ki is dynamic) -> pl.when guard.
    # q row r attends to k col c iff c <= r + (sk - sq) [causal]
    #                            and c >  r + (sk - sq) - window [window]
    off = sk - sq
    q_lo = qi * block_q
    k_lo = ki * block_k
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_lo + block_q - 1 + off
    if window:
        live &= (k_lo + block_k - 1) > q_lo + off - window

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)             # (block_q, d)
        k = k_ref[0].astype(jnp.float32)             # (block_k, d)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)

        rows = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= cols <= rows + off
        if window:
            mask &= cols > rows + off - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        scale: float | None = None,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, d); k, v: (B, Hkv, Sk, d) -> (B, Hq, Sq, d)."""
    B, Hq, Sq, d = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    g = Hq // Hkv
    if scale is None:
        scale = d ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, \
        f"seq lens ({Sq},{Sk}) must tile by blocks ({block_q},{block_k})"
    nq, nk = Sq // block_q, Sk // block_k

    qf = q.reshape(B * Hq, Sq, d)
    kf = k.reshape(B * Hkv, Sk, d)
    vf = v.reshape(B * Hkv, Sk, d)

    kernel = functools.partial(
        _fa_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, sq=Sq, sk=Sk, n_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            # GQA: head group index folds away in the KV index map
            pl.BlockSpec((1, block_k, d), lambda b, i, j, g=g: (b // g, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j, g=g: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),      # m (running max)
            pltpu.VMEM((block_q, 1), jnp.float32),      # l (running denom)
            pltpu.VMEM((block_q, d), jnp.float32),      # acc (numerator)
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, Sq, d)
