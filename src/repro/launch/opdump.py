import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Per-op byte/flop breakdown of one unit probe -- the dry-run 'profiler'.

  PYTHONPATH=src python -m repro.launch.opdump --arch deepseek-67b \
      --shape train_4k --mesh multipod --stage 0 [--settings '{...}']

Groups RESULT bytes of every HLO instruction in the compiled per-unit probe
by opcode (fusion kinds separated), which is the closest thing to a memory
profile this CPU container can produce: it shows WHERE the roofline memory
term comes from.
"""

import argparse
import json
import re
from collections import defaultdict

from repro.core.container import Container
from repro.launch.analysis import _shape_bytes, parse_collectives
from repro.launch.dryrun import build_image

_INSTR = re.compile(r"^\s+(?:ROOT )?%?[\w.\-]+ = (\S+) ([\w\-]+)\(")


def op_breakdown(hlo: str) -> dict[str, float]:
    agg: dict[str, float] = defaultdict(float)
    for line in hlo.splitlines():
        line = line.split(", metadata=")[0]
        m = _INSTR.match(line)
        if not m:
            continue
        typ, op = m.groups()
        agg[op] += _shape_bytes(typ)
    return dict(agg)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--stage", type=int, default=0)
    ap.add_argument("--collectives", default="generic")
    ap.add_argument("--settings", default='{"remat":"dots"}')
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    image = build_image(args.arch, args.shape, args.mesh,
                        collectives=args.collectives,
                        settings=json.loads(args.settings))
    c = Container(image, platform=args.mesh)
    lowered, count = c.lower_unit_probe(args.stage, c.cell.kind)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    print(f"# unit probe {args.arch}/{args.shape}/{args.mesh} stage{args.stage} "
          f"x{count}")
    print(f"# flops/dev={ca.get('flops', 0):.3e}  "
          f"bytes_accessed/dev={ca.get('bytes accessed', 0):.3e}")
    text = compiled.as_text()
    st = parse_collectives(text)
    print("# collectives (per unit, per device):")
    for op in sorted(st.bytes_by_op, key=lambda o: -st.bytes_by_op[o]):
        print(f"#   {op:20s} n={st.count_by_op[op]:4d} bytes={st.bytes_by_op[op]:.3e}")
    # biggest individual collective instructions
    import re as _re
    biggest = []
    for line in text.splitlines():
        line = line.split(", metadata=")[0]
        mm = _re.search(r"= (\S+) (all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)", line)
        if mm:
            biggest.append((_shape_bytes(mm.group(1)), mm.group(2), mm.group(1)[:60]))
    for b, op, t in sorted(biggest, reverse=True)[:8]:
        print(f"#   big: {op:18s} {b:.3e}  {t}")
    agg = op_breakdown(text)
    total = sum(agg.values())
    print(f"# result-bytes total (per unit, per device): {total:.3e}")
    for op, b in sorted(agg.items(), key=lambda kv: -kv[1])[: args.top]:
        print(f"{op:28s} {b:.3e}  {b / total * 100:5.1f}%")


if __name__ == "__main__":
    main()
