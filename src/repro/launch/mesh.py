"""Production meshes for the multi-pod dry-run.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax import;
everything else sees the real 1-CPU device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
