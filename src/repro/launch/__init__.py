# launch: production mesh, multi-pod dry-run, analysis, train/serve drivers.
