"""Render the roofline table + dry-run summary from results/dryrun artifacts.

  PYTHONPATH=src python -m repro.launch.report --dir results/dryrun [--md]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.launch.analysis import HBM_PER_CHIP


def load(dir_: str) -> list[dict]:
    recs = []
    for p in sorted(Path(dir_).glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_s(x: float) -> str:
    return f"{x:.2e}"


def table(recs: list[dict], md: bool = False, mesh: str | None = None) -> str:
    rows = []
    hdr = ["arch", "shape", "mesh", "kind", "compute_s", "memory_s",
           "collective_s", "dominant", "GiB/dev", "fits", "useful", "roofline"]
    for r in recs:
        if r.get("status") == "skipped":
            if mesh is None or r["mesh"] == mesh:
                rows.append([r["arch"], r["shape"], r["mesh"], "--",
                             "--", "--", "--", "SKIPPED", "--", "--", "--",
                             "--"])
            continue
        if r.get("status") != "ok":
            rows.append([r["arch"], r["shape"], r["mesh"], "--"] +
                        ["FAILED"] * 8)
            continue
        if mesh is not None and r["mesh"] != mesh:
            continue
        rl = r["roofline"]
        mem = r["memory"]
        rows.append([
            r["arch"], r["shape"], r["mesh"], r["kind"],
            fmt_s(rl["compute_s"]), fmt_s(rl["memory_s"]),
            fmt_s(rl["collective_s"]), rl["dominant"],
            f"{mem['resident_bytes_per_device'] / 2**30:.2f}",
            "y" if mem["fits_hbm"] else "N",
            f"{rl['useful_flops_fraction']:.3f}",
            f"{rl['roofline_fraction']:.4f}",
        ])
    widths = [max(len(str(row[i])) for row in rows + [hdr])
              for i in range(len(hdr))]
    sep = " | " if md else "  "
    out = [sep.join(h.ljust(w) for h, w in zip(hdr, widths))]
    if md:
        out[0] = "| " + out[0] + " |"
        out.append("|" + "|".join("-" * (w + 2) for w in widths) + "|")
        for row in rows:
            out.append("| " + sep.join(str(c).ljust(w)
                                       for c, w in zip(row, widths)) + " |")
    else:
        out.append("-" * len(out[0]))
        for row in rows:
            out.append(sep.join(str(c).ljust(w) for c, w in zip(row, widths)))
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    recs = load(args.dir)
    print(table(recs, md=args.md, mesh=args.mesh))
    ok = [r for r in recs if r.get("status") == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
        coll = max(ok, key=lambda r: r["roofline"]["collective_s"]
                   / max(r["roofline"]["bound_s"]
                         if "bound_s" in r["roofline"] else
                         max(r["roofline"]["compute_s"],
                             r["roofline"]["memory_s"],
                             r["roofline"]["collective_s"]), 1e-30))
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']}/"
              f"{worst['mesh']} = {worst['roofline']['roofline_fraction']:.4f}")
        print(f"most collective-bound:   {coll['arch']}/{coll['shape']}/"
              f"{coll['mesh']} collective_s={coll['roofline']['collective_s']:.2e}")


if __name__ == "__main__":
    main()
