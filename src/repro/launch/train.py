"""Training driver: srun-shaped entry point.

  PYTHONPATH=src python -m repro.launch.train --image <tag-or-Imagefile> \
      [--platform local|pod|multipod] --steps 100

The paper's `srun shifter --image=... ./demo` analog: one image, any
platform, the host decides where it runs. Fault tolerance is on by default:
deterministic data, periodic async checkpoints into the container overlay,
resume from the latest checkpoint (possibly on a DIFFERENT platform --
elastic restart), straggler monitoring with checkpoint-on-trip.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint.elastic import reshard_restore
from repro.checkpoint.store import CheckpointStore
from repro.checkpoint.straggler import StragglerMonitor
from repro.core.runtime import Runtime
from repro.data.pipeline import DataConfig, SyntheticLM


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--image", required=True,
                    help="registry tag/digest, or a path to an Imagefile")
    ap.add_argument("--platform", default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--root", default=".stevedore")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    rt = Runtime(args.root)
    if Path(args.image).exists():
        image = rt.build(Path(args.image).read_text())
    else:
        image = rt.pull(args.image)
    c = rt.run(image, platform=args.platform)
    c.ensure_overlay()
    cell = c.cell
    print(f"[train] image={image.short_digest} arch={c.arch.name} "
          f"platform={c.platform} cell={cell.name} abi={c.abi.describe()}")

    data = SyntheticLM(DataConfig(
        vocab_size=c.arch.vocab_size, seq_len=cell.seq_len,
        global_batch=cell.global_batch, seed=args.seed,
        frontend_len=c.arch.frontend_len, d_model=c.arch.d_model))

    store = CheckpointStore(c.overlay / "ckpt")
    start_step = 0
    if args.resume and store.latest_step() is not None:
        t = {"params": c.abstract_params(), "opt": c.abstract_opt_state()}
        sh = {"params": c.param_shardings(), "opt": c.opt_state_shardings()}
        restored = reshard_restore(store, t, sh)
        params, opt = restored["params"], restored["opt"]
        start_step = int(jax.device_get(opt["step"]))
        print(f"[train] resumed from step {start_step} "
              f"(elastic: mesh={c.platform})")
    else:
        params = c.init_params(args.seed)
        opt = c.init_opt_state(params)

    step_fn = jax.jit(c.train_step_fn(), donate_argnums=(0, 1))
    mon = StragglerMonitor()
    last_loss = float("nan")
    for i in range(start_step, start_step + args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        mon.start()
        params, opt, metrics = step_fn(params, opt, batch)
        jax.block_until_ready(metrics["loss"])
        timing = mon.stop()
        last_loss = float(metrics["loss"])
        c.log_metrics(i + 1, {**metrics, "step_seconds":
                              timing["step_seconds"],
                              "straggler_flag": timing["flagged"]})
        if timing["tripped"]:
            print(f"[train] straggler trip at step {i+1}: checkpointing for "
                  "drain/replace")
            store.save(i + 1, {"params": params, "opt": opt}, blocking=True)
        elif (i + 1) % args.ckpt_every == 0:
            store.save(i + 1, {"params": params, "opt": opt})
        if (i + 1) % 10 == 0 or i == start_step:
            print(f"[train] step {i+1} loss={last_loss:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"t={timing['step_seconds']*1e3:.0f}ms")
    store.wait()
    store.save(start_step + args.steps, {"params": params, "opt": opt},
               blocking=True)
    print(f"[train] done at step {start_step + args.steps}; "
          f"overlay={c.overlay}")
    return {"final_loss": last_loss, "overlay": str(c.overlay),
            "steps": start_step + args.steps}


if __name__ == "__main__":
    main()
