"""Serving driver: batched prefill + greedy decode from an image.

  PYTHONPATH=src python -m repro.launch.serve --image <tag> \
      [--platform local] --requests 8 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runtime import Runtime
from repro.serve.serve_step import greedy_sample


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--image", required=True)
    ap.add_argument("--platform", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--root", default=".stevedore")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    rt = Runtime(args.root)
    image = (rt.build(Path(args.image).read_text())
             if Path(args.image).exists() else rt.pull(args.image))
    c = rt.run(image, platform=args.platform)
    cfg = c.arch
    B, P, G = args.requests, args.prompt_len, args.gen
    print(f"[serve] image={image.short_digest} arch={cfg.name} "
          f"batch={B} prompt={P} gen={G}")

    params = c.init_params(args.seed)
    from repro.serve.serve_step import ServeStepBuilder
    b = ServeStepBuilder(c.model, c.mesh, c.rules)
    prefill = jax.jit(b.build_prefill(cache_len=P + G + 1))
    generate = jax.jit(b.build_generate_loop(G))

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32)
    fe = (jnp.asarray(rng.standard_normal(
        (B, cfg.frontend_len, cfg.d_model)) * 0.02, jnp.bfloat16)
        if cfg.frontend else None)

    t0 = time.perf_counter()
    if fe is not None:
        last_logits, cache = prefill(params, prompts, fe)
    else:
        last_logits, cache = prefill(params, prompts)
    jax.block_until_ready(last_logits)
    t_prefill = time.perf_counter() - t0

    first = greedy_sample(last_logits, cfg.vocab_size)[:, None]
    t0 = time.perf_counter()
    toks, _ = generate(params, cache, first,
                       jnp.int32(P + (cfg.frontend_len or 0)))
    jax.block_until_ready(toks)
    t_gen = time.perf_counter() - t0

    print(f"[serve] prefill {t_prefill*1e3:.1f} ms "
          f"({B*P/t_prefill:.0f} tok/s), decode {t_gen*1e3:.1f} ms "
          f"({B*G/t_gen:.0f} tok/s)")
    print(f"[serve] sample continuation (req 0): {toks[0, :16].tolist()}")
    return {"prefill_s": t_prefill, "decode_s": t_gen,
            "tokens": np.asarray(toks)}


if __name__ == "__main__":
    main()
