"""Serving driver: a thin CLI over the Pod orchestrator.

Continuous (default): a Pod of Container replicas serves staggered
variable-length requests via continuous batching:

  PYTHONPATH=src python -m repro.launch.serve --image <tag|Imagefile> \
      --replicas 2 --slots 8 --requests 32 --gen 32

Multi-pod (--pods N): the same trace served by a PodRouter fronting N
pods (each its own scheduler + queue), with --policy shortest-queue
(load-aware, default) or consistent-hash (rid session affinity).

Static (--mode static): the pre-orchestrator baseline -- one fixed batch,
prefill + scanned greedy decode -- kept as the fig6 comparison point. Both
modes compile through the Container serve path (explicit in/out shardings +
CompileCache), not ad-hoc re-jits: a second run of either mode, or a second
replica, deserializes the executables instead of re-tracing.

Both modes replay the SAME deterministic trace (prompts, budgets, and --
for frontend-embedding archs like musicgen/internvl2 -- per-request
audio/vision prefix embeddings), and both return ``request_tokens``:
continuous and static produce identical tokens request-for-request.
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runtime import Runtime


def _tail_budgets(gen: int, n: int) -> list[int]:
    """Heavy-tailed decode budgets: most requests short, one in four runs
    the full budget (the production shape that makes a static wave idle on
    its longest member). One helper so both serving modes -- and the fig6
    benchmark -- replay the SAME trace."""
    tail = [2, max(2, gen // 8), max(2, gen // 4), gen]
    return [tail[i % len(tail)] for i in range(n)]


def _frontend_width(cfg) -> int:
    return cfg.frontend_len if cfg.frontend else 0


def _build_requests(args, cfg, rng):
    """Deterministic staggered, variable-length trace.

    Frontend-embedding archs (musicgen/internvl2) get a per-request
    modality prefix: a deterministic stand-in for precomputed EnCodec
    frames / InternViT patch embeddings (the frontends are stubs per the
    assignment). Both serve modes replay this SAME trace, so continuous and
    static produce identical tokens request-for-request.

    ``--shared-prefix N`` prepends ONE fixed N-token block (a fleet-wide
    system prompt) to every request and declares it via
    ``GenRequest.prefix_len`` -- the trace the prefix page cache and the
    prefix-hash router policy are measured on. The block is drawn from the
    rng FIRST, so the per-request tail of the trace is identical whether
    or not caching is enabled (same flags -> bitwise-same trace).

    ``--batch-every N`` tags every Nth request (rid % N == N-1) as the
    ``batch`` QoS class -- sheddable under overload, preemptible under
    pool pressure; 0 (default) leaves the whole trace interactive.
    ``--deadline-ticks D`` puts an admission deadline on the batch
    requests (the tier the SLO policy may drop). Neither flag changes the
    prompts or budgets, so QoS on/off replays the same token trace."""
    from repro.orchestrator import GenRequest
    reqs = []
    budgets = _tail_budgets(args.gen, args.requests)
    fe_len = _frontend_width(cfg)
    shared = max(0, int(getattr(args, "shared_prefix", 0)))
    batch_every = max(0, int(getattr(args, "batch_every", 0)))
    deadline = getattr(args, "deadline_ticks", None)
    sys_prompt = rng.integers(0, cfg.vocab_size, shared) if shared else None
    for i in range(args.requests):
        plen = int(args.prompt_len * (0.5 + 0.5 * ((i * 7919) % 97) / 96))
        fe = (0.02 * rng.standard_normal((fe_len, cfg.d_model)).astype(
            np.float32) if fe_len else None)
        prompt = rng.integers(0, cfg.vocab_size, max(1, plen))
        if shared:
            prompt = np.concatenate([sys_prompt, prompt])
        is_batch = batch_every and i % batch_every == batch_every - 1
        reqs.append(GenRequest(
            rid=i,
            prompt=prompt,
            max_new_tokens=budgets[i],
            arrival=i // max(1, getattr(args, "arrive_per_tick", 8)),
            frontend=fe,
            prefix_len=shared,
            priority="batch" if is_batch else "interactive",
            deadline_ticks=deadline if is_batch else None))
    return reqs


def _arch_config(rt: Runtime, image):
    """The image's resolved ModelConfig (without running a container)."""
    from repro.configs import get_config
    cfg = (image if not isinstance(image, str) else rt.pull(image)).config()
    return get_config(cfg["arch"]["name"], **cfg["arch"].get("overrides", {}))


def _pod_kwargs(args, cfg) -> dict:
    """Pod constructor kwargs sized for the trace -- shared by every fleet
    member, whether the pod is built here or inside a fabric worker
    process (the kwargs are JSON-serializable by construction)."""
    # per-request span: frontend prefix + shared system prompt + prompt +
    # gen + chunk-overshoot
    shared = max(0, int(getattr(args, "shared_prefix", 0)))
    max_len = _frontend_width(cfg) + shared + args.prompt_len + args.gen + 8
    if getattr(args, "paged", False):
        # paged: max_len is only the per-request span; double it so long
        # requests fit, and size the pool to the contiguous bank's HBM
        return dict(replicas=args.replicas, n_slots=args.slots,
                    max_len=2 * max_len, platform=args.platform,
                    seed=args.seed, paged=True, page_size=args.page_size,
                    n_pages=args.slots * (-(-max_len // args.page_size)) + 1,
                    prefix_cache=bool(getattr(args, "prefix_cache", False)),
                    spill_pages=getattr(args, "spill_pages", 0))
    return dict(replicas=args.replicas, n_slots=args.slots,
                max_len=max_len, platform=args.platform, seed=args.seed)


def _make_pod(rt: Runtime, image, args, cfg):
    """One serving pod sized for the trace (shared by every fleet member)."""
    from repro.orchestrator import Pod
    return Pod(rt, image, **_pod_kwargs(args, cfg))


def serve_continuous(rt: Runtime, image, args) -> dict:
    from repro.orchestrator import ContinuousScheduler, PodRouter
    from repro.orchestrator.obs import decomposition, export_chrome
    from repro.orchestrator.telemetry import latency_summary
    cfg = _arch_config(rt, image)
    n_pods = max(1, int(getattr(args, "pods", 1)))
    pods = [_make_pod(rt, image, args, cfg) for _ in range(n_pods)]
    if n_pods > 1:
        # fleet: one router surface over per-pod schedulers/queues
        driver = PodRouter(pods,
                           policy=getattr(args, "policy", "shortest-queue"),
                           fairness_cap=args.fairness_cap,
                           shed_queue_depth=getattr(
                               args, "shed_queue_depth", None),
                           shed_ttft_p99=getattr(
                               args, "shed_ttft_p99", None))
    else:
        driver = ContinuousScheduler(pods[0],
                                     fairness_cap=args.fairness_cap)
    rng = np.random.default_rng(args.seed)
    reqs = _build_requests(args, cfg, rng)

    t0 = time.perf_counter()
    driver.submit(reqs)
    done = driver.run()
    wall = time.perf_counter() - t0
    # terminal phase: ps stays honest after exit
    if n_pods > 1:
        driver.write_state(final=True)      # also finalizes member pods
    else:
        pods[0].write_state(final=True)

    engines = [e for p in pods for e in p.engines]
    toks = sum(len(r.tokens) for r in done)
    dec_s = sum(e.decode_s for e in engines)
    pre_s = sum(e.prefill_s for e in engines)
    ticks = sum(e.decode_ticks for e in engines)
    out = {
        "mode": "continuous",
        "pods": n_pods,
        "requests": len(done),
        "tokens": toks,
        "wall_s": wall,
        "decode_s": dec_s,
        "prefill_s": pre_s,
        "decode_ticks": ticks,
        "decode_tok_per_s": toks / dec_s if dec_s else 0.0,
        "prefill_positions": sum(e.prefill_positions for e in engines),
        "prefix_cache": {
            "enabled": any(e.prefix_cache for e in engines),
            "hits": sum(e.prefix_hits for e in engines),
            "misses": sum(e.prefix_misses for e in engines),
            "tokens_saved": sum(e.prefix_tokens_saved for e in engines),
            # radix-registry taxonomy + spill-tier traffic
            "ancestor_hits": sum(e.prefix_ancestor_hits for e in engines),
            "partial_hits": sum(e.prefix_partial_hits for e in engines),
            "spills": sum(e.pool.spills for e in engines
                          if getattr(e, "paged", False)),
            "restores": sum(e.pool.restores for e in engines
                            if getattr(e, "paged", False)),
        },
        "tokens_wasted": sum(e.tokens_wasted for e in engines),
        # QoS accounting: page-level preemptions/resumes on the engines,
        # sheds at the router (overload) and schedulers (deadline)
        "preemptions": sum(e.preemptions for e in engines),
        "resumes": sum(e.resumes for e in engines),
        "shed": (driver.shed_total if n_pods > 1
                 else len(driver.shedded)),
        # nearest-rank percentiles, measured from request ARRIVAL (the
        # trace stagger is offered load, not serving latency)
        **latency_summary(done),
        "request_tokens": {r.rid: list(r.tokens) for r in done},
        "pod": pods[0].status() if n_pods == 1 else None,
    }
    # TTFT / inter-token-latency decomposition derived from the span logs
    # (not re-measured): the same numbers a trace viewer would show
    buffers = (driver.trace_buffers() if n_pods > 1
               else [pods[0].trace])
    out["decomposition"] = decomposition(buffers)
    if getattr(args, "batch_every", 0):
        # mixed-QoS trace: the per-class split is the fig10 deliverable
        out["decomposition_interactive"] = decomposition(
            buffers, priority="interactive")
        out["decomposition_batch"] = decomposition(buffers, priority="batch")
    trace_path = getattr(args, "trace", None)
    if trace_path:
        trace = export_chrome(buffers, trace_path)
        print(f"[serve] trace: {len(trace['traceEvents'])} events -> "
              f"{trace_path} (open in Perfetto / chrome://tracing)")
    if n_pods > 1:
        out["fleet"] = driver.status()
        print(f"[serve] fleet={driver.router_id} policy={driver.policy} "
              f"pods={n_pods} image={pods[0].image.short_digest} "
              f"replicas={args.replicas} slots={args.slots}")
    else:
        print(f"[serve] pod={pods[0].pod_id} "
              f"image={pods[0].image.short_digest} "
              f"replicas={args.replicas} slots={args.slots}")
    # a run with no completions has no latency: render '-', never a fake 0
    if out["latency_count"]:
        p50, p99 = out["p50_latency_ticks"], out["p99_latency_ticks"]
    else:
        p50 = p99 = "-"
    print(f"[serve] {len(done)} requests, {toks} tokens in {wall:.2f}s "
          f"(decode {out['decode_tok_per_s']:.0f} tok/s over {ticks} ticks; "
          f"p50 {p50} / p99 {p99} ticks)")
    d = out["decomposition"]
    if d["latency_count"]:
        print(f"[serve] ttft p50 {d['ttft_p50_ticks']} / "
              f"p99 {d['ttft_p99_ticks']} ticks; "
              f"itl p50 {d['itl_p50_ticks']:.2f} / "
              f"p99 {d['itl_p99_ticks']:.2f} ticks/tok")
    pc = out["prefix_cache"]
    if pc["enabled"]:
        print(f"[serve] prefix cache: {pc['hits']} hits "
              f"({pc['ancestor_hits']} ancestor, {pc['partial_hits']} "
              f"partial) / {pc['misses']} misses, "
              f"{pc['tokens_saved']} prefill tokens skipped")
        if pc["spills"] or pc["restores"]:
            print(f"[serve] spill tier: {pc['spills']} spills / "
                  f"{pc['restores']} restores")
    if out["preemptions"] or out["shed"]:
        print(f"[serve] qos: {out['preemptions']} preemptions / "
              f"{out['resumes']} resumes, {out['shed']} shed")
    return out


def serve_fabric(rt: Runtime, image, args) -> dict:
    """The same trace served over the cross-host fabric: router and pods
    speak the framed message protocol instead of method calls.

    ``--fabric loopback`` keeps workers in-process (deterministic, the
    codec still round-trips every message); ``--fabric proc`` launches
    one worker PROCESS per pod over stdin/stdout pipes -- the
    configuration the fault-injection benchmark kills pods under.
    ``--min-pods``/``--max-pods`` bound the elastic fleet; scale-up
    triggers on the outstanding-token backlog per live pod, scale-down
    drains the newest pod after a sustained idle streak."""
    from repro.orchestrator.fabric import (
        FABRIC_POLICIES, FabricRouter, load_fleet_spans,
        loopback_spawner, proc_spawner)
    from repro.orchestrator.obs import (
        decomposition, export_chrome, validate_fleet_closure)
    from repro.orchestrator.telemetry import latency_summary
    if args.policy not in FABRIC_POLICIES:
        raise SystemExit(f"--fabric supports policies {FABRIC_POLICIES}, "
                         f"not {args.policy!r}")
    cfg = _arch_config(rt, image)
    pod_kwargs = _pod_kwargs(args, cfg)
    if args.fabric == "proc":
        imagefile = (Path(args.image).read_text()
                     if Path(args.image).exists() else None)
        spawn = proc_spawner(
            args.root, imagefile=imagefile,
            ref=None if imagefile else args.image,
            pod_kwargs=pod_kwargs, fairness_cap=args.fairness_cap)
    else:
        spawn = loopback_spawner(rt, image, pod_kwargs=pod_kwargs,
                                 fairness_cap=args.fairness_cap)
    router = FabricRouter(
        spawn, runtime=rt, pods=max(1, args.pods), policy=args.policy,
        min_pods=max(1, getattr(args, "min_pods", 1) or 1),
        max_pods=getattr(args, "max_pods", None),
        heartbeat_every=getattr(args, "heartbeat_every", 4),
        miss_limit=getattr(args, "miss_limit", 2),
        scale_up_tokens=getattr(args, "scale_up_tokens", None),
        scale_idle_ticks=getattr(args, "scale_idle_ticks", None),
        wall_clock=args.fabric == "proc")
    rng = np.random.default_rng(args.seed)
    reqs = _build_requests(args, cfg, rng)

    t0 = time.perf_counter()
    router.submit(reqs)
    done = router.run()
    wall = time.perf_counter() - t0
    fleet = router.status()
    # loopback worker buffers are reachable only through the membership,
    # which close() clears -- capture them first. proc workers flush span
    # FILES at retire, so those are pooled after close.
    local_buffers = (None if args.fabric == "proc"
                     else router.trace_buffers())
    router.close()

    toks = sum(len(r.tokens) for r in done)
    out = {
        "mode": "fabric",
        "fabric": args.fabric,
        "pods": args.pods,
        "requests": len(done),
        "tokens": toks,
        "wall_s": wall,
        "shed": len(router.shedded),
        "rejected": len(router.rejected),
        **latency_summary(done),
        "request_tokens": {r.rid: list(r.tokens) for r in done},
        "fleet": fleet,
        "reroutes": fleet["fabric"]["reroutes"],
        "evictions": fleet["fabric"]["evictions"],
    }
    buffers = (load_fleet_spans(rt.root, fleet=router.fleet)
               if args.fabric == "proc" else local_buffers)
    out["fleet_closure"] = validate_fleet_closure(buffers)
    out["decomposition"] = decomposition(buffers)
    trace_path = getattr(args, "trace", None)
    if trace_path:
        trace = export_chrome(buffers, trace_path)
        print(f"[serve] trace: {len(trace['traceEvents'])} events -> "
              f"{trace_path} (open in Perfetto / chrome://tracing)")
    fb = fleet["fabric"]
    print(f"[serve] fabric={args.fabric} fleet={router.router_id} "
          f"policy={router.policy} live={fb['live']} "
          f"(spawned {fb['spawned']}, retired {fb['retired']}, "
          f"evicted {fb['evictions']})")
    print(f"[serve] {len(done)} requests, {toks} tokens in {wall:.2f}s; "
          f"{fb['reroutes']} reroutes; closure: "
          f"{out['fleet_closure']['routed']} routed / "
          f"{out['fleet_closure']['closed']} closed")
    return out


def serve_static(rt: Runtime, image, args) -> dict:
    """Fixed-batch baseline THROUGH the container compile path.

    Replays the SAME trace as continuous mode, one wave of ``slots``
    requests at a time: wave prefill with per-row prompt (and frontend
    prefix) lengths, then a scanned greedy decode of the full ``gen``
    budget for every wave member -- the static batch cannot release a
    finished slot, which is exactly the waste fig6 measures. Tokens are
    identical to continuous mode request-for-request."""
    c = rt.run(image, platform=args.platform)
    cfg = c.arch
    B, P, G = args.slots, args.prompt_len, args.gen
    F = _frontend_width(cfg)
    cache_len = F + P + G + 1
    shapes = dict(batch=B, prompt_len=P, cache_len=cache_len)
    if F:
        shapes["frontend_len"] = F
    prefill = c.compile_serve_step("prefill_slot", **shapes)
    generate = c.compile_serve_step("generate", batch=B, cache_len=cache_len,
                                    gen_steps=G, per_row=True)
    rng = np.random.default_rng(args.seed)
    reqs = _build_requests(args, cfg, rng)
    params = c.init_params(args.seed)

    toks_useful = 0
    t_pre = t_dec = 0.0
    waves = 0
    request_tokens: dict[int, list[int]] = {}
    t0 = time.perf_counter()
    for lo in range(0, len(reqs), B):
        wave = reqs[lo:lo + B]
        toks = np.zeros((B, P), np.int32)
        lens = np.ones(B, np.int32)          # pad rows: 1 real token (row 0)
        fls = np.zeros(B, np.int32)
        fe = np.zeros((B, F, cfg.d_model), np.float32) if F else None
        for j, r in enumerate(wave):
            toks[j, :r.prompt_len] = r.prompt
            lens[j] = r.prompt_len
            if F and r.frontend is not None:
                fe[j, :r.frontend_len] = r.frontend
                fls[j] = r.frontend_len
        fe_args = ((jnp.asarray(fe, c.cache_dtype), jnp.asarray(fls))
                   if F else ())
        t1 = time.perf_counter()
        first, cache = prefill(params, jnp.asarray(toks), jnp.asarray(lens),
                               *fe_args)
        jax.block_until_ready(first)
        t_pre += time.perf_counter() - t1
        t1 = time.perf_counter()
        # the static batch cannot release a finished slot: it decodes the
        # full G steps for everyone in the wave, each row at its own
        # prefix+prompt start position
        gen_toks, _ = generate(params, cache, jnp.asarray(first)[:, None],
                               jnp.asarray(fls + lens))
        jax.block_until_ready(gen_toks)
        t_dec += time.perf_counter() - t1
        first_np, gen_np = np.asarray(first), np.asarray(gen_toks)
        for j, r in enumerate(wave):
            # same convention as continuous mode: a budget of g counts g
            # tokens (the prefill-sampled first token is inside the budget)
            g = min(r.max_new_tokens, G)
            request_tokens[r.rid] = (
                [int(first_np[j])] + [int(t) for t in gen_np[j, :g - 1]])
            toks_useful += g
        waves += 1
    wall = time.perf_counter() - t0
    out = {
        "mode": "static",
        "requests": len(reqs),
        "tokens": toks_useful,
        "wall_s": wall,
        "decode_s": t_dec,
        "prefill_s": t_pre,
        "decode_ticks": waves * G,
        "decode_tok_per_s": toks_useful / t_dec if t_dec else 0.0,
        "request_tokens": request_tokens,
    }
    print(f"[serve] static baseline: {len(reqs)} requests in {waves} "
          f"waves of {B}: {toks_useful} useful tokens, decode "
          f"{out['decode_tok_per_s']:.0f} tok/s ({t_dec:.2f}s)")
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--image", required=True)
    ap.add_argument("--platform", default=None)
    ap.add_argument("--mode", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--pods", type=int, default=1,
                    help="pods behind a PodRouter (>1 = multi-pod fleet)")
    ap.add_argument("--policy",
                    choices=("shortest-queue", "consistent-hash",
                             "prefix-hash"),
                    default="shortest-queue",
                    help="router placement policy (--pods > 1); prefix-hash "
                         "places on the shared-prefix digest so cache hits "
                         "land on the pod that owns the pages")
    ap.add_argument("--fabric", choices=("none", "loopback", "proc"),
                    default="none",
                    help="serve over the cross-host fabric: workers speak "
                         "the framed message protocol in-process "
                         "(loopback) or as one OS process per pod (proc)")
    ap.add_argument("--min-pods", type=int, default=1,
                    help="elastic floor (--fabric): the fleet heals back "
                         "to this many pods after evictions")
    ap.add_argument("--max-pods", type=int, default=None,
                    help="elastic ceiling (--fabric); default --pods")
    ap.add_argument("--heartbeat-every", type=int, default=4,
                    help="fabric liveness probe cadence in ticks")
    ap.add_argument("--miss-limit", type=int, default=2,
                    help="consecutive missed probes before eviction")
    ap.add_argument("--scale-up-tokens", type=int, default=None,
                    help="spawn a pod when outstanding tokens per live "
                         "pod exceed N (--fabric)")
    ap.add_argument("--scale-idle-ticks", type=int, default=None,
                    help="drain+retire the newest pod after N idle ticks "
                         "(--fabric)")
    ap.add_argument("--slots", type=int, default=8,
                    help="KV slots per replica (static: the batch size)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--arrive-per-tick", type=int, default=8,
                    help="staggered arrivals: requests arriving per tick")
    ap.add_argument("--fairness-cap", type=int, default=8)
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache (shared page pool + Pallas "
                         "paged-attention) instead of per-slot slabs")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefix-cache", action="store_true",
                    help="copy-on-write prefix page sharing for requests "
                         "declaring a shared leading block (implies --paged)")
    ap.add_argument("--spill-pages", type=int, default=0,
                    help="host-RAM spill tier for evicted prefix pages: "
                         "0 disables, -1 is unbounded, N caps the store at "
                         "N pages (requires --prefix-cache)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend one fixed N-token system prompt to every "
                         "request (the shared-prefix trace)")
    ap.add_argument("--batch-every", type=int, default=0,
                    help="tag every Nth request as the batch QoS class "
                         "(sheddable + preemptible); 0 = all interactive")
    ap.add_argument("--deadline-ticks", type=int, default=None,
                    help="admission deadline for batch requests: shed if "
                         "not admitted within D ticks of arrival")
    ap.add_argument("--shed-queue-depth", type=int, default=None,
                    help="router shedding threshold (--pods > 1): shed "
                         "batch submissions when every fitting pod's "
                         "queue_depth gauge is at or over N")
    ap.add_argument("--shed-ttft-p99", type=int, default=None,
                    help="router shedding threshold (--pods > 1): shed "
                         "batch submissions when every fitting pod's "
                         "ttft p99 is at or over N ticks")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export the run's request-lifecycle spans as "
                         "Chrome trace-event JSON (open in Perfetto)")
    ap.add_argument("--root", default=".stevedore")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.prefix_cache:
        args.paged = True           # prefix sharing is page-granular
    if args.spill_pages:
        if not args.prefix_cache:
            ap.error("--spill-pages requires --prefix-cache (the spill "
                     "tier holds evicted prefix-registry pages)")
        if args.spill_pages < 0:
            args.spill_pages = None     # unbounded host store
    if args.mode == "static" and args.fabric != "none":
        ap.error("--fabric applies to continuous mode only")
    if args.mode == "static" and args.pods > 1:
        # never let a "static fleet" silently serve from one host: the
        # static baseline has no router tier, and comparing it against an
        # N-pod continuous run would be N-times biased
        ap.error("--pods applies to continuous mode only "
                 "(static is the single-host baseline)")

    rt = Runtime(args.root)
    # a registry ref is passed through as a ref so the Pod stays
    # tag-upgradable (RollingDeployer re-resolves it); an Imagefile is built
    image = (rt.build(Path(args.image).read_text())
             if Path(args.image).exists() else args.image)
    if args.mode == "static":
        return serve_static(rt, image, args)
    if args.fabric != "none":
        return serve_fabric(rt, image, args)
    return serve_continuous(rt, image, args)


if __name__ == "__main__":
    main()
