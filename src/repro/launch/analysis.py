"""Roofline analysis over compiled dry-run artifacts.

Three terms per (arch x shape x mesh), at TPU v5e constants:

  compute    = HLO_FLOPs_per_device / peak_FLOPs          (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
  collective = wire_bytes_per_device / link_bw            (~50 GB/s/link ICI)

``cost_analysis()`` on the partitioned module reports PER-DEVICE flops/bytes
(verified empirically), so the terms above divide by one chip's peak.
Collective bytes are parsed from the partitioned HLO text (per-device shard
shapes): all-gather counts its result, reduce-scatter / all-to-all /
collective-permute their operands, all-reduce its operands x2 (ring
RS+AG decomposition).

Scan correction: HloCostAnalysis counts a while-loop body ONCE regardless of
trip count, so scanned layer stacks would be under-counted by ~n_layers.
The dry-run therefore lowers per-stage *unit probes* and the reported totals
are   full_module + sum_s (count_s - 1) * unit_probe_s   for flops, bytes
and collective bytes alike.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

# ---- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link (per-device wire budget proxy)
HBM_PER_CHIP = 16 * 1024**3     # 16 GiB

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def _shape_bytes(typestr: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(typestr):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)
    cross_pod_bytes: int = 0     # bytes in collectives spanning a pod boundary

    @property
    def wire_bytes(self) -> int:
        """Per-device bytes on the wire; all-reduce weighted x2 (ring)."""
        total = 0
        for op, b in self.bytes_by_op.items():
            total += 2 * b if op == "all-reduce" else b
        return total

    def add(self, other: "CollectiveStats", scale: int = 1) -> None:
        for op, b in other.bytes_by_op.items():
            self.bytes_by_op[op] = self.bytes_by_op.get(op, 0) + b * scale
        for op, c in other.count_by_op.items():
            self.count_by_op[op] = self.count_by_op.get(op, 0) + c * scale
        self.cross_pod_bytes += other.cross_pod_bytes * scale


_GROUPS_RE = re.compile(r"replica_groups=(?:\{\{([0-9,]+)\}|\[(\d+),(\d+)\])")
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[0-9,]+\}(?:,\{[0-9,]+\})*)\}")


def _group_size(line: str) -> int:
    """Participants per replica group (1 if absent/unparseable)."""
    m = _GROUPS_RE.search(line)
    if not m:
        return 1
    if m.group(1) is not None:
        return m.group(1).count(",") + 1
    return int(m.group(3))          # iota form [n_groups, group_size]


def _crosses_pod(line: str, n_devices: int, pod_size: int) -> bool:
    """True iff any replica group spans a pod boundary (id // pod_size).

    Handles both explicit ``{{0,256},...}`` and iota
    ``[G,S]<=[dims]T(perm)`` forms (materialised exactly).
    """
    m = _IOTA_RE.search(line)
    if m:
        import numpy as np
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(p) for p in m.group(4).split(",")])
        groups = ids.reshape(g, s)
        pods = groups // pod_size
        return bool((pods != pods[:, :1]).any())
    m = _EXPLICIT_RE.search(line)
    if m:
        for grp in m.group(1)[1:-1].split("},{"):
            ids = [int(x) for x in grp.split(",")]
            if len({i // pod_size for i in ids}) > 1:
                return True
        return False
    return False


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-device collective bytes from partitioned HLO text.

    Byte accounting is RESULT-based (operand types are not always printed):
      all-gather          result           (~bytes received per device)
      all-reduce          result           (x2 ring factor in wire_bytes)
      reduce-scatter      result x group   (operand = result x participants)
      all-to-all          result           (send ~= recv)
      collective-permute  result
    metadata/op_name strings are stripped first (they can contain shape-like
    text from source locations); ``-done`` lines don't match the pattern so
    async pairs count once.
    """
    st = CollectiveStats()
    mnum = re.search(r"num_partitions=(\d+)", hlo_text[:4000])
    n_dev = int(mnum.group(1)) if mnum else 1
    pod_size = 256 if n_dev > 256 else n_dev   # 2x16x16 production mesh
    for line in hlo_text.splitlines():
        stripped = line.split(", metadata=")[0]
        m = _COLL_RE.search(stripped)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        shapes = [_shape_bytes(s) for s in re.findall(
            r"[a-z0-9]+\[[0-9,]*\]", result_type)]
        nbytes = max(shapes) if shapes else 0
        if op == "reduce-scatter":
            nbytes *= _group_size(stripped)
        st.bytes_by_op[op] = st.bytes_by_op.get(op, 0) + nbytes
        st.count_by_op[op] = st.count_by_op.get(op, 0) + 1
        if n_dev > 256 and _crosses_pod(stripped, n_dev, pod_size):
            st.cross_pod_bytes += 2 * nbytes if op == "all-reduce" else nbytes
    return st


@dataclass
class Cost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collectives: CollectiveStats = field(default_factory=CollectiveStats)

    def add(self, other: "Cost", scale: int = 1) -> None:
        self.flops += other.flops * scale
        self.bytes_accessed += other.bytes_accessed * scale
        self.collectives.add(other.collectives, scale)


def cost_of(compiled, hlo_text: str | None = None) -> Cost:
    ca = compiled.cost_analysis() or {}
    text = hlo_text if hlo_text is not None else compiled.as_text()
    return Cost(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        collectives=parse_collectives(text),
    )


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    model_flops_global: float
    n_devices: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO flops aggregated over devices)."""
        total = self.flops_per_device * self.n_devices
        return self.model_flops_global / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """(useful work at peak) / (time the dominant term implies).

        == MFU if compute-bound with zero waste."""
        ideal = self.model_flops_global / (self.n_devices * PEAK_FLOPS_BF16)
        return ideal / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "model_flops_global": self.model_flops_global,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
            "n_devices": self.n_devices,
        }


def roofline(cost: Cost, model_flops_global: float, n_devices: int) -> Roofline:
    return Roofline(
        compute_s=cost.flops / PEAK_FLOPS_BF16,
        memory_s=cost.bytes_accessed / HBM_BW,
        collective_s=cost.collectives.wire_bytes / ICI_BW,
        flops_per_device=cost.flops,
        bytes_per_device=cost.bytes_accessed,
        wire_bytes_per_device=cost.collectives.wire_bytes,
        model_flops_global=model_flops_global,
        n_devices=n_devices,
    )


def model_flops(cfg, cell, tokens_override: float | None = None) -> float:
    """6·N·D (train) / 2·N·D (prefill & decode); N = flop-participating,
    *active* params for MoE."""
    n_active = cfg.param_count(active_only=True)
    if not cfg.tie_embeddings:
        n_active -= cfg.vocab_size * cfg.d_model   # lookup table does no flops
    tokens = tokens_override
    if tokens is None:
        tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    mult = 6.0 if cell.kind == "train" else 2.0
    return mult * n_active * tokens
