import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: for the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh,
``jax.jit(step).lower(**input_specs).compile()`` must succeed for every
assigned architecture x input-shape cell, and the compiled artifact yields
memory_analysis (fits?) + cost_analysis (FLOPs/bytes) + the collective
schedule for EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      --arch all --shape all --mesh pod,multipod --out results/dryrun

Per-cell JSON artifacts land under --out; rerunning skips cells whose
artifact already exists (crash-resumable, like any decent launcher).
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, get_config
from repro.core.container import Container
from repro.core.image import ImageBuilder
from repro.launch.analysis import (
    HBM_PER_CHIP, Cost, cost_of, model_flops, parse_collectives, roofline,
)
from repro.models.config import SHAPE_CELLS, get_shape_cell, long_context_capable

MESH_PLATFORMS = {"pod": "pod", "multipod": "multipod"}


def build_image(arch: str, shape: str, platform: str, *,
                collectives: str = "generic", settings: dict | None = None,
                precision: dict | None = None,
                arch_overrides: dict | None = None,
                collective_options: dict | None = None):
    b = (ImageBuilder.from_scratch()
         .arch(arch, **(arch_overrides or {}))
         .shape(shape)
         .mesh(platform)
         .precision(**(precision or
                       {"params": "float32", "compute": "bfloat16"}))
         .collectives(collectives, **(collective_options or {})))
    if settings:
        b.set(**settings)
    return b.build()


def skip_reason(arch: str, shape: str) -> str | None:
    cfg = get_config(arch)
    if shape == "long_500k" and not long_context_capable(cfg):
        return ("pure full-attention arch: 512k cached decode is quadratic-"
                "cost; cell skipped per assignment (DESIGN.md §4)")
    return None


def run_cell(arch: str, shape: str, platform: str, *,
             collectives: str = "generic", settings: dict | None = None,
             precision: dict | None = None,
             arch_overrides: dict | None = None,
             collective_options: dict | None = None,
             probes: bool = True) -> dict:
    """Lower+compile one cell; returns the result record."""
    t_start = time.perf_counter()
    image = build_image(arch, shape, platform,
                        collectives=collectives, settings=settings,
                        precision=precision, arch_overrides=arch_overrides,
                        collective_options=collective_options)
    c = Container(image, platform=platform)
    kind = c.cell.kind

    lowered = c.lower_step(kind)
    t_lower = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter()

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    total = cost_of(compiled, hlo)

    stage_counts = [st.count for st in c.model.stages]
    probe_info = []
    if probes:
        for si, st in enumerate(c.model.stages):
            if st.count <= 1:
                probe_info.append({"stage": si, "count": st.count,
                                   "scaled": False})
                continue
            pl, count = c.lower_unit_probe(si, kind)
            pc = pl.compile()
            unit_cost = cost_of(pc)
            total.add(unit_cost, count - 1)
            probe_info.append({
                "stage": si, "count": count, "scaled": True,
                "unit_flops": unit_cost.flops,
                "unit_bytes": unit_cost.bytes_accessed,
                "unit_wire_bytes": unit_cost.collectives.wire_bytes,
            })

    n_dev = c.mesh.devices.size
    mf = model_flops(c.arch, c.cell)
    rl = roofline(total, mf, n_dev)

    args_b = int(mem.argument_size_in_bytes)
    out_b = int(mem.output_size_in_bytes)
    tmp_b = int(mem.temp_size_in_bytes)
    alias_b = int(mem.alias_size_in_bytes)
    resident = args_b + tmp_b + max(0, out_b - alias_b)
    record = {
        "arch": arch, "shape": shape, "mesh": platform, "kind": kind,
        "status": "ok",
        "image": image.digest,
        "abi": collectives,
        "settings": settings or {},
        "precision": precision or {"params": "float32", "compute": "bfloat16"},
        "arch_overrides": arch_overrides or {},
        "n_devices": n_dev,
        "seconds": {"lower": t_lower - t_start,
                    "compile": t_compile - t_lower},
        "memory": {
            "argument_bytes": args_b, "output_bytes": out_b,
            "temp_bytes": tmp_b, "alias_bytes": alias_b,
            "resident_bytes_per_device": resident,
            "hbm_per_chip": HBM_PER_CHIP,
            "fits_hbm": resident <= HBM_PER_CHIP,
        },
        "cost": {
            "flops_per_device": total.flops,
            "bytes_per_device": total.bytes_accessed,
            "collective_bytes_by_op": total.collectives.bytes_by_op,
            "collective_count_by_op": total.collectives.count_by_op,
            "wire_bytes_per_device": total.collectives.wire_bytes,
            "cross_pod_bytes_per_device": total.collectives.cross_pod_bytes,
        },
        "stages": stage_counts,
        "probes": probe_info,
        "roofline": rl.to_dict(),
    }
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="pod,multipod")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--collectives", default="generic")
    ap.add_argument("--settings", default="")
    ap.add_argument("--precision", default="",
                    help='JSON, e.g. {"params":"bfloat16","compute":"bfloat16"}')
    ap.add_argument("--arch-overrides", default="",
                    help='JSON ModelConfig overrides, e.g. {"attn_score_dtype":"bfloat16"}')
    ap.add_argument("--collective-options", default="",
                    help='JSON ABI options, e.g. {"mode":"explicit","zero1":false}')
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="",
                    help="suffix for artifact filenames (perf variants)")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = (list(SHAPE_CELLS) if args.shape == "all"
              else args.shape.split(","))
    meshes = args.mesh.split(",")
    # default: activation checkpointing on (required to fit ANY large train
    # cell; orthogonal to the paper-faithful generic-vs-host ABI axis)
    settings = json.loads(args.settings) if args.settings else {"remat": "dots"}
    precision = json.loads(args.precision) if args.precision else None
    arch_overrides = (json.loads(args.arch_overrides)
                      if args.arch_overrides else None)
    collective_options = (json.loads(args.collective_options)
                          if args.collective_options else None)

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                tag = f"-{args.tag}" if args.tag else ""
                name = f"{arch}__{shape}__{mesh}{tag}.json"
                path = out / name
                if path.exists() and not args.force:
                    print(f"[skip-cached] {name}")
                    continue
                reason = skip_reason(arch, shape)
                if reason:
                    rec = {"arch": arch, "shape": shape, "mesh": mesh,
                           "status": "skipped", "reason": reason}
                    path.write_text(json.dumps(rec, indent=2))
                    n_skip += 1
                    print(f"[skipped]  {name}: {reason[:60]}...")
                    continue
                t0 = time.perf_counter()
                try:
                    rec = run_cell(arch, shape, mesh,
                                   collectives=args.collectives,
                                   settings=settings,
                                   precision=precision,
                                   arch_overrides=arch_overrides,
                                   collective_options=collective_options,
                                   probes=not args.no_probes)
                    path.write_text(json.dumps(rec, indent=2))
                    n_ok += 1
                    rl = rec["roofline"]
                    print(f"[ok {time.perf_counter()-t0:6.1f}s] {name} "
                          f"dom={rl['dominant']:10s} "
                          f"bound={rl['compute_s']:.2e}/{rl['memory_s']:.2e}/"
                          f"{rl['collective_s']:.2e}s "
                          f"mem/dev={rec['memory']['resident_bytes_per_device']/2**30:.2f}GiB")
                except Exception as e:
                    n_fail += 1
                    rec = {"arch": arch, "shape": shape, "mesh": mesh,
                           "status": "failed", "error": str(e),
                           "traceback": traceback.format_exc()}
                    path.write_text(json.dumps(rec, indent=2))
                    print(f"[FAILED {time.perf_counter()-t0:6.1f}s] {name}: "
                          f"{type(e).__name__}: {str(e)[:200]}")
    print(f"\ndone: ok={n_ok} skipped={n_skip} failed={n_fail}")


if __name__ == "__main__":
    main()
