"""Shared building blocks: norms, RoPE, MLP variants, embeddings."""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamDef


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_defs(cfg: ModelConfig) -> dict:
    d = {"scale": ParamDef((cfg.d_model,), ("embed",), "ones")}
    if cfg.norm == "layernorm":
        d["bias"] = ParamDef((cfg.d_model,), ("embed",), "zeros")
    return d


def apply_norm(p: dict, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
        return (x32 * p["scale"].astype(jnp.float32)).astype(dt)
    if kind == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
        x32 = (x32 - mu) * jax.lax.rsqrt(var + eps)
        return (x32 * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)
    raise ValueError(kind)


def rms_gated(x: jax.Array, z: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Mamba-2 gated RMSNorm: rmsnorm(x * silu(z)) * scale."""
    dt = x.dtype
    y = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings (with partial-rotary support: stablelm 25%, nemotron 50%)
# ---------------------------------------------------------------------------

def rope_freqs(hd_rot: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd_rot, 2, dtype=jnp.float32) / hd_rot))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float, pct: float = 1.0) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    hd_rot = int(hd * pct)
    hd_rot -= hd_rot % 2
    if hd_rot == 0:
        return x
    xr, xp = x[..., :hd_rot], x[..., hd_rot:]
    freqs = rope_freqs(hd_rot, theta)                       # (hd_rot/2,)
    ang = positions[..., None, None].astype(jnp.float32) * freqs  # (..., S,1,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., ::2].astype(jnp.float32), xr[..., 1::2].astype(jnp.float32)
    ro = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    ro = ro.reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([ro, xp], axis=-1) if hd_rot < hd else ro


# ---------------------------------------------------------------------------
# MLPs: swiglu | geglu | gelu | relu2
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "wg": ParamDef((D, F), ("embed", "mlp")),
            "wu": ParamDef((D, F), ("embed", "mlp")),
            "wd": ParamDef((F, D), ("mlp", "embed")),
        }
    return {
        "wi": ParamDef((D, F), ("embed", "mlp")),
        "wd": ParamDef((F, D), ("mlp", "embed")),
    }


def apply_mlp(p: dict, x: jax.Array, kind: str) -> jax.Array:
    dt = x.dtype
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        h = act(x @ p["wg"].astype(dt)) * (x @ p["wu"].astype(dt))
        return h @ p["wd"].astype(dt)
    h = x @ p["wi"].astype(dt)
    if kind == "gelu":
        h = jax.nn.gelu(h)
    elif kind == "relu2":                 # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(kind)
    return h @ p["wd"].astype(dt)


# ---------------------------------------------------------------------------
# embeddings / head (with physical vocab padding, Megatron-style)
# ---------------------------------------------------------------------------

VOCAB_PAD_MULTIPLE = 128  # covers TP<=128 and XLA lane alignment


def padded_vocab(v: int) -> int:
    return ((v + VOCAB_PAD_MULTIPLE - 1) // VOCAB_PAD_MULTIPLE) * VOCAB_PAD_MULTIPLE


def embed_defs(cfg: ModelConfig) -> dict:
    vp = padded_vocab(cfg.vocab_size)
    d = {"tokens": ParamDef((vp, cfg.d_model), ("vocab", "embed"), "small_normal")}
    if not cfg.tie_embeddings:
        d["head"] = ParamDef((cfg.d_model, vp), ("embed", "vocab"))
    return d


def embed_tokens(p: dict, tokens: jax.Array, cfg: ModelConfig, dtype) -> jax.Array:
    x = p["tokens"].astype(dtype)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    return x


def lm_logits(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return x @ p["tokens"].astype(x.dtype).T
    return x @ p["head"].astype(x.dtype)
