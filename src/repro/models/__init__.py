from repro.models.config import ModelConfig, ShapeCell, SHAPE_CELLS
from repro.models.transformer import Model, build_stages

__all__ = ["ModelConfig", "ShapeCell", "SHAPE_CELLS", "Model", "build_stages"]
