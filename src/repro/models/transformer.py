"""Decoder-only LM assembly for all 10 architectures.

Scan discipline (compile-time critical at 512 devices / 95 layers):
layers are grouped into *stages*; each stage is a stack of identical *units*
scanned with ``jax.lax.scan`` over stacked parameters. A unit is one or more
blocks (recurrentgemma's cycle (rec, rec, attn) is one unit of three blocks);
remainder layers that do not complete a cycle form a trailing stage.

Block kinds: attn (full/local + MLP), moe (attn + routed MoE), ssm (Mamba-2
SSD), rec (RG-LRU + MLP).

The same stage structure drives train (no cache), prefill (collect cache as
scan ys) and decode (cache as scan xs/ys), so cache pytrees always line up
with parameter pytrees.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rec_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import AttnGeometry, resolve_geometry
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    embed_defs,
    embed_tokens,
    lm_logits,
    mlp_defs,
    norm_defs,
    padded_vocab,
)
from repro.models.params import ParamDef, stack_defs


@dataclass(frozen=True)
class Stage:
    unit: tuple[str, ...]     # block kinds within one scanned unit
    count: int                # number of units scanned


def build_stages(cfg: ModelConfig) -> tuple[Stage, ...]:
    types = cfg.layer_types()
    if len(set(types)) == 1:
        return (Stage((types[0],), len(types)),)
    if cfg.block_pattern:
        p = len(cfg.block_pattern)
        n_full, rem = divmod(len(types), p)
        stages = [Stage(tuple(cfg.block_pattern), n_full)] if n_full else []
        if rem:
            stages.append(Stage(tuple(types[n_full * p:]), 1))
        return tuple(stages)
    # run-length group consecutive identical types (first_k_dense etc.)
    stages: list[Stage] = []
    i = 0
    while i < len(types):
        j = i
        while j < len(types) and types[j] == types[i]:
            j += 1
        stages.append(Stage((types[i],), j - i))
        i = j
    if len(stages) > 6:
        raise ValueError(
            f"{cfg.name}: layer pattern fragments into {len(stages)} stages; "
            "set block_pattern explicitly for cyclic layouts"
        )
    return tuple(stages)


# ---------------------------------------------------------------------------
# per-block param defs
# ---------------------------------------------------------------------------

def block_defs(kind: str, cfg: ModelConfig, geom: AttnGeometry) -> dict:
    if kind in ("attn", "local"):
        d = {"ln1": norm_defs(cfg), "attn": attn_mod.attn_defs(cfg, geom)}
        if cfg.parallel_block:
            d["mlp"] = mlp_defs(cfg)
        else:
            d["ln2"] = norm_defs(cfg)
            d["mlp"] = mlp_defs(cfg)
        return d
    if kind == "moe":
        return {
            "ln1": norm_defs(cfg),
            "attn": attn_mod.attn_defs(cfg, geom),
            "ln2": norm_defs(cfg),
            "moe": moe_mod.moe_defs(cfg),
        }
    if kind == "ssm":
        return {"ln1": norm_defs(cfg), "ssm": ssm_mod.ssm_defs(cfg)}
    if kind == "rec":
        return {
            "ln1": norm_defs(cfg),
            "rec": rec_mod.rec_defs(cfg),
            "ln2": norm_defs(cfg),
            "mlp": mlp_defs(cfg),
        }
    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

class Model:
    """A config bound to a TP width (for head padding / kv replication).

    ``constrain`` is an optional ``fn(x, logical_axes) -> x`` injected by the
    distribution layer; the model never sees the mesh directly.
    """

    def __init__(self, cfg: ModelConfig, tp: int = 1,
                 constrain: Callable | None = None,
                 remat: str = "none", act_dtype=jnp.bfloat16,
                 moe_mesh=None):
        self.cfg = cfg
        self.geom = resolve_geometry(cfg, tp) if cfg.n_heads else None
        self.stages = build_stages(cfg)
        self.constrain = constrain or (lambda x, spec: x)
        self.remat = remat
        self.act_dtype = act_dtype
        # mesh for the shard_map EP dispatch (None -> pure-XLA fallback);
        # moe_batch_axes: None = derive from mesh, () = caller is already
        # manual over the batch axes (explicit-ABI path)
        self.moe_mesh = moe_mesh
        self.moe_batch_axes = None

    # -- params ---------------------------------------------------------
    def param_defs(self) -> dict:
        cfg = self.cfg
        d: dict = {"embed": embed_defs(cfg), "final_norm": norm_defs(cfg)}
        for si, st in enumerate(self.stages):
            unit = {
                f"b{bi}": block_defs(kind, cfg, self.geom)
                for bi, kind in enumerate(st.unit)
            }
            d[f"stage{si}"] = stack_defs(unit, st.count)
        return d

    # -- caches -----------------------------------------------------------
    def cache_defs(self, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
        cfg = self.cfg
        d: dict = {}
        for si, st in enumerate(self.stages):
            unit: dict = {}
            for bi, kind in enumerate(st.unit):
                entry = self._block_cache_defs(kind, batch, max_len, dtype)
                if entry:
                    unit[f"b{bi}"] = stack_defs(entry, st.count)
            d[f"stage{si}"] = unit
        return d

    def _block_cache_defs(self, kind: str, batch: int, max_len: int, dtype) -> dict:
        cfg = self.cfg
        if kind in ("attn", "local", "moe"):
            g = self.geom
            S = min(cfg.window, max_len) if (kind == "local" or
                                             (cfg.attn_kind == "local" and cfg.window)) else max_len
            spec = ("batch", "kv_seq", "kv_heads", None)
            return {
                "k": ParamDef((batch, S, g.n_kv, g.head_dim), spec, "zeros"),
                "v": ParamDef((batch, S, g.n_kv, g.head_dim), spec, "zeros"),
            }
        if kind == "ssm":
            di, ds = cfg.d_inner, cfg.ssm_state
            return {
                "conv": ParamDef((batch, cfg.conv_kernel - 1, di + 2 * ds),
                                 ("batch", None, "rnn"), "zeros"),
                "state": ParamDef((batch, cfg.ssm_heads, ds, cfg.ssm_headdim),
                                  ("batch", "heads", None, None), "zeros"),
            }
        if kind == "rec":
            R = cfg.rnn_width_
            return {
                "conv": ParamDef((batch, cfg.conv_kernel - 1, R),
                                 ("batch", None, "rnn"), "zeros"),
                "state": ParamDef((batch, R), ("batch", "rnn"), "zeros"),
            }
        return {}

    def paged_cache_defs(self, n_pages: int, page_size: int,
                         dtype=jnp.bfloat16) -> dict:
        """Cache defs for PAGED serving: per attention layer one global page
        pool ``(n_kv, n_pages, page_size, hd)`` shared by every slot through
        the host-side page table (one table for all layers -- allocation is
        identical layer-to-layer). Ring-buffer (windowed) and recurrent
        caches are per-slot state, not pageable history -> unsupported."""
        cfg = self.cfg
        if cfg.attn_kind == "local" and cfg.window:
            raise NotImplementedError(
                "paged KV cache does not support sliding-window attention "
                "(ring-buffer caches stay contiguous)")
        d: dict = {}
        for si, st in enumerate(self.stages):
            unit: dict = {}
            for bi, kind in enumerate(st.unit):
                if kind in ("ssm", "rec", "local"):
                    raise NotImplementedError(
                        f"paged KV cache does not support {kind!r} blocks "
                        "(windowed/recurrent caches stay contiguous)")
                g = self.geom
                spec = ("kv_heads", None, None, None)
                unit[f"b{bi}"] = stack_defs({
                    "k": ParamDef((g.n_kv, n_pages, page_size, g.head_dim),
                                  spec, "zeros"),
                    "v": ParamDef((g.n_kv, n_pages, page_size, g.head_dim),
                                  spec, "zeros"),
                }, st.count)
            d[f"stage{si}"] = unit
        return d

    # -- forward (train / prefill) ------------------------------------------
    def forward(self, params: dict, tokens: jax.Array,
                frontend_embeds: jax.Array | None = None,
                frontend_len: jax.Array | None = None,
                collect_cache: bool = False, cache_len: int | None = None,
                prefix_kv: dict | None = None,
                prefix_pages: jax.Array | None = None,
                prefix_len: int = 0):
        """tokens: (B, S_tok). Returns logits (B,S,Vp) [, cache].

        ``frontend_embeds`` (B, F, D) is a modality prefix prepended ahead of
        the token embeddings. ``frontend_len`` (scalar or (B,)) marks how many
        of the F buffer rows are real: the prefix and tokens are then packed
        contiguously (real frontend rows, then tokens, then all the right-pad
        garbage) so positions stay gap-free and the causal mask hides every
        pad row -- the serving path's right-pad contract. With
        ``frontend_len == F`` the pack is the identity gather, bitwise equal
        to the plain concatenation the train path uses.

        SUFFIX prefill over a shared KV prefix (the paged prefix cache):
        with ``prefix_len > 0`` (static), ``tokens`` are only the UNCACHED
        suffix of a prompt whose first ``prefix_len`` positions already sit
        in the paged pool ``prefix_kv`` (the per-stage paged_cache_defs
        tree) at the physical pages listed in ``prefix_pages``
        ((ceil(prefix_len / page_size),) int32 -- the last page may be only
        partially covered when the shared prefix ends mid-page; positions
        past ``prefix_len`` are sliced off). Token positions are offset past
        the prefix (RoPE included) and every attention block gathers the
        prefix pages and attends over [prefix, suffix]; the collected cache
        covers the SUFFIX positions only. Full-attention archs only --
        exactly the archs the paged pool itself admits."""
        cfg = self.cfg
        dtype = self.act_dtype
        if prefix_len:
            if frontend_embeds is not None:
                raise NotImplementedError(
                    "prefix-cached suffix prefill does not compose with "
                    "frontend embeddings")
            if prefix_kv is None or prefix_pages is None:
                raise ValueError("prefix_len > 0 needs prefix_kv + "
                                 "prefix_pages")
        x = embed_tokens(params["embed"], tokens, cfg, dtype)
        if frontend_embeds is not None:
            fe = frontend_embeds.astype(dtype)
            x = jnp.concatenate([fe, x], axis=1)
            if frontend_len is not None:
                F, S = fe.shape[1], x.shape[1]
                fl = jnp.broadcast_to(
                    jnp.asarray(frontend_len, jnp.int32),
                    (x.shape[0],))[:, None]
                pos = jnp.arange(S, dtype=jnp.int32)[None, :]
                src = jnp.where(pos < fl, pos, pos + (F - fl))
                src = jnp.minimum(src, S - 1)   # tail rows: clamped garbage
                x = jnp.take_along_axis(x, src[:, :, None], axis=1)
        B, S, _ = x.shape
        x = self.constrain(x, ("batch", "seq", "embed"))
        # (1, S): positions are batch-independent in train/prefill, so the
        # causal mask materialises as (1, Sq, Sk) instead of (B, Sq, Sk).
        # Suffix prefill offsets them past the cached prefix.
        positions = prefix_len + jnp.arange(S, dtype=jnp.int32)[None, :]

        caches = {}
        aux_total = jnp.zeros((), jnp.float32)
        for si, st in enumerate(self.stages):
            body = self._make_body(st, positions, collect_cache,
                                   cache_len or S,
                                   prefix_pages=prefix_pages,
                                   prefix_len=prefix_len)
            if self.remat != "none":
                body = _remat(body, self.remat)
            xs = ((params[f"stage{si}"], prefix_kv[f"stage{si}"])
                  if prefix_len else params[f"stage{si}"])
            (x, aux), ys = jax.lax.scan(body, (x, aux_total), xs)
            aux_total = aux
            if collect_cache:
                caches[f"stage{si}"] = ys
        x = apply_norm(params["final_norm"], x, cfg.norm)
        logits = lm_logits(params["embed"], x, cfg)
        logits = self.constrain(logits, ("batch", "seq", "vocab"))
        if collect_cache:
            return logits, caches, aux_total
        return logits, aux_total

    def _make_body(self, st: Stage, positions, collect_cache: bool,
                   cache_len: int, prefix_pages=None, prefix_len: int = 0):
        cfg, geom = self.cfg, self.geom

        def body(carry, xs):
            x, aux = carry
            unit_params, unit_prefix = xs if prefix_len else (xs, None)
            entries = {}
            for bi, kind in enumerate(st.unit):
                p = unit_params[f"b{bi}"]
                pkv = unit_prefix.get(f"b{bi}") if unit_prefix else None
                x, aux_b, entry = self._apply_block(kind, p, x, positions,
                                                    collect_cache, cache_len,
                                                    prefix_kv=pkv,
                                                    prefix_pages=prefix_pages,
                                                    prefix_len=prefix_len)
                aux = aux + aux_b
                if collect_cache and entry is not None:
                    entries[f"b{bi}"] = entry
            return (x, aux), (entries if collect_cache else None)

        return body

    def _apply_block(self, kind: str, p: dict, x, positions,
                     collect_cache: bool, cache_len: int,
                     prefix_kv=None, prefix_pages=None, prefix_len: int = 0):
        cfg, geom = self.cfg, self.geom
        aux = jnp.zeros((), jnp.float32)
        entry = None
        window = cfg.window if (kind == "local" or cfg.attn_kind == "local") else 0
        if prefix_len and (window or kind in ("ssm", "rec")):
            raise NotImplementedError(
                "prefix-cached suffix prefill supports full attention only")

        if kind in ("attn", "local", "moe"):
            h = apply_norm(p["ln1"], x, cfg.norm)
            q, k, v = attn_mod.project_qkv(p["attn"], h, cfg, geom, positions)
            q = self.constrain(q, ("batch", "seq", "heads", None))
            k = self.constrain(k, ("batch", "kv_seq", "kv_heads", None))
            v = self.constrain(v, ("batch", "kv_seq", "kv_heads", None))
            k_all, v_all, kv_pos = k, v, positions
            if prefix_len:
                # gather the cached prefix pages (n_kv, kp, ps, hd) into a
                # contiguous (B, prefix_len, n_kv, hd) history ahead of the
                # suffix KV; kv positions run 0..prefix_len+S-1 while the q
                # positions stay offset past the prefix. prefix_len may end
                # MID-page (radix partial match): the last page is gathered
                # whole and the tail positions past prefix_len sliced off
                B, S = k.shape[0], k.shape[1]
                def _gather(pool):
                    n_kv, _, ps_, hd = pool.shape
                    pg = jnp.take(pool, prefix_pages, axis=1)
                    pg = pg.reshape(n_kv, -1, hd)[:, :prefix_len]
                    pg = pg.transpose(1, 0, 2)
                    return jnp.broadcast_to(pg[None], (B, prefix_len, n_kv, hd))
                k_all = jnp.concatenate(
                    [_gather(prefix_kv["k"]).astype(k.dtype), k], axis=1)
                v_all = jnp.concatenate(
                    [_gather(prefix_kv["v"]).astype(v.dtype), v], axis=1)
                kv_pos = jnp.arange(prefix_len + S, dtype=jnp.int32)[None, :]
            ctx = attn_mod.attend(q, k_all, v_all, positions, kv_pos, window,
                                  score_dtype=jnp.dtype(cfg.attn_score_dtype),
                                  q_chunk=cfg.attn_q_chunk,
                                  kv_chunk=cfg.attn_kv_chunk,
                                  q_offset=prefix_len)
            attn_out = attn_mod.attn_out(p["attn"], ctx)
            if collect_cache:
                entry = self._prefill_cache_entry(k, v, window, cache_len)
            if cfg.parallel_block:
                x = x + attn_out + apply_mlp(p["mlp"], h, cfg.mlp)
            else:
                x = x + attn_out
                h2 = apply_norm(p["ln2"], x, cfg.norm)
                if kind == "moe":
                    moe_out, aux = self._moe(p["moe"], h2)
                    x = x + moe_out
                else:
                    x = x + apply_mlp(p["mlp"], h2, cfg.mlp)
        elif kind == "ssm":
            h = apply_norm(p["ln1"], x, cfg.norm)
            if collect_cache:
                out, entry = _ssm_prefill(p["ssm"], h, cfg)
            else:
                out = ssm_mod.ssm_forward(p["ssm"], h, cfg)
            x = x + out
        elif kind == "rec":
            h = apply_norm(p["ln1"], x, cfg.norm)
            if collect_cache:
                out, entry = _rec_prefill(p["rec"], h, cfg)
            else:
                out = rec_mod.rec_forward(p["rec"], h, cfg)
            x = x + out
            h2 = apply_norm(p["ln2"], x, cfg.norm)
            x = x + apply_mlp(p["mlp"], h2, cfg.mlp)
        else:
            raise ValueError(kind)
        x = self.constrain(x, ("batch", "seq", "embed"))
        return x, aux, entry

    def _moe(self, p_moe, h):
        if self.moe_mesh is not None:
            return moe_mod.moe_forward_spmd(p_moe, h, self.cfg, self.moe_mesh,
                                            batch_axes=self.moe_batch_axes)
        return moe_mod.moe_forward(p_moe, h, self.cfg, self.constrain)

    def _prefill_cache_entry(self, k, v, window: int, cache_len: int):
        """Store the last ``cache_len`` (or window) positions into the cache.

        Windowed caches are *ring buffers* with the invariant that position p
        lives at slot ``p % ring``; the kept tail must be rolled into that
        layout or the first decoded tokens attend to permuted history."""
        S = k.shape[1]
        keep = min(window, cache_len) if window else cache_len
        if S >= keep:
            k_, v_ = k[:, S - keep:], v[:, S - keep:]
            if window:
                k_ = jnp.roll(k_, S % keep, axis=1)
                v_ = jnp.roll(v_, S % keep, axis=1)
        else:
            pad = keep - S
            k_ = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_ = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return {"k": k_, "v": v_}

    # -- decode ------------------------------------------------------------
    def decode_step(self, params: dict, cache: dict, tokens: jax.Array,
                    idx: jax.Array, page_table: jax.Array | None = None):
        """tokens: (B,1); idx: int32 position -- scalar (lockstep batch) or
        (B,) per-row positions (slot-granular continuous batching).
        -> (logits, new_cache).

        With ``page_table`` (B, max_pages) the attention caches are PAGED
        pools (see paged_cache_defs) and decode routes through the paged
        kernel; without it the caches are contiguous per-slot slabs.

        The cache rides in the scan CARRY and is updated in place with
        dynamic_update_index (params are dynamically indexed per layer).
        The earlier xs->ys formulation made XLA hold 3-4 functional copies
        of the multi-GB cache in while-loop temps (observed: 47 GiB temp
        against an 11.9 GiB cache on deepseek decode_32k); carry aliasing
        plus donated inputs keeps it at ~1 copy (EXPERIMENTS.md §Perf)."""
        cfg = self.cfg
        dtype = self.act_dtype
        x = embed_tokens(params["embed"], tokens, cfg, dtype)
        x = self.constrain(x, ("batch", "seq", "embed"))
        new_cache: dict = {}
        for si, st in enumerate(self.stages):
            body = self._make_decode_body(st, idx, page_table)
            stage_params = params[f"stage{si}"]

            def carry_body(carry, i, body=body, stage_params=stage_params):
                x, scache = carry
                up = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, False),
                    stage_params)
                uc = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, False),
                    scache)
                (x,), entries = body((x,), (up, uc))
                scache = jax.tree.map(
                    lambda buf, new: jax.lax.dynamic_update_index_in_dim(
                        buf, new.astype(buf.dtype), i, 0),
                    scache, entries)
                return (x, scache), None

            (x, sc), _ = jax.lax.scan(
                carry_body, (x, cache[f"stage{si}"]),
                jnp.arange(st.count))
            new_cache[f"stage{si}"] = sc
        x = apply_norm(params["final_norm"], x, cfg.norm)
        logits = lm_logits(params["embed"], x, cfg)
        return logits, new_cache

    def _make_decode_body(self, st: Stage, idx, page_table=None):
        cfg, geom = self.cfg, self.geom

        def body(carry, xs):
            (x,) = carry
            unit_params, unit_cache = xs
            new_entries = {}
            for bi, kind in enumerate(st.unit):
                p = unit_params[f"b{bi}"]
                c = unit_cache.get(f"b{bi}") if unit_cache else None
                window = cfg.window if (kind == "local" or
                                        cfg.attn_kind == "local") else 0
                if kind in ("attn", "local", "moe"):
                    h = apply_norm(p["ln1"], x, cfg.norm)
                    if page_table is not None:
                        out, nc = attn_mod.paged_decode_attn(
                            p["attn"], h, c, idx, page_table, cfg, geom,
                            window)
                    else:
                        out, nc = attn_mod.decode_attn(p["attn"], h, c, idx,
                                                       cfg, geom, window)
                    if cfg.parallel_block:
                        x = x + out + apply_mlp(p["mlp"], h, cfg.mlp)
                    else:
                        x = x + out
                        h2 = apply_norm(p["ln2"], x, cfg.norm)
                        if kind == "moe":
                            mo, _ = self._moe(p["moe"], h2)
                            x = x + mo
                        else:
                            x = x + apply_mlp(p["mlp"], h2, cfg.mlp)
                elif kind == "ssm":
                    h = apply_norm(p["ln1"], x, cfg.norm)
                    out, nc = ssm_mod.ssm_decode(p["ssm"], h, c, cfg)
                    x = x + out
                elif kind == "rec":
                    h = apply_norm(p["ln1"], x, cfg.norm)
                    out, nc = rec_mod.rec_decode(p["rec"], h, c, cfg)
                    x = x + out
                    h2 = apply_norm(p["ln2"], x, cfg.norm)
                    x = x + apply_mlp(p["mlp"], h2, cfg.mlp)
                else:
                    raise ValueError(kind)
                new_entries[f"b{bi}"] = nc
            return (x,), new_entries

        return body


    # -- per-unit cost probes (dry-run roofline scan correction) --------------
    def unit_param_defs(self, si: int) -> dict:
        return {
            f"b{bi}": block_defs(kind, self.cfg, self.geom)
            for bi, kind in enumerate(self.stages[si].unit)
        }

    def unit_cache_defs(self, si: int, batch: int, max_len: int, dtype) -> dict:
        out = {}
        for bi, kind in enumerate(self.stages[si].unit):
            entry = self._block_cache_defs(kind, batch, max_len, dtype)
            if entry:
                out[f"b{bi}"] = entry
        return out

    def unit_probe(self, si: int, kind: str):
        """A standalone function whose HLO cost == one scan iteration of
        stage ``si`` (XLA's cost analysis counts while bodies once; the
        dry-run multiplies these probes by (count-1) to correct totals).

        kind: 'train' (fwd+bwd), 'prefill' (fwd + cache collect),
              'decode' (one-token step with cache update)."""
        st = self.stages[si]

        def fwd(unit_params, x, positions, collect):
            body = self._make_body(st, positions, collect, x.shape[1])
            if self.remat != "none" and kind == "train":
                body = _remat(body, self.remat)
            (x2, aux), ys = body((x, jnp.zeros((), jnp.float32)), unit_params)
            return x2, aux, ys

        if kind == "train":
            def probe(unit_params, x, positions):
                def loss(up, xx):
                    x2, aux, _ = fwd(up, xx, positions, False)
                    return jnp.sum(x2.astype(jnp.float32) ** 2) * 1e-6 + aux
                gp, gx = jax.grad(loss, argnums=(0, 1))(unit_params, x)
                return gp, gx
            return probe
        if kind == "prefill":
            def probe(unit_params, x, positions):
                x2, aux, ys = fwd(unit_params, x, positions, True)
                return x2, aux, ys
            return probe
        if kind == "decode":
            def probe(unit_params, unit_cache, x, idx):
                body = self._make_decode_body(st, idx)
                (x2,), entries = body((x,), (unit_params, unit_cache))
                return x2, entries
            return probe
        raise ValueError(kind)


# ---------------------------------------------------------------------------
# prefill variants that also return final recurrent states
# ---------------------------------------------------------------------------

def _ssm_prefill(p, x, cfg: ModelConfig):
    """ssm_forward with the decode cache (final chunk state + conv tail)."""
    return ssm_mod.ssm_forward(p, x, cfg, return_cache=True)


def _rec_prefill(p, x, cfg: ModelConfig):
    dt_ = x.dtype
    y = jax.nn.gelu(x @ p["wy"].astype(dt_))
    raw = x @ p["wx"].astype(dt_)
    xr = rec_mod._causal_conv(raw, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_))
    a, b = rec_mod._rglru_coeffs(p, xr)
    h = rec_mod.rglru_scan(a, b)
    out = (h.astype(dt_) * y) @ p["wo"].astype(dt_)
    return out, {"conv": raw[:, -(cfg.conv_kernel - 1):], "state": h[:, -1]}


def _remat(body, mode: str):
    if mode == "full":
        return jax.checkpoint(body, policy=None)
    if mode == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(f"unknown remat mode {mode!r}")
