"""Mamba-2 / SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD algorithm (the paper's "quadratic-within-chunk, linear-across-
chunk" decomposition -- this is what makes SSM training matmul-dominated and
MXU-friendly on TPU):

  per chunk of length Q:
    L[i,j]   = exp(cum_a_i - cum_a_j) * dt_j        (i >= j, intra-chunk decay)
    Y_intra  = ((C B^T) .* L) X                      -- quadratic in Q only
    S_chunk  = sum_j exp(cum_a_last - cum_a_j) dt_j B_j (x) X_j   (H,N,P)
  across chunks:
    S_k      = exp(sum_a_k) S_{k-1} + S_chunk_k      -- associative scan
    Y_inter  = (C_i exp(cum_a_i)) . S_{k-1}
  Y = Y_intra + Y_inter + D*X, then gated RMSNorm and out-projection.

Projections are kept *separate* (wz/wx/wB/wC/wdt rather than one fused
in_proj) so each piece takes its natural sharding: d_inner -> model TP,
B/C state dims replicated, dt heads -> model. Same FLOPs, cleaner SPMD
(noted in DESIGN.md as a layout deviation from the reference CUDA code).

Decode is the O(1) recurrence: h = exp(dt*A) h + dt * B (x) x ; y = C.h + D x.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rms_gated
from repro.models.params import ParamDef


def ssm_defs(cfg: ModelConfig) -> dict:
    D, di, ds, nh, K = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                        cfg.ssm_heads, cfg.conv_kernel)
    conv_ch = di + 2 * ds      # conv runs over (x, B, C) channels
    return {
        "wz": ParamDef((D, di), ("embed", "rnn")),
        "wx": ParamDef((D, di), ("embed", "rnn")),
        "wB": ParamDef((D, ds), ("embed", None)),
        "wC": ParamDef((D, ds), ("embed", None)),
        "wdt": ParamDef((D, nh), ("embed", "heads")),
        "conv_w": ParamDef((conv_ch, K), ("rnn", None), "normal", 0.1),
        "conv_b": ParamDef((conv_ch,), ("rnn",), "zeros"),
        "A_log": ParamDef((nh,), ("heads",), "normal", 0.5),
        "D": ParamDef((nh,), ("heads",), "ones"),
        "dt_bias": ParamDef((nh,), ("heads",), "zeros"),
        "gate_norm": ParamDef((di,), ("rnn",), "ones"),
        "out": ParamDef((di, D), ("rnn", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. x: (B,S,Ch), w: (Ch,K)."""
    K = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, :, None].transpose(1, 2, 0),           # (K, 1, Ch) KIO
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return jax.nn.silu(out + b)


def _ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD scan. x: (b,S,H,P); dt: (b,S,H); A: (H,)<0; B,C: (b,S,N).

    Returns y: (b,S,H,P). Group count fixed at 1 (per the 2.7b config)."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    nc = S // chunk
    xq = x.reshape(b, nc, chunk, H, P)
    dtq = dt.reshape(b, nc, chunk, H)
    Bq = B.reshape(b, nc, chunk, N)
    Cq = C.reshape(b, nc, chunk, N)

    da = dtq * A                                           # (b,nc,Q,H) negative
    cum = jnp.cumsum(da, axis=2)                           # inclusive cumsum
    seg_total = cum[:, :, -1]                              # (b,nc,H)

    # ---- intra-chunk (quadratic in chunk length; matmul-dominated) ---------
    scores = jnp.einsum("bcin,bcjn->bcij", Cq, Bq)         # (b,nc,Q,Q)
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])
    # decay L[i,j] = exp(cum_i - cum_j) * dt_j   per head
    L = jnp.exp(
        jnp.clip(cum[:, :, :, None, :] - cum[:, :, None, :, :], -60.0, 0.0)
    ) * dtq[:, :, None, :, :]                              # (b,nc,Q,Q,H)
    L = jnp.where(causal[None, None, :, :, None], L, 0.0)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, L, xq)

    # ---- chunk boundary states ---------------------------------------------
    wts = jnp.exp(jnp.clip(seg_total[:, :, None, :] - cum, -60.0, 0.0)) * dtq
    # S_chunk[b,c,h,n,p] = sum_j wts[...,j,h] * B[...,j,n] * x[...,j,h,p]
    s_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", wts, Bq, xq)

    # ---- inter-chunk recurrence: S_k = g_k * S_{k-1} + S_chunk_k ------------
    g = jnp.exp(jnp.clip(seg_total, -60.0, 0.0))           # (b,nc,H)

    def combine(a, b_):
        ga, sa = a
        gb, sb = b_
        return ga * gb, sb + gb[..., None, None] * sa

    gs, ss = jax.lax.associative_scan(combine, (g, s_chunk), axis=1)
    # state *entering* chunk c = scanned state of chunk c-1
    s_prev = jnp.concatenate(
        [jnp.zeros_like(ss[:, :1]), ss[:, :-1]], axis=1
    )                                                      # (b,nc,H,N,P)

    # ---- inter-chunk contribution -------------------------------------------
    cin = Cq[:, :, :, None, :] * jnp.exp(
        jnp.clip(cum, -60.0, 0.0)
    )[..., None]                                           # (b,nc,Q,H,N)
    y_inter = jnp.einsum("bcihn,bchnp->bcihp", cin, s_prev)

    y = (y_intra + y_inter).reshape(b, S, H, P)
    return y, ss[:, -1]                                    # (.., final state)


def ssm_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                return_cache: bool = False):
    """Training/prefill forward. x: (B,S,D) -> (B,S,D) [, decode cache].

    Padded tail steps (to a chunk multiple) only influence later positions,
    so real outputs are unaffected; BUT the final *state* must be taken at
    the true position S, so when a cache is requested we avoid padding by
    asserting chunk-divisibility (all assigned cells are powers of two)."""
    Bsz, S, D = x.shape
    di, ds, nh, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    dt_ = x.dtype

    z = x @ p["wz"].astype(dt_)
    xi = x @ p["wx"].astype(dt_)
    Bm = x @ p["wB"].astype(dt_)
    Cm = x @ p["wC"].astype(dt_)
    dt = x @ p["wdt"].astype(dt_)

    conv_in = jnp.concatenate([xi, Bm, Cm], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_))
    xi, Bm, Cm = jnp.split(conv_out, [di, di + ds], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (H,)

    xh = xi.reshape(Bsz, S, nh, P)
    chunk = min(cfg.ssm_chunk, S)
    pad = (-S) % chunk
    if pad and return_cache:
        raise ValueError(f"prefill length {S} must be divisible by ssm_chunk "
                         f"{chunk} when a decode cache is requested")
    if pad:
        # zero-pad the tail to a chunk multiple; padded steps only influence
        # later (sliced-off) positions, so real outputs are unaffected.
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y, final_state = _ssd_chunked(xh.astype(jnp.float32), dt, A,
                                  Bm.astype(jnp.float32),
                                  Cm.astype(jnp.float32), chunk)
    if pad:
        y = y[:, :S]
        xh = xh[:, :S]
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, di).astype(dt_)

    y = rms_gated(y, z, p["gate_norm"])
    out = y @ p["out"].astype(dt_)
    if return_cache:
        cache = {"conv": conv_in[:, -(cfg.conv_kernel - 1):],
                 "state": final_state}
        return out, cache
    return out


# ---------------------------------------------------------------------------
# decode (O(1) state update)
# ---------------------------------------------------------------------------

def init_ssm_cache(n_layers: int, batch: int, cfg: ModelConfig, dtype) -> dict:
    di, ds, nh, P, K = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                        cfg.ssm_headdim, cfg.conv_kernel)
    return {
        "conv": jnp.zeros((n_layers, batch, K - 1, di + 2 * ds), dtype),
        "state": jnp.zeros((n_layers, batch, nh, ds, P), jnp.float32),
    }


def ssm_cache_specs():
    return {
        "conv": ("layers", "batch", None, "rnn"),
        "state": ("layers", "batch", "heads", None, None),
    }


def ssm_decode(p: dict, x: jax.Array, cache: dict, cfg: ModelConfig):
    """x: (B,1,D); cache: {conv (B,K-1,Ch), state (B,H,N,P)}."""
    Bsz = x.shape[0]
    di, ds, nh, P, K = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                        cfg.ssm_headdim, cfg.conv_kernel)
    dt_ = x.dtype
    xt = x[:, 0]                                           # (B,D)

    z = xt @ p["wz"].astype(dt_)
    xi = xt @ p["wx"].astype(dt_)
    Bm = xt @ p["wB"].astype(dt_)
    Cm = xt @ p["wC"].astype(dt_)
    dt = xt @ p["wdt"].astype(dt_)

    conv_in = jnp.concatenate([xi, Bm, Cm], axis=-1)       # (B,Ch)
    win = jnp.concatenate([cache["conv"], conv_in[:, None]], axis=1)  # (B,K,Ch)
    conv_out = jnp.einsum("bkc,ck->bc", win, p["conv_w"].astype(dt_))
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(dt_))
    xi, Bm, Cm = jnp.split(conv_out, [di, di + ds], axis=-1)
    new_conv = win[:, 1:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)                                    # (B,H)

    xh = xi.reshape(Bsz, nh, P).astype(jnp.float32)
    dBx = jnp.einsum("bh,bn,bhp->bhnp", dt, Bm.astype(jnp.float32), xh)
    state = a[..., None, None] * cache["state"] + dBx
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), state)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(Bsz, di).astype(dt_)

    y = rms_gated(y, z, p["gate_norm"])
    out = (y @ p["out"].astype(dt_))[:, None]              # (B,1,D)
    return out, {"conv": new_conv, "state": state}
