"""Mixture-of-Experts: top-k routing with capacity-based scatter dispatch.

Dispatch strategy (XLA/SPMD-friendly, dry-run-compilable at 512 devices):

  1. router logits -> top-k experts + renormalised weights per token;
  2. position-in-expert via a cumsum over the (tokens, experts) one-hot;
     tokens beyond ``capacity = cf * T * k / E`` are dropped (GShard-style);
  3. scatter tokens into an (E, C, D) expert buffer -- the buffer is
     sharded E->model (expert parallelism) and C->data, so the scatter is
     where the MoE all-to-all happens, inserted by the SPMD partitioner;
  4. batched expert GEMMs einsum('ecd,edf->ecf') -- E model-sharded;
  5. gather back + weighted combine.

On TPU, step 3-4 would be replaced by a Pallas grouped-GEMM (megablocks
style); the XLA formulation here is the reference and the dry-run path.
FLOPs are proportional to *dispatched* tokens (cf * active), not to E --
this is what makes MODEL_FLOPS(active)/HLO_FLOPs meaningful for MoE archs.

Shared experts (DeepSeekMoE / Moonlight / Llama-4) run as a dense MLP branch
of width n_shared * expert_d_ff added to the routed output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.compat import shard_map as _shard_map
from repro.models.config import ModelConfig
from repro.models.layers import apply_mlp, mlp_defs
from repro.models.params import ParamDef


def moe_defs(cfg: ModelConfig) -> dict:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    d: dict = {
        "router": ParamDef((D, E), ("embed", None), "small_normal"),
        "experts": {
            "wg": ParamDef((E, D, F), ("experts", "embed", "mlp")),
            "wu": ParamDef((E, D, F), ("experts", "embed", "mlp")),
            "wd": ParamDef((E, F, D), ("experts", "mlp", "embed")),
        },
    }
    if cfg.n_shared_experts:
        d["shared"] = mlp_defs(cfg, d_ff=cfg.n_shared_experts * F)
    return d


def _router(p, x, cfg: ModelConfig):
    """x: (T, D) -> (idx (T,k), weight (T,k), aux_loss scalar)."""
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weight, idx = jax.lax.top_k(probs, cfg.top_k)
    weight = weight / jnp.maximum(weight.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing auxiliary loss
    E = cfg.n_experts
    me = probs.mean(0)                                               # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / idx.size
    aux = E * jnp.sum(me * ce)
    return idx, weight, aux


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(8, -(-c // 8) * 8)   # round up to 8 for lane alignment


def moe_forward(p: dict, x: jax.Array, cfg: ModelConfig, constrain_fn=None):
    """x: (B,S,D) -> (B,S,D), aux_loss.

    ``constrain_fn(tensor, logical_axes)`` lets the caller inject sharding
    constraints (E->model, C->data) without this module knowing the mesh.
    """
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(cfg, T)
    xt = x.reshape(T, D)

    idx, weight, aux = _router(p, xt, cfg)                 # (T,k)

    # ---- position-in-expert (dropping beyond capacity) ---------------------
    flat_e = idx.reshape(-1)                               # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)    # (T*k, E)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)            # positions start at 0
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]   # (T*k,)
    keep = pos < C
    slot = flat_e * C + jnp.where(keep, pos, 0)            # (T*k,) in [0, E*C)

    # ---- dispatch: scatter into the (E*C, D) expert buffer -----------------
    src = jnp.repeat(xt, k, axis=0) * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((E * C, D), x.dtype).at[slot].add(
        src, mode="drop", indices_are_sorted=False, unique_indices=False
    )
    buf = buf.reshape(E, C, D)
    if constrain_fn is not None:
        buf = constrain_fn(buf, ("experts", "capacity", "embed"))

    # ---- expert compute: batched GEMMs, E sharded over model ---------------
    we = p["experts"]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, we["wg"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, we["wu"].astype(x.dtype))
    out_buf = jnp.einsum("ecf,efd->ecd", h, we["wd"].astype(x.dtype))
    if constrain_fn is not None:
        out_buf = constrain_fn(out_buf, ("experts", "capacity", "embed"))

    # ---- combine: gather back + weighted sum over k ------------------------
    gathered = out_buf.reshape(E * C, D)[slot]             # (T*k, D)
    gathered = gathered * (weight.reshape(-1)[:, None].astype(x.dtype)
                           * keep[:, None].astype(x.dtype))
    y = gathered.reshape(T, k, D).sum(axis=1)

    if cfg.n_shared_experts:
        y = y + apply_mlp(p["shared"], xt, cfg.mlp)
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# SPMD expert parallelism via shard_map (the production dispatch)
# ---------------------------------------------------------------------------
#
# GSPMD cannot partition a data-dependent scatter across the expert axis --
# left to propagation it REPLICATES the expert GEMMs on every device
# (observed: useful-flops fraction 0.007 on moonshot). The production path
# therefore makes the EP decomposition explicit with a *partial-manual*
# shard_map: manual over (pod, data, model), so that
#
#   * tokens stay local to their data shard (GShard "groups = data shards":
#     capacity is per-shard, no cross-data comm at all);
#   * each model shard owns E/tp experts and scatters ONLY its own experts'
#     tokens into a local (E_l, C, D) buffer (out-of-range slots dropped);
#   * expert GEMMs are plain local batched matmuls (MXU-shaped);
#   * the only communication is ONE psum over the model axis combining
#     routed partial outputs + the shared-expert partial sums -- the same
#     wire cost as the dense-FFN TP all-reduce it replaces.
#
# The Pallas grouped-GEMM kernel would slot in at the local einsum on TPU.

def moe_param_specs(cfg: ModelConfig, model_axis: str = "model") -> dict:
    """shard_map in_specs for the moe param subtree (matches moe_defs)."""
    d: dict = {
        "router": P(),
        "experts": {"wg": P(model_axis), "wu": P(model_axis),
                    "wd": P(model_axis)},
    }
    if cfg.n_shared_experts:
        d["shared"] = {"wg": P(None, model_axis), "wu": P(None, model_axis),
                       "wd": P(model_axis, None)}
    return d


def _moe_local(p: dict, x: jax.Array, cfg: ModelConfig, model_axis: str,
               batch_axes: tuple[str, ...], tp: int):
    """Per-device body. x: (B_local, S, D) -- batch already data-local.

    ``tp`` is the static model-axis size (from the mesh; lax.axis_size is
    not available on every supported jax)."""
    Bl, S, D = x.shape
    T = Bl * S
    E, k = cfg.n_experts, cfg.top_k
    el = E // tp
    off = jax.lax.axis_index(model_axis) * el
    C = capacity(cfg, T)                                   # per data shard
    xt = x.reshape(T, D)

    idx, weight, aux = _router(p, xt, cfg)                 # replicated math

    flat_e = idx.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C
    local = (flat_e >= off) & (flat_e < off + el)
    ok = keep & local
    # out-of-range slot for dropped/non-local tokens -> scatter mode "drop"
    slot = jnp.where(ok, (flat_e - off) * C + pos, el * C)

    src = jnp.repeat(xt, k, axis=0) * ok[:, None].astype(x.dtype)
    buf = jnp.zeros((el * C, D), x.dtype).at[slot].add(src, mode="drop")
    buf = buf.reshape(el, C, D)

    we = p["experts"]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, we["wg"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, we["wu"].astype(x.dtype))
    out_buf = jnp.einsum("ecf,efd->ecd", h, we["wd"].astype(x.dtype))

    gathered = jnp.take(out_buf.reshape(el * C, D), slot, axis=0,
                        mode="fill", fill_value=0)
    gathered = gathered * (weight.reshape(-1)[:, None].astype(x.dtype)
                           * ok[:, None].astype(x.dtype))
    y = gathered.reshape(T, k, D).sum(axis=1)

    if cfg.n_shared_experts:
        sh = p["shared"]
        hs = (jax.nn.silu(xt @ sh["wg"].astype(x.dtype))
              * (xt @ sh["wu"].astype(x.dtype)))           # (T, F_local)
        y = y + hs @ sh["wd"].astype(x.dtype)              # partial over F

    y = jax.lax.psum(y, model_axis)                        # THE one collective
    if batch_axes:
        aux = jax.lax.pmean(aux, tuple(batch_axes))
    return y.reshape(Bl, S, D), aux


def moe_forward_spmd(p: dict, x: jax.Array, cfg: ModelConfig, mesh: Mesh,
                     model_axis: str = "model",
                     batch_axes: tuple[str, ...] | None = None):
    """shard_map-wrapped EP dispatch; falls back to moe_forward when the
    mesh cannot shard it (E % tp != 0 or batch not divisible).

    ``batch_axes=None`` derives the data axes from the mesh; pass ``()``
    when calling from inside an outer shard_map that is already manual over
    the batch axes (the explicit-ABI train step).

    AXIS ORDER MATTERS: the batch dim everywhere else is constrained
    P(("pod","data")) -- the in/out specs here must use the SAME order or
    GSPMD inserts a full-batch reshard (observed: 2x21.5 GB all-gathers per
    MoE layer on the multipod mesh, 4x the cell's whole collective term)."""
    baxes = (tuple(a for a in ("pod", "data") if a in mesh.axis_names)
             if batch_axes is None else tuple(batch_axes))
    tp = mesh.shape.get(model_axis, 1)
    bdiv = 1
    for a in baxes:
        bdiv *= mesh.shape[a]
    if cfg.n_experts % tp or x.shape[0] % bdiv:
        return moe_forward(p, x, cfg)

    manual = set(baxes) | {model_axis}
    pspecs = moe_param_specs(cfg, model_axis)
    xspec = (P(baxes if len(baxes) > 1 else baxes[0]) if baxes else P())
    fn = _shard_map(
        lambda pl, xl: _moe_local(pl, xl, cfg, model_axis, baxes, tp),
        mesh=mesh,
        in_specs=(pspecs, xspec),
        out_specs=(xspec, P()),
        axis_names=manual,
        check_vma=False,
    )
    return fn(p, x)
