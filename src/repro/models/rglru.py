"""RG-LRU recurrent block (Griffin / RecurrentGemma) [arXiv:2402.19427].

Block layout (the paper's "recurrent block"):

    x -(wy)-> GeLU --------------------------\
    x -(wx)-> causal conv1d -> RG-LRU -> h --(*)--> (wo) -> out

RG-LRU recurrence (per channel):
    r_t = sigmoid(BlockDiag_a(x_t))          recurrence gate
    i_t = sigmoid(BlockDiag_x(x_t))          input gate
    a_t = exp(c * softplus(Lambda) * (-r_t)) i.e. a^(c r_t), a=sigmoid(Lambda)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses ``jax.lax.associative_scan`` (log-depth, TPU-native --
this is the hardware adaptation of the paper's linear-scan CUDA kernel; the
Pallas kernel in repro.kernels.rglru_scan implements the blocked variant).
Decode is the O(1) recurrence.

Gate projections are block-diagonal as in RecurrentGemma. The reference
model uses n_blocks = n_heads (=10 for 2b); we use n_blocks = 16 so the
block axis shards exactly over the 16-way model axis (DESIGN.md §4 records
this TP-divisibility deviation; parameter count changes by <0.1% of model).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamDef

RGLRU_BLOCKS = 16     # block-diagonal gate blocks == model-axis size
_C = 8.0              # Griffin's fixed temperature on the log-decay


def rec_defs(cfg: ModelConfig) -> dict:
    D, R, K = cfg.d_model, cfg.rnn_width_, cfg.conv_kernel
    nb = RGLRU_BLOCKS
    bs = R // nb
    return {
        "wx": ParamDef((D, R), ("embed", "rnn")),
        "wy": ParamDef((D, R), ("embed", "rnn")),
        "conv_w": ParamDef((R, K), ("rnn", None), "normal", 0.1),
        "conv_b": ParamDef((R,), ("rnn",), "zeros"),
        "gate_a_w": ParamDef((nb, bs, bs), ("rnn", None, None)),
        "gate_a_b": ParamDef((nb, bs), ("rnn", None), "zeros"),
        "gate_x_w": ParamDef((nb, bs, bs), ("rnn", None, None)),
        "gate_x_b": ParamDef((nb, bs), ("rnn", None), "zeros"),
        "lam": ParamDef((R,), ("rnn",), "normal", 1.0),
        "wo": ParamDef((R, D), ("rnn", "embed")),
    }


def _block_linear(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (..., R) with R = nb*bs; w: (nb, bs, bs)."""
    nb, bs, _ = w.shape
    xb = x.reshape(*x.shape[:-1], nb, bs)
    yb = jnp.einsum("...ni,nij->...nj", xb, w) + b
    return yb.reshape(*x.shape[:-1], nb * bs)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    K = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, :, None].transpose(1, 2, 0),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return out + b


def _rglru_coeffs(p: dict, x: jax.Array):
    """x: (B,S,R) conv output -> per-step (a, b_in) of h = a*h + b_in."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(_block_linear(xf, p["gate_a_w"].astype(jnp.float32),
                                     p["gate_a_b"].astype(jnp.float32)))
    i = jax.nn.sigmoid(_block_linear(xf, p["gate_x_w"].astype(jnp.float32),
                                     p["gate_x_b"].astype(jnp.float32)))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = i * xf
    b_in = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0)) * gated
    return a, b_in


def rglru_scan(a: jax.Array, b: jax.Array) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t along axis 1 via associative scan."""

    def combine(u, v):
        au, bu = u
        av, bv = v
        return au * av, bv + av * bu

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rec_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Training/prefill. x: (B,S,D) -> (B,S,D)."""
    dt_ = x.dtype
    y = jax.nn.gelu(x @ p["wy"].astype(dt_))
    xr = x @ p["wx"].astype(dt_)
    xr = _causal_conv(xr, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_))
    a, b = _rglru_coeffs(p, xr)
    h = rglru_scan(a, b).astype(dt_)
    return (h * y) @ p["wo"].astype(dt_)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_rec_cache(n_layers: int, batch: int, cfg: ModelConfig, dtype) -> dict:
    R, K = cfg.rnn_width_, cfg.conv_kernel
    return {
        "conv": jnp.zeros((n_layers, batch, K - 1, R), dtype),
        "state": jnp.zeros((n_layers, batch, R), jnp.float32),
    }


def rec_cache_specs():
    return {
        "conv": ("layers", "batch", None, "rnn"),
        "state": ("layers", "batch", "rnn"),
    }


def rec_decode(p: dict, x: jax.Array, cache: dict, cfg: ModelConfig):
    """x: (B,1,D); cache conv (B,K-1,R), state (B,R)."""
    dt_ = x.dtype
    xt = x[:, 0]
    y = jax.nn.gelu(xt @ p["wy"].astype(dt_))
    xr = xt @ p["wx"].astype(dt_)
    win = jnp.concatenate([cache["conv"], xr[:, None]], axis=1)      # (B,K,R)
    xr = jnp.einsum("bkr,rk->br", win, p["conv_w"].astype(dt_)) + p["conv_b"].astype(dt_)
    a, b = _rglru_coeffs(p, xr[:, None])
    a, b = a[:, 0], b[:, 0]
    state = a * cache["state"] + b
    h = state.astype(dt_)
    out = ((h * y) @ p["wo"].astype(dt_))[:, None]
    return out, {"conv": win[:, 1:], "state": state}
