"""Parameter definition trees: one source of truth for shape/init/sharding.

Every module describes its parameters as a tree of ``ParamDef`` (shape +
logical axes + init law). From that single tree we derive:
  * materialised params        (``materialize``  -- real training)
  * abstract params            (``abstract``     -- dry-run ShapeDtypeStructs)
  * NamedShardings             (via dist.sharding rules)
  * stacked per-layer params   (``stack_defs``   -- scan-over-layers)
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.dist.sharding import ShardingRules, logical_sharding


@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "fan_in"      # fan_in | normal | zeros | ones | small_normal
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def stack_defs(tree, n: int):
    """Prepend a scanned 'layers' axis of size n to every ParamDef."""
    return jax.tree.map(
        lambda d: replace(d, shape=(n, *d.shape), logical=("layers", *d.logical)),
        tree,
        is_leaf=is_def,
    )


def _init_one(d: ParamDef, key, dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "normal":
        return (d.scale * jax.random.normal(key, d.shape)).astype(dtype)
    if d.init == "small_normal":
        return (0.02 * d.scale * jax.random.normal(key, d.shape)).astype(dtype)
    if d.init == "fan_in":
        # truncated-normal with 1/sqrt(fan_in); fan_in = product of all dims
        # except the last (works for (in, out) and (in, heads, hd) layouts).
        fan_in = max(1, math.prod(d.shape[:-1]) if len(d.shape) > 1 else d.shape[0])
        # for stacked (layers, ...) defs, drop the scan axis from fan-in
        std = d.scale / math.sqrt(fan_in)
        return (std * jax.random.truncated_normal(key, -2.0, 2.0, d.shape)).astype(dtype)
    raise ValueError(f"unknown init {d.init!r}")


def materialize(tree, key, dtype=jnp.float32):
    """Instantiate a ParamDef tree. Keys are derived per-path (fold_in of the
    flattened leaf index) so adding parameters never reshuffles others."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_def)
    out = [
        _init_one(d, jax.random.fold_in(key, i), dtype) for i, d in enumerate(leaves)
    ]
    return jax.tree.unflatten(treedef, out)


def abstract(tree, dtype=jnp.float32):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), tree, is_leaf=is_def
    )


def shardings(tree, mesh, rules: ShardingRules):
    return jax.tree.map(
        lambda d: logical_sharding(d.logical, mesh, rules), tree, is_leaf=is_def
    )


def logical_specs(tree):
    return jax.tree.map(lambda d: d.logical, tree, is_leaf=is_def)


def count_params(tree) -> int:
    return sum(math.prod(d.shape) for d in jax.tree.leaves(tree, is_leaf=is_def))
