"""Model + shape-cell configuration schema for the 10 assigned architectures."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # query heads; 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # block flavour
    mlp: str = "swiglu"              # swiglu | gelu | relu2
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    parallel_block: bool = False     # stablelm/gpt-neox style parallel attn+ffn
    rope_theta: float = 10000.0
    rope_pct: float = 1.0            # partial rotary (stablelm 0.25, nemotron 0.5)
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma-style sqrt(d_model) embedding scale
    attn_kind: str = "full"          # full | local (sliding window)
    window: int = 0                  # local-attention window size
    attn_score_dtype: str = "float32"  # bfloat16 halves score-chain traffic
                                     # (f32 running stats kept either way)
    attn_q_chunk: int = 2048         # chunked-attention tile sizes (XLA path);
    attn_kv_chunk: int = 2048        # larger tiles = fewer renorm passes,
                                     # more live score bytes

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0           # leading dense layers (Moonlight style)
    moe_every: int = 1               # MoE layer cadence (1 = every layer)
    moe_d_ff: int = 0                # expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256             # SSD chunk length
    conv_kernel: int = 4

    # hybrid (RecurrentGemma / Griffin)
    block_pattern: tuple[str, ...] = ()   # repeating cycle, e.g. ("rec","rec","attn")
    rnn_width: int = 0               # RG-LRU width (0 -> d_model)

    # modality frontend stub
    frontend: str = ""               # "" | "audio" | "vision"
    frontend_len: int = 0            # prefix positions fed by the stub frontend

    source: str = ""                 # citation tag from the assignment table

    # ---- derived -----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(1, self.n_heads)

    @property
    def d_inner(self) -> int:        # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    @property
    def rnn_width_(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def layer_types(self) -> tuple[str, ...]:
        """Per-layer block type, resolving pattern / MoE cadence / SSM."""
        out = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                out.append("ssm")
            elif self.block_pattern:
                out.append(self.block_pattern[i % len(self.block_pattern)])
            elif self.n_experts and i >= self.first_k_dense and (
                (i - self.first_k_dense) % self.moe_every == 0
            ):
                out.append("moe")
            else:
                out.append("attn")
        return tuple(out)

    # ---- parameter counting (for roofline MODEL_FLOPS) ----------------------
    def param_count(self, active_only: bool = False) -> int:
        """Total (or active-per-token) parameter count, embeddings included."""
        D, V = self.d_model, self.vocab_size
        n = V * D                                # token embedding
        if not self.tie_embeddings:
            n += D * V                           # output head
        hd = self.head_dim_
        for t in self.layer_types():
            n += 2 * D                           # two norms (scale only, approx)
            if t in ("attn", "moe"):
                n += D * self.n_heads * hd       # wq
                n += 2 * D * self.n_kv_heads * hd  # wk, wv
                n += self.n_heads * hd * D       # wo
            if t == "attn":
                n += self._mlp_params(self.d_ff)
            elif t == "moe":
                n += D * self.n_experts          # router
                e = self.top_k if active_only else self.n_experts
                n += e * self._mlp_params(self.expert_d_ff)
                n += self.n_shared_experts * self._mlp_params(self.expert_d_ff)
            elif t == "ssm":
                di, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
                n += D * (2 * di + 2 * ds + nh)  # in_proj (z,x,B,C,dt)
                n += (di + 2 * ds) * self.conv_kernel  # conv1d
                n += 2 * nh + di                 # A_log, D, gate-norm
                n += di * D                      # out_proj
            elif t == "rec":
                dr = self.rnn_width_
                n += 2 * D * dr                  # two input branches
                n += dr * (self.conv_kernel + 1)  # temporal conv + bias
                n += 2 * (dr * dr // 16 + dr)    # block-diag gates (16 blocks)
                n += dr                          # Lambda
                n += dr * D                      # out proj
                n += self._mlp_params(self.d_ff)  # Griffin blocks pair w/ MLP
        return n

    def with_overrides(self, **kw: Any) -> "ModelConfig":
        known = {f.name for f in dataclasses.fields(self)}
        bad = set(kw) - known
        if bad:
            raise ValueError(f"unknown ModelConfig overrides: {sorted(bad)}")
        return replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test config: same family/block structure, tiny dims."""
        pat = self.block_pattern
        n_layers = max(len(pat), 2) if pat else (3 if self.first_k_dense else 2)
        kv = min(self.n_kv_heads, 2) if self.n_heads else 0
        return replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers if self.family != "ssm" else 2,
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=kv,
            head_dim=16 if self.n_heads else 0,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            first_k_dense=min(self.first_k_dense, 1),
            moe_d_ff=64 if self.n_experts else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            ssm_chunk=8,
            rnn_width=64 if self.block_pattern else 0,
            window=min(self.window, 16) if self.window else 0,
            frontend_len=min(self.frontend_len, 4),
        )

    def _mlp_params(self, d_ff: int) -> int:
        mats = 3 if self.mlp in ("swiglu", "geglu") else 2
        return mats * self.d_model * d_ff


@dataclass(frozen=True)
class ShapeCell:
    """One assigned input-shape cell. ``kind`` selects the lowered step."""

    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    def scaled(self, seq_len: int | None = None, global_batch: int | None = None) -> "ShapeCell":
        return replace(
            self,
            name=self.name + "-scaled",
            seq_len=seq_len or self.seq_len,
            global_batch=global_batch or self.global_batch,
        )


SHAPE_CELLS: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def get_shape_cell(name: str) -> ShapeCell:
    try:
        return SHAPE_CELLS[name]
    except KeyError:
        raise KeyError(f"unknown shape cell {name!r}; have {sorted(SHAPE_CELLS)}") from None


def long_context_capable(cfg: ModelConfig) -> bool:
    """Whether the arch is sub-quadratic in cached context (SSM/hybrid/linear).

    Pure full-attention archs skip ``long_500k`` (see DESIGN.md §4).
    ``attn`` and ``moe`` blocks both carry attention; they only count as
    sub-quadratic when the arch uses windowed (local) attention.
    """
    types = set(cfg.layer_types())
    has_attention = bool(types & {"attn", "moe"})
    return (not has_attention) or cfg.attn_kind == "local"
