"""GQA attention: train/prefill (full + local window) and cached decode.

TP geometry
-----------
The assigned configs have head counts that do not always divide the 16-way
model axis (llama3.2: 24 q heads; musicgen: 24; llama4: 40; recurrentgemma:
10 MQA). We therefore resolve an ``AttnGeometry`` at runtime-bind time:

  * q heads physically padded to a multiple of TP (Megatron's
    ``make_vocab_size_divisible_by`` applied to heads); padded heads get
    zero-init wq/wo rows so they are exact no-ops numerically;
  * kv heads replicated by the smallest integer r such that kv*r divides the
    padded q heads AND is divisible by TP -- this is the standard
    "KV replication for TP > n_kv_heads" trick (MaxText); it's what lets the
    32k/500k KV *cache* shard over the model axis instead of replicating
    ~100GB per chip.

The padding overhead is honest, visible compute: it is counted in HLO_FLOPs
and reported in the roofline MODEL_FLOPS/HLO_FLOPs ratio.

Long sequences
--------------
Full-softmax scores for prefill_32k would be (B,H,32k,32k) -- hundreds of GB.
``attend`` therefore switches to a chunked online-softmax (flash-style
lax.scan over KV chunks, running max/denominator) above a size threshold.
On TPU the Pallas kernel (repro.kernels.flash_attention) replaces this path;
the XLA formulation here is its oracle and the dry-run/compile path.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope
from repro.models.params import ParamDef

NEG_INF = -1e30
CHUNKED_KV_THRESHOLD = 8192   # use online-softmax scan above this many keys
KV_CHUNK = 2048
Q_CHUNK = 2048


@dataclass(frozen=True)
class AttnGeometry:
    n_q: int          # padded q heads
    n_q_orig: int
    n_kv: int         # replicated (and, for MHA, padded) kv heads
    n_kv_orig: int
    head_dim: int

    @property
    def q_per_kv(self) -> int:
        return self.n_q // self.n_kv

    @property
    def kv_rep(self) -> int:
        return self.n_kv // self.n_kv_orig if self.n_kv % self.n_kv_orig == 0 else 0


def resolve_geometry(cfg: ModelConfig, tp: int) -> AttnGeometry:
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    hp = -(-h // tp) * tp if h % tp else h            # pad q heads to TP multiple
    if kv == h:                                        # MHA: kv pads with q
        kvp = hp
    else:
        r = 1
        while r <= tp and ((kv * r) % tp or hp % (kv * r)):
            r += 1
        kvp = kv * r if r <= tp else hp                # fallback: full replication
    return AttnGeometry(hp, h, kvp, kv, hd)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig, geom: AttnGeometry) -> dict:
    D, hd = cfg.d_model, geom.head_dim
    return {
        # padded q/o slots exist physically; zero-padding is applied by the
        # init mask below (fan_in init then multiplied by the validity mask
        # at apply time would cost flops -- instead padded slots simply learn;
        # they are dead weight only w.r.t. the canonical checkpoint format).
        "wq": ParamDef((D, geom.n_q, hd), ("embed", "heads", None)),
        "wk": ParamDef((D, geom.n_kv_orig, hd), ("embed", None, None)),
        "wv": ParamDef((D, geom.n_kv_orig, hd), ("embed", None, None)),
        "wo": ParamDef((geom.n_q, hd, D), ("heads", None, "embed")),
    }


# ---------------------------------------------------------------------------
# score-path helpers
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, window: int) -> jax.Array:
    """(…, Sq, Sk) additive mask: causal, optionally sliding-window."""
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    ok &= k_pos[..., None, :] >= 0           # ring-buffer slots not yet written
    if window:
        ok &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa_dense(q, k, v, q_pos, k_pos, window, scale,
                score_dtype=jnp.float32) -> jax.Array:
    """q: (B,Sq,Hkv,G,hd)  k,v: (B,Sk,Hkv,hd)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32) * scale
    s = s + _mask_bias(q_pos, k_pos, window)[:, None, None]
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v)


def _sdpa_chunked(q, k, v, q_pos, k_pos, window, scale,
                  score_dtype=jnp.float32,
                  q_chunk=None, kv_chunk=None, q_offset: int = 0) -> jax.Array:
    """Online-softmax over KV chunks (flash-style, XLA formulation).

    Memory: O(Sq * KV_CHUNK) scores instead of O(Sq * Sk).

    The chunk loop is STATICALLY UNROLLED (python for), not lax.scan:
    XLA's HloCostAnalysis counts a while-loop body once regardless of trip
    count, which would under-report attention FLOPs/bytes by nchunks in the
    dry-run roofline. Unrolled chunks are counted exactly, and XLA's
    scheduler can overlap chunk DMA with compute (what the Pallas kernel
    does explicitly on TPU). Fully-causal (all-masked) chunk/q-block pairs
    are skipped at trace time -- the same block-sparsity the Pallas kernel
    exploits -- so causal attention costs ~half of the rectangular count.
    """
    B, Sq, Hkv, G, hd = q.shape
    Sk = k.shape[1]
    KV_CHUNK = kv_chunk or globals()["KV_CHUNK"]
    Q_CHUNK = q_chunk or globals()["Q_CHUNK"]
    nchunks = -(-Sk // KV_CHUNK)
    pad = nchunks * KV_CHUNK - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)),
                        constant_values=jnp.iinfo(jnp.int32).max)

    # q is chunked too so trace-time causal skipping applies per (qi, ki)
    nq = -(-Sq // Q_CHUNK)
    qpad = nq * Q_CHUNK - Sq
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, qpad)), constant_values=-1)

    # static per-chunk position bounds: q_pos/k_pos are data, but for the
    # skip decision we rely on the canonical layout (positions ascending
    # from ``q_offset`` -- 0 for train/prefill, the shared-prefix length
    # for prefix-cached suffix prefill, whose queries see prefix keys at
    # positions BELOW their own block index); decode (Sq==1) never skips.
    causal_layout = Sq > 1
    out_qchunks = []
    for qi in range(nq):
        qb = jax.lax.slice_in_dim(q, qi * Q_CHUNK, (qi + 1) * Q_CHUNK, axis=1)
        qpb = jax.lax.slice_in_dim(q_pos, qi * Q_CHUNK, (qi + 1) * Q_CHUNK, axis=1)
        m = jnp.full((B, Hkv, G, Q_CHUNK), NEG_INF, jnp.float32)
        l = jnp.zeros((B, Hkv, G, Q_CHUNK), jnp.float32)
        acc = jnp.zeros((B, Hkv, G, Q_CHUNK, hd), jnp.float32)
        q_lo = q_offset + qi * Q_CHUNK            # min q position in block
        q_hi = q_offset + (qi + 1) * Q_CHUNK - 1
        for ki in range(nchunks):
            k_lo = ki * KV_CHUNK
            if causal_layout:
                if k_lo > q_hi:                   # fully future: skip
                    continue
                if window and (ki + 1) * KV_CHUNK - 1 < q_lo - window + 1:
                    continue                      # fully out of window: skip
            kb = jax.lax.slice_in_dim(k, k_lo, k_lo + KV_CHUNK, axis=1)
            vb = jax.lax.slice_in_dim(v, k_lo, k_lo + KV_CHUNK, axis=1)
            pb = jax.lax.slice_in_dim(k_pos, k_lo, k_lo + KV_CHUNK, axis=1)
            if score_dtype == jnp.float32:
                s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                               preferred_element_type=jnp.float32) * scale
                s = s + _mask_bias(qpb, pb, window)[:, None, None]
                m_new = jnp.maximum(m, s.max(axis=-1))
                alpha = jnp.exp(m - m_new)
                p = jnp.exp(s - m_new[..., None])
                l = l * alpha + p.sum(axis=-1)
                pv = p.astype(vb.dtype)
            else:
                # low-precision score chain: the (bq x bk) arrays -- the
                # dominant HBM traffic of XLA attention -- stay in bf16;
                # running max/denominator/accumulator stay f32.
                s = (jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                                preferred_element_type=score_dtype)
                     * jnp.asarray(scale, score_dtype))
                s = s + _mask_bias(qpb, pb, window)[:, None, None].astype(
                    score_dtype)
                m_new = jnp.maximum(m, s.max(axis=-1).astype(jnp.float32))
                alpha = jnp.exp(m - m_new)
                p = jnp.exp(s - m_new[..., None].astype(score_dtype))
                l = l * alpha + jnp.sum(p, axis=-1, dtype=jnp.float32)
                pv = p.astype(vb.dtype)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", pv, vb,
                preferred_element_type=jnp.float32)
            m = m_new
        out_qchunks.append(acc / jnp.maximum(l, 1e-30)[..., None])
    out = jnp.concatenate(out_qchunks, axis=3)     # (B,Hkv,G,Sq+pad,hd)
    out = out.transpose(0, 3, 1, 2, 4).astype(q.dtype)
    return out[:, :Sq] if qpad else out


def attend(q, k, v, q_pos, k_pos, window: int = 0,
           score_dtype=jnp.float32, q_chunk=None, kv_chunk=None,
           q_offset: int = 0) -> jax.Array:
    """Grouped attention. q: (B,Sq,Hq,hd) -> (B,Sq,Hq,hd).

    k/v carry the *replicated* kv heads (geom.n_kv). ``q_offset`` is the
    STATIC base of the canonical q positions (nonzero only for the
    prefix-cached suffix prefill) -- the chunked path's trace-time causal
    skipping must know it, or it would skip KV chunks that sit between the
    0-based block index and the true offset positions."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, Hkv, Hq // Hkv, hd)
    if k.shape[1] > CHUNKED_KV_THRESHOLD or score_dtype != jnp.float32:
        out = _sdpa_chunked(qg, k, v, q_pos, k_pos, window, scale,
                            score_dtype, q_chunk=q_chunk, kv_chunk=kv_chunk,
                            q_offset=q_offset)
    else:
        out = _sdpa_dense(qg, k, v, q_pos, k_pos, window, scale)
    return out.reshape(B, Sq, Hq, hd)


# ---------------------------------------------------------------------------
# block forward paths
# ---------------------------------------------------------------------------

def project_qkv(p: dict, x: jax.Array, cfg: ModelConfig, geom: AttnGeometry,
                positions: jax.Array):
    """x: (B,S,D) -> q (B,S,Hq,hd), k/v (B,S,n_kv,hd) with RoPE + replication."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_pct)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_pct)
    k, v = replicate_kv(k, geom), replicate_kv(v, geom)
    return q, k, v


def replicate_kv(kv: jax.Array, geom: AttnGeometry) -> jax.Array:
    """(…, n_kv_orig, hd) -> (…, n_kv, hd).

    Gather-based replication: target slot j serves padded q heads
    [j*g, (j+1)*g) and reads the kv head the FIRST of those q heads uses in
    the canonical (unpadded) grouping. For divisible cases this equals
    jnp.repeat; for padded MHA the extra slots alias the last canonical
    head (the padded q heads are additional learned heads either way)."""
    h = kv.shape[-2]
    if h == geom.n_kv:
        return kv
    g = geom.q_per_kv
    group = max(1, geom.n_q_orig // h)          # canonical q-heads per kv
    q0 = jnp.minimum(jnp.arange(geom.n_kv) * g, geom.n_q_orig - 1)
    idx = jnp.minimum(q0 // group, h - 1)
    return jnp.take(kv, idx, axis=-2)


def attn_out(p: dict, ctx: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(ctx.dtype))


def attn_forward(p: dict, x: jax.Array, cfg: ModelConfig, geom: AttnGeometry,
                 positions: jax.Array, window: int = 0) -> jax.Array:
    q, k, v = project_qkv(p, x, cfg, geom, positions)
    ctx = attend(q, k, v, positions, positions, window)
    return attn_out(p, ctx)


# ---------------------------------------------------------------------------
# decode with KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(n_layers: int, batch: int, max_len: int, geom: AttnGeometry,
                  dtype) -> dict:
    shp = (n_layers, batch, max_len, geom.n_kv, geom.head_dim)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


def kv_cache_specs(window: int = 0):
    """Logical axes of one layer-stack's cache entry."""
    spec = ("layers", "batch", "kv_seq", "kv_heads", None)
    return {"k": spec, "v": spec}


def paged_decode_attn(p: dict, x: jax.Array, layer_cache: dict,
                      idx: jax.Array, page_table: jax.Array,
                      cfg: ModelConfig, geom: AttnGeometry,
                      window: int = 0):
    """One-token decode over a PAGED KV pool (slot-granular only).

    x: (B,1,D); layer_cache k/v: (n_kv, n_pages, page_size, hd) -- the
    layer's global page pool; idx: (B,) per-row positions; page_table:
    (B, max_pages) physical page ids (garbage page 0 where unmapped).

    Writes the new token's K/V into page ``table[b, idx//ps]`` at offset
    ``idx % ps`` (the scheduler guarantees that page is allocated before
    dispatch -- alloc-on-write happens host-side in the PagePool), then
    attends through repro.kernels.paged_attention (Pallas on TPU, XLA
    oracle elsewhere). Free slots write through table rows reset to the
    garbage page; their output is discarded by the host."""
    if window:
        raise NotImplementedError(
            "paged decode supports full attention only (ring-buffer windows "
            "keep the contiguous per-slot layout)")
    from repro.kernels.paged_attention.ops import paged_attention
    B = x.shape[0]
    positions = idx[:, None].astype(jnp.int32)
    q, k, v = project_qkv(p, x, cfg, geom, positions)   # k/v: (B,1,n_kv,hd)
    n_kv, n_pages, ps, hd = layer_cache["k"].shape
    mp = page_table.shape[1]
    page = jnp.take_along_axis(
        page_table, jnp.clip(idx // ps, 0, mp - 1)[:, None], axis=1)[:, 0]
    off = idx % ps
    knew = jnp.moveaxis(k[:, 0], 1, 0)                  # (n_kv, B, hd)
    vnew = jnp.moveaxis(v[:, 0], 1, 0)
    ck = layer_cache["k"].at[:, page, off].set(
        knew.astype(layer_cache["k"].dtype))
    cv = layer_cache["v"].at[:, page, off].set(
        vnew.astype(layer_cache["v"].dtype))
    ctx = paged_attention(q[:, 0], ck, cv, page_table,
                          idx.astype(jnp.int32) + 1, window=window)
    return attn_out(p, ctx[:, None]), {"k": ck, "v": cv}


def decode_attn(p: dict, x: jax.Array, layer_cache: dict, idx: jax.Array,
                cfg: ModelConfig, geom: AttnGeometry, window: int = 0):
    """One-token decode. x: (B,1,D); layer_cache k/v: (B,S,n_kv,hd);
    idx: current position -- a scalar (whole-batch lockstep decode) or a
    (B,) vector of per-row positions (slot-granular continuous batching:
    every batch row is an independent request at its own depth).
    Returns (out, new_cache).

    For ``window`` caches the buffer is a ring of size window (positions are
    reconstructed modulo the ring)."""
    B = x.shape[0]
    per_slot = jnp.ndim(idx) == 1
    positions = (idx[:, None].astype(jnp.int32) if per_slot
                 else jnp.full((B, 1), idx, jnp.int32))
    q, k, v = project_qkv(p, x, cfg, geom, positions)
    S = layer_cache["k"].shape[1]
    slot = jnp.mod(idx, S) if window else idx
    if per_slot:
        # per-row writes: vmap the row update so each request lands at its
        # own position (XLA lowers this to one scatter, not B updates)
        upd = jax.vmap(
            lambda buf, new, s: jax.lax.dynamic_update_slice(
                buf, new, (s, 0, 0)))
        ck = upd(layer_cache["k"], k, slot)
        cv = upd(layer_cache["v"], v, slot)
    else:
        ck = jax.lax.dynamic_update_slice(layer_cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(layer_cache["v"], v, (0, slot, 0, 0))
    if window:
        # ring buffer: true position of ring slot j given current write pos
        ring_idx = jnp.arange(S)
        if per_slot:
            k_pos = idx[:, None] - jnp.mod(slot[:, None] - ring_idx[None, :], S)
        else:
            k_pos = idx - jnp.mod(slot - ring_idx, S)
            k_pos = jnp.broadcast_to(k_pos, (B, S))
    else:
        k_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    ctx = attend(q, ck, cv, positions, k_pos, window,
                 score_dtype=jnp.dtype(cfg.attn_score_dtype))
    return attn_out(p, ctx), {"k": ck, "v": cv}
