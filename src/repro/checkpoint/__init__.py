from repro.checkpoint.store import CheckpointStore
from repro.checkpoint.elastic import reshard_restore
from repro.checkpoint.straggler import StragglerMonitor

__all__ = ["CheckpointStore", "reshard_restore", "StragglerMonitor"]
