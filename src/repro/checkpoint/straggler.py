"""Straggler mitigation: per-step timing outlier detection + reactions.

At 1000+ nodes, a single slow host (thermal throttling, failing HBM, noisy
neighbour) gates every synchronous collective. The monitor keeps a robust
running estimate (median + MAD over a sliding window) of step latency and
flags outliers; the driver (launch/train.py) reacts by:

  * logging + metrics (always);
  * after ``trip_threshold`` consecutive flags: requesting a checkpoint so
    the scheduler can drain/replace the slow host and the job restarts from
    the last step rather than losing work (ties into elastic.py).

On this CPU container the timings are real wall-clock per step; on a
cluster each host feeds its own timer and the reduction is a max() over
hosts (one line in the driver).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field


@dataclass
class StragglerMonitor:
    window: int = 32
    mad_factor: float = 5.0      # flag if step > median + factor * MAD
    trip_threshold: int = 3      # consecutive flags before requesting action
    _times: deque = field(default_factory=lambda: deque(maxlen=64))
    _consecutive: int = 0
    flags: int = 0
    trips: int = 0
    _t0: float | None = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> dict:
        assert self._t0 is not None, "stop() without start()"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(dt)

    def observe(self, step_seconds: float) -> dict:
        """Feed one step latency; returns {flagged, tripped, median, bound}."""
        ts = sorted(self._times)
        flagged = tripped = False
        median = bound = float("nan")
        if len(ts) >= max(8, self.window // 4):
            median = ts[len(ts) // 2]
            mad = sorted(abs(t - median) for t in ts)[len(ts) // 2]
            bound = median + self.mad_factor * max(mad, 0.02 * median, 1e-9)
            if step_seconds > bound:
                flagged = True
                self.flags += 1
                self._consecutive += 1
                if self._consecutive >= self.trip_threshold:
                    tripped = True
                    self.trips += 1
                    self._consecutive = 0
            else:
                self._consecutive = 0
        if not flagged:
            # outliers are excluded from the running window so one bad host
            # cannot poison the estimate it is judged against
            self._times.append(step_seconds)
        return {"flagged": flagged, "tripped": tripped,
                "median": median, "bound": bound, "step_seconds": step_seconds}
