"""Content-addressed checkpoint store with async save and atomic publish.

Same storage discipline as the image registry (paper §2.2's layered file
system applied to training state):

* every tensor is stored once under ``blobs/<sha256>`` -- consecutive
  checkpoints share unchanged tensors (embedding tables that stopped
  updating, frozen frontends, optimizer step scalars...), so checkpoint k+1
  costs only its delta, exactly like pushing a derived image;
* a checkpoint is a JSON *manifest* mapping tree paths -> (blob, shape,
  dtype), published atomically via rename, so a crash mid-save can never
  corrupt the latest checkpoint (fault-tolerance requirement);
* saves run on a background thread (training continues; ``wait()`` joins
  before the next save or at exit).

Restore returns numpy trees; Container/elastic.py device_puts them with the
target mesh's shardings (which is how elastic re-sharding falls out for
free: the store is layout-agnostic).
"""

from __future__ import annotations

import hashlib
import json
import os
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _tree_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


class CheckpointStore:
    def __init__(self, root: str | os.PathLike):
        self.root = Path(root)
        (self.root / "blobs").mkdir(parents=True, exist_ok=True)
        (self.root / "manifests").mkdir(parents=True, exist_ok=True)
        self._thread: threading.Thread | None = None
        self.last_stats: dict | None = None

    # -- save --------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False) -> None:
        """Snapshot to host (numpy) synchronously, write blobs async."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()
        if blocking:
            self._write(step, host_tree)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> None:
        t0 = time.perf_counter()
        manifest: dict[str, Any] = {"step": step, "tensors": {}}
        new_blobs = reused = new_bytes = 0
        for path, leaf in _tree_paths(host_tree):
            # NOTE: np.ascontiguousarray promotes 0-d -> 1-d; keep the rank
            arr = np.asarray(leaf, order="C")
            digest = hashlib.sha256(arr.tobytes()).hexdigest()
            blob = self.root / "blobs" / digest
            if not blob.exists():
                tmp = blob.with_suffix(".tmp")
                with open(tmp, "wb") as f:
                    np.save(f, arr, allow_pickle=False)
                os.replace(tmp, blob)
                new_blobs += 1
                new_bytes += arr.nbytes
            else:
                reused += 1
            manifest["tensors"][path] = {
                "blob": digest,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        mpath = self.root / "manifests" / f"step-{step:010d}.json"
        tmp = mpath.with_suffix(".tmp")
        tmp.write_text(json.dumps(manifest))
        os.replace(tmp, mpath)          # atomic publish
        latest = self.root / "LATEST"
        ltmp = latest.with_suffix(".tmp")
        ltmp.write_text(mpath.name)
        os.replace(ltmp, latest)
        self.last_stats = {
            "step": step, "new_blobs": new_blobs, "reused_blobs": reused,
            "new_bytes": new_bytes, "seconds": time.perf_counter() - t0,
        }

    # -- restore --------------------------------------------------------------
    def steps(self) -> list[int]:
        return sorted(
            int(p.stem.split("-")[1]) for p in (self.root / "manifests").glob("step-*.json")
        )

    def latest_step(self) -> int | None:
        latest = self.root / "LATEST"
        if not latest.exists():
            return None
        return int(latest.read_text().strip().split("-")[1].split(".")[0])

    def restore(self, template, step: int | None = None):
        """Load into the structure of ``template`` (numpy leaves)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.root}")
        manifest = json.loads(
            (self.root / "manifests" / f"step-{step:010d}.json").read_text())
        tensors = manifest["tensors"]

        flat = jax.tree_util.tree_flatten_with_path(template)
        leaves, treedef = flat
        out = []
        for kp, leaf in leaves:
            path = jax.tree_util.keystr(kp)
            if path not in tensors:
                raise KeyError(f"checkpoint step {step} missing tensor {path}")
            meta = tensors[path]
            arr = np.load(self.root / "blobs" / meta["blob"], allow_pickle=False)
            arr = arr.reshape(tuple(meta["shape"]))
            want = tuple(getattr(leaf, "shape", ()))
            if want != tuple(arr.shape):
                raise ValueError(
                    f"{path}: checkpoint shape {arr.shape} != template {want}")
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, [a for a in out])

    def gc(self, keep_last: int = 3) -> int:
        """Drop old manifests + unreferenced blobs; returns blobs removed."""
        steps = self.steps()
        drop = steps[:-keep_last] if keep_last else steps
        for s in drop:
            (self.root / "manifests" / f"step-{s:010d}.json").unlink(missing_ok=True)
        live: set[str] = set()
        for p in (self.root / "manifests").glob("step-*.json"):
            m = json.loads(p.read_text())
            live.update(t["blob"] for t in m["tensors"].values())
        removed = 0
        for blob in (self.root / "blobs").iterdir():
            if blob.name not in live:
                blob.unlink()
                removed += 1
        return removed
