"""Elastic re-sharding: restore a checkpoint onto a different mesh.

Because CheckpointStore is layout-agnostic (global numpy arrays) and all
shardings derive from *logical* axis rules, moving a run from 256 chips to
512 (or down to a workstation) is: restore -> device_put with the target
mesh's NamedShardings. This is the paper's portability claim applied to
*state*, not just code: the same artifact instantiates on any platform.

Node-failure story (documented here, exercised by tests/test_faults.py):
  1. detect failure (missed heartbeat / collective timeout);
  2. relaunch the job on the surviving topology (e.g. drop a pod:
     multipod -> pod platform);
  3. ``reshard_restore`` the last published checkpoint onto the new mesh;
  4. the deterministic data pipeline (data/pipeline.py) replays from the
     restored step, so no data is skipped or double-counted.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore


def reshard_restore(store: CheckpointStore, template, shardings,
                    step: int | None = None):
    """Restore ``step`` and place leaves with ``shardings`` (same treedef).

    ``template`` carries shapes/dtypes (arrays or ShapeDtypeStructs);
    ``shardings`` is a matching tree of NamedShardings for the TARGET mesh.
    """
    host = store.restore(template, step)
    dtypes = jax.tree.map(lambda t: t.dtype, template)
    host = jax.tree.map(lambda a, dt: np.asarray(a, dtype=dt), host, dtypes)
    return jax.tree.map(jax.device_put, host, shardings)
