"""Stevedore: container-inspired environment runtime for multi-pod JAX training/serving.

Reproduction + TPU-native extension of:
  "Containers for portable, productive and performant scientific computing"
  (Hale, Li, Richardson, Wells; 2016).

The paper's layered-image / registry / swappable-ABI / import-cache ideas are
implemented as first-class features of a JAX training & serving framework:

  repro.core       -- EnvImage, Imagefile, Registry, Container, CollectiveABI,
                      CompileCache, Platform runtimes
  repro.models     -- the 10-architecture model zoo (dense / MoE / SSM / hybrid)
  repro.dist       -- mesh + logical-axis sharding rules
  repro.train      -- optimizer, train-step builders (implicit & explicit ABI)
  repro.serve      -- prefill / decode steps with KV + SSM caches
  repro.kernels    -- Pallas TPU kernels (validated via interpret=True on CPU)
  repro.launch     -- production mesh, multi-pod dry-run, train/serve drivers
"""

__version__ = "1.0.0"
