"""CompileCache: the TPU-world answer to the paper's 'Python import problem'.

Paper §4.2 / Fig. 4: at 1000 MPI processes, every process imports thousands of
small Python files from a parallel FS -> ~30 min of startup. Containers fix it
because the image is ONE large file mounted per node.

The multi-pod JAX analog: every *host* in a 1000-host job traces, lowers and
compiles the train step -- minutes of redundant work per host, identical on
all of them. The fix is the same shape as the paper's: persist the artifact
once, keyed by content hash, and have every other host load one big file.

Cache levels (best effort, graceful degradation):

  L1  serialized compiled executable (``jax.experimental.serialize_executable``)
      -> deserialize_and_load skips trace+lower+compile entirely;
  L2  StableHLO text of the lowered module
      -> skips trace+lower (the Python-heavy part), recompiles natively;
  L0  miss -> full trace+lower+compile, then populate L1+L2.

Keys: sha256 over (image digest, step kind, mesh fingerprint, abstract input
signature, jax/jaxlib versions, backend) -- the exact analog of an image
digest pinning a bit-exact environment. A key never collides across meshes or
framework versions, so a cache is safely shareable cluster-wide (the paper's
registry role).
"""

from __future__ import annotations

import hashlib
import json
import os
import pickle
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Callable

import jax

try:  # L1 support
    from jax.experimental import serialize_executable as _se
    _HAVE_SERIALIZE = True
except Exception:  # pragma: no cover
    _HAVE_SERIALIZE = False


def mesh_fingerprint(mesh: jax.sharding.Mesh) -> str:
    return json.dumps(
        {"axes": list(mesh.axis_names), "shape": [int(s) for s in mesh.devices.shape],
         "ndev": int(mesh.devices.size)},
        sort_keys=True,
    )


def abstract_signature(args_tree: Any) -> str:
    leaves, treedef = jax.tree.flatten(args_tree)
    sig = [
        (list(map(int, getattr(l, "shape", ()))), str(getattr(l, "dtype", type(l).__name__)))
        for l in leaves
    ]
    return json.dumps({"tree": str(treedef), "leaves": sig})


@dataclass
class CacheStats:
    hits_l1: int = 0
    hits_l2: int = 0
    misses: int = 0
    last_level: str = ""
    last_seconds: float = 0.0


class CompileCache:
    def __init__(self, root: str | os.PathLike):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.stats = CacheStats()

    # -- keying --------------------------------------------------------------
    def key(self, *, image_digest: str, step_kind: str, mesh: jax.sharding.Mesh,
            args_tree: Any) -> str:
        body = json.dumps(
            {
                "image": image_digest,
                "step": step_kind,
                "mesh": mesh_fingerprint(mesh),
                "sig": abstract_signature(args_tree),
                "jax": jax.__version__,
                "backend": jax.default_backend(),
            },
            sort_keys=True,
        )
        return hashlib.sha256(body.encode()).hexdigest()

    def _paths(self, key: str) -> tuple[Path, Path, Path]:
        return (
            self.root / f"{key}.exec",       # L1: pickled serialized executable
            self.root / f"{key}.stablehlo",  # L2: lowered module text
            self.root / f"{key}.meta.json",
        )

    # -- main entry ------------------------------------------------------------
    def get_or_build(
        self,
        key: str,
        lower_fn: Callable[[], Any],
        *,
        want_executable: bool = True,
    ):
        """Return a compiled executable for ``key``.

        ``lower_fn()`` must return a ``jax.stages.Lowered``. On a miss we
        lower+compile and persist both cache levels.
        """
        p_exec, p_hlo, p_meta = self._paths(key)

        # L1: full executable
        if want_executable and _HAVE_SERIALIZE and p_exec.exists():
            t0 = time.perf_counter()
            try:
                payload = pickle.loads(p_exec.read_bytes())
                compiled = _se.deserialize_and_load(
                    payload["serialized"], payload["in_tree"], payload["out_tree"]
                )
                self.stats.hits_l1 += 1
                self.stats.last_level = "L1"
                self.stats.last_seconds = time.perf_counter() - t0
                return compiled
            except Exception:
                p_exec.unlink(missing_ok=True)  # stale/incompatible: fall through

        t0 = time.perf_counter()
        lowered = lower_fn()
        compiled = lowered.compile()
        elapsed = time.perf_counter() - t0
        self.stats.misses += 1
        self.stats.last_level = "L0"
        self.stats.last_seconds = elapsed

        # populate caches (best effort)
        try:
            _atomic_bytes(p_hlo, lowered.as_text().encode())
        except Exception:
            pass
        if _HAVE_SERIALIZE:
            try:
                serialized, in_tree, out_tree = _se.serialize(compiled)
                _atomic_bytes(
                    p_exec,
                    pickle.dumps(
                        {"serialized": serialized, "in_tree": in_tree, "out_tree": out_tree}
                    ),
                )
            except Exception:
                pass
        _atomic_bytes(
            p_meta,
            json.dumps(
                {"built_seconds": elapsed, "jax": jax.__version__,
                 "backend": jax.default_backend()}
            ).encode(),
        )
        return compiled

    def lowered_text(self, key: str) -> str | None:
        """L2 read: the persisted StableHLO (for offline roofline analysis)."""
        p = self._paths(key)[1]
        return p.read_text() if p.exists() else None

    def has(self, key: str) -> bool:
        p_exec, p_hlo, _ = self._paths(key)
        return p_exec.exists() or p_hlo.exists()

    def evict(self, key: str) -> None:
        for p in self._paths(key):
            p.unlink(missing_ok=True)


def _atomic_bytes(path: Path, data: bytes) -> None:
    tmp = path.with_suffix(path.suffix + ".tmp")
    tmp.write_bytes(data)
    os.replace(tmp, path)
