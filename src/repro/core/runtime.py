"""Runtime facade: docker-CLI-shaped operations over images + containers.

    rt = Runtime(root)                         # ~/.stevedore analog
    img = rt.build(imagefile_text, tag="stable")
    c = rt.run("stable", platform="local")     # -> Container
    rt.images(); rt.ps()

The Runtime owns the registry, the compile cache (shared across containers,
like the paper's per-node image mount), and the overlay root.
"""

from __future__ import annotations

import json
import os
from pathlib import Path

from repro.core.compile_cache import CompileCache
from repro.core.container import Container
from repro.core.image import EnvImage
from repro.core.imagefile import parse_imagefile
from repro.core.registry import Registry, TransferStats


class Runtime:
    def __init__(self, root: str | os.PathLike = ".stevedore"):
        self.root = Path(root)
        self.registry = Registry(self.root / "registry")
        self.compile_cache = CompileCache(self.root / "compile-cache")
        self.overlay_root = self.root / "overlays"

    # -- images ------------------------------------------------------------
    def build(self, imagefile_text: str, tag: str | None = None) -> EnvImage:
        image = parse_imagefile(imagefile_text, registry=self.registry)
        self.registry.push(image, tag=tag)
        return image

    def push(self, image: EnvImage, tag: str | None = None) -> TransferStats:
        return self.registry.push(image, tag)

    def pull(self, ref: str) -> EnvImage:
        return self.registry.pull(ref)

    def images(self) -> list[dict]:
        tags = self.registry.tags()
        by_digest: dict[str, list[str]] = {}
        for t, d in tags.items():
            by_digest.setdefault(d, []).append(t)
        return [
            {"digest": d[:12], "tags": sorted(by_digest.get(d, []))}
            for d in self.registry.images()
        ]

    # -- containers --------------------------------------------------------
    def run(self, ref_or_image, platform: str | None = None) -> Container:
        image = (ref_or_image if isinstance(ref_or_image, EnvImage)
                 else self.pull(ref_or_image))
        c = Container(image, platform=platform,
                      overlay_root=self.overlay_root,
                      compile_cache=self.compile_cache)
        c.ensure_overlay()
        return c

    def ps(self) -> list[dict]:
        out = []
        if self.overlay_root.exists():
            for d in sorted(self.overlay_root.iterdir()):
                meta = d / "container.json"
                if meta.exists():
                    rec = json.loads(meta.read_text())
                    rec["id"] = d.name
                    out.append(rec)
        return out
