"""Container: runtime instantiation of an EnvImage on a platform.

`docker run` analog. A Container binds the immutable image to

  * a concrete device mesh (the platform: local / pod / multipod),
  * resolved sharding rules (logical-axis table, FSDP/SP/ZeRO-1 toggles),
  * the collective ABI implementation named by the image,
  * compiled step functions (train / prefill / decode), obtained through
    the CompileCache (the import-problem fix),
  * a writable overlay directory (checkpoints, metrics, logs) -- the image
    is never mutated, many containers can share one image.

Input specs follow the assigned shape cell: ``input_specs()`` returns
weak-type-correct ShapeDtypeStructs (no allocation), which is what the
multi-pod dry-run lowers against.
"""

from __future__ import annotations

import json
import os
import uuid
from dataclasses import dataclass
from functools import cached_property, partial
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape_cell
from repro.core.abi import CollectiveABI, abi_from_image_config
from repro.core.compile_cache import CompileCache
from repro.core.image import EnvImage
from repro.dist.mesh import PLATFORMS, batch_axes, make_platform_mesh
from repro.dist.sharding import ShardingRules, check_divisibility, safe_spec
from repro.models.config import ModelConfig, ShapeCell
from repro.models.params import abstract, materialize, shardings as def_shardings
from repro.models.transformer import Model
from repro.serve.serve_step import ServeStepBuilder
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.train_step import TrainStepBuilder


_safe_spec = safe_spec  # shared with dist.sharding (exclude_axes-aware)


class Container:
    def __init__(self, image: EnvImage, platform: str | None = None,
                 overlay_root: str | os.PathLike | None = None,
                 compile_cache: CompileCache | None = None):
        self.image = image
        cfg = image.config()
        if cfg["arch"] is None:
            raise ValueError("image has no ARCH layer")
        self.settings: dict = dict(cfg.get("settings", {}))
        self.arch: ModelConfig = get_config(
            cfg["arch"]["name"], **cfg["arch"].get("overrides", {}))
        shape_cfg = dict(cfg.get("shape") or {})
        self.cell: ShapeCell | None = None
        if shape_cfg:
            base = get_shape_cell(shape_cfg.pop("name"))
            self.cell = base.scaled(**shape_cfg) if shape_cfg else base

        # platform: image default, overridable at run time (docker-run style)
        mesh_cfg = dict(cfg.get("mesh") or {"platform": "local"})
        self.platform = platform or mesh_cfg.get("platform", "local")
        self.mesh: Mesh = make_platform_mesh(self.platform)
        self.abi: CollectiveABI = abi_from_image_config(cfg)

        self.rules = ShardingRules.default(
            fsdp=bool(self.settings.get("fsdp", False)),
            seq_parallel=bool(self.settings.get("seq_parallel", False)),
        )
        extra_rules = self.settings.get("rules")
        if extra_rules:
            self.rules = self.rules.with_(**{
                k: (tuple(v) if isinstance(v, list) else v)
                for k, v in dict(extra_rules).items()
            })
        # ZeRO-1: optimizer state shards over the batch axes on 'embed' dims
        if self.abi.zero1:
            self.opt_rules = self.rules.with_(embed=("pod", "data"))
        else:
            self.opt_rules = self.rules

        tp = self.mesh.shape.get("model", 1)
        moe_impl = self.settings.get("moe_impl", "spmd")
        self.model = Model(
            self.arch, tp=tp,
            constrain=self._constrain,
            remat=str(self.settings.get("remat", "none")),
            act_dtype=jnp.dtype(cfg["precision"].get("compute", "bfloat16")),
            moe_mesh=self.mesh if (moe_impl == "spmd" and tp > 1
                                   and self.arch.n_experts) else None,
        )
        self.param_dtype = jnp.dtype(cfg["precision"].get("params", "float32"))
        self.cache_dtype = jnp.dtype(cfg["precision"].get("compute", "bfloat16"))
        self.opt = OptConfig(**self.settings.get("optimizer", {}))

        self.container_id = f"{image.short_digest}-{uuid.uuid4().hex[:8]}"
        self.overlay = (Path(overlay_root) if overlay_root
                        else Path(".stevedore") / "overlays") / self.container_id
        self.compile_cache = compile_cache
        self._metrics_path = self.overlay / "metrics.jsonl"
        # serve-step compile accounting, bucketed by dispatch class
        # ("prefill"/"decode"/"other" -> {hits, misses, seconds}); filled by
        # compile_serve_step, surfaced in SlotEngine.status()/`repro ps`
        self.serve_compile_stats: dict[str, dict] = {}

    # ------------------------------------------------------------------
    def _constrain(self, x, logical):
        spec = _safe_spec(x.shape, logical, self.mesh, self.rules)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    # -- parameters ---------------------------------------------------------
    @cached_property
    def param_defs(self):
        return self.model.param_defs()

    def param_shardings(self):
        return def_shardings(self.param_defs, self.mesh, self.rules)

    def opt_state_shardings(self):
        ps = def_shardings(self.param_defs, self.mesh, self.opt_rules)
        out = {"m": ps, "v": ps, "step": NamedSharding(self.mesh, P())}
        if self.param_dtype != jnp.float32:
            out["master"] = ps
        if self._powersgd_rank():
            from repro.dist.mesh import batch_axes
            baxes = batch_axes(self.mesh)
            sh0 = NamedSharding(self.mesh,
                                P(baxes if len(baxes) > 1 else baxes[0]))
            comm = self._comm_template(abstract_only=True)
            out["comm"] = jax.tree.map(lambda _: sh0, comm)
        return out

    def abstract_params(self):
        return abstract(self.param_defs, self.param_dtype)

    def abstract_opt_state(self):
        f32 = abstract(self.param_defs, jnp.float32)
        out = {"m": f32, "v": f32,
               "step": jax.ShapeDtypeStruct((), jnp.int32)}
        if self.param_dtype != jnp.float32:
            out["master"] = f32
        if self._powersgd_rank():
            out["comm"] = self._comm_template(abstract_only=True)
        return out

    def init_params(self, seed: int = 0):
        """Materialise params with the image's param shardings applied."""
        shs = self.param_shardings()
        init = jax.jit(
            lambda key: materialize(self.param_defs, key, self.param_dtype),
            out_shardings=shs)
        return init(jax.random.key(seed))

    def init_opt_state(self, params):
        from functools import partial
        init = partial(adamw_init,
                       with_master=self.param_dtype != jnp.float32)
        state = jax.jit(init, out_shardings={
            k: v for k, v in self.opt_state_shardings().items()
            if k != "comm"})(params)
        if self._powersgd_rank():
            from repro.train.compression import powersgd_init
            from repro.dist.mesh import batch_axes
            nsh = 1
            for a in batch_axes(self.mesh):
                nsh *= self.mesh.shape[a]
            comm = powersgd_init(jax.tree.map(lambda d: d, params),
                                 self._powersgd_rank())
            expand = lambda a: jnp.broadcast_to(a[None], (nsh, *a.shape))
            state["comm"] = {"q": jax.tree.map(expand, comm["q"]),
                             "err": jax.tree.map(expand, comm["err"])}
            sh = self.opt_state_shardings()["comm"]
            state["comm"] = jax.tree.map(jax.device_put, state["comm"], sh)
        return state

    def _powersgd_rank(self) -> int:
        if self.abi.options.get("compression") == "powersgd":
            return int(self.abi.options.get("rank", 16))
        return 0

    def _comm_template(self, abstract_only: bool = False):
        """Abstract comm-state tree: per-shard leading axis on q/err."""
        from repro.train.compression import _as_matrix, _compressible
        from repro.dist.mesh import batch_axes
        rank = self._powersgd_rank()
        nsh = 1
        for a in batch_axes(self.mesh):
            nsh *= self.mesh.shape[a]
        aparams = self.abstract_params()

        def q_leaf(p):
            if not _compressible(p, rank):
                return None
            n = int(np.prod(p.shape[1:]))
            return jax.ShapeDtypeStruct((nsh, n, rank), jnp.float32)

        def e_leaf(p):
            if not _compressible(p, rank):
                return None
            return jax.ShapeDtypeStruct((nsh, *p.shape), jnp.float32)

        return {"q": jax.tree.map(q_leaf, aparams),
                "err": jax.tree.map(e_leaf, aparams)}

    # -- input specs (ShapeDtypeStruct stand-ins; no allocation) -------------
    def input_specs(self, kind: str | None = None) -> dict:
        cell = self.cell
        if cell is None:
            raise ValueError("image has no SHAPE layer")
        kind = kind or cell.kind
        B, S = cell.global_batch, cell.seq_len
        fe_len = self.arch.frontend_len if self.arch.frontend else 0
        tok = jax.ShapeDtypeStruct((B, S - fe_len), jnp.int32)
        fe = (jax.ShapeDtypeStruct((B, fe_len, self.arch.d_model), self.cache_dtype)
              if fe_len else None)
        if kind == "train":
            batch = {"tokens": tok,
                     "labels": jax.ShapeDtypeStruct((B, S - fe_len), jnp.int32)}
            if fe is not None:
                batch["frontend_embeds"] = fe
            return {"batch": batch}
        if kind == "prefill":
            out = {"tokens": tok}
            if fe is not None:
                out["frontend_embeds"] = fe
            return out
        if kind == "decode":
            cache_defs = self.model.cache_defs(B, S, self.cache_dtype)
            cache = self._abstract_cache(cache_defs)
            return {
                "cache": cache,
                "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "idx": jax.ShapeDtypeStruct((), jnp.int32),
            }
        raise ValueError(f"unknown step kind {kind!r}")

    def _abstract_cache(self, cache_defs):
        def leaf(d):
            # recurrent states are f32; kv/conv follow the compute dtype
            dt = jnp.float32 if d.shape and d.logical and (
                d.logical[-1] in ("rnn",) and len(d.shape) == 3
                or (len(d.shape) == 5 and d.logical[2] == "heads")
            ) else self.cache_dtype
            return jax.ShapeDtypeStruct(d.shape, dt)
        from repro.models.params import is_def
        return jax.tree.map(leaf, cache_defs, is_leaf=is_def)

    def input_shardings(self, specs) -> Any:
        """Tree of NamedShardings for an input_specs() tree."""
        def leaf_spec(x, logical):
            return NamedSharding(self.mesh,
                                 _safe_spec(x.shape, logical, self.mesh, self.rules))

        out: dict = {}
        for k, v in specs.items():
            if k == "batch":
                out[k] = {
                    kk: leaf_spec(vv, ("batch",) + (None,) * (vv.ndim - 1))
                    for kk, vv in v.items()
                }
            elif k in ("tokens", "frontend_embeds"):
                out[k] = leaf_spec(v, ("batch",) + (None,) * (v.ndim - 1))
            elif k == "idx":
                out[k] = NamedSharding(self.mesh, P())
            elif k == "cache":
                cache_defs = self.model.cache_defs(
                    self.cell.global_batch, self.cell.seq_len, self.cache_dtype)
                from repro.models.params import is_def
                out[k] = jax.tree.map(
                    lambda d: NamedSharding(
                        self.mesh,
                        _safe_spec(d.shape, d.logical, self.mesh, self.rules)),
                    cache_defs, is_leaf=is_def)
            else:
                raise KeyError(k)
        return out

    # -- step builders --------------------------------------------------------
    def train_step_fn(self) -> Callable:
        builder = TrainStepBuilder(
            model=self.model, mesh=self.mesh, rules=self.rules, abi=self.abi,
            opt=self.opt, microbatches=int(self.settings.get("microbatches", 1)))
        return builder.build()

    def prefill_fn(self, cache_len: int | None = None) -> Callable:
        b = ServeStepBuilder(self.model, self.mesh, self.rules)
        return b.build_prefill(cache_len or (self.cell.seq_len if self.cell else 0))

    def decode_fn(self) -> Callable:
        return ServeStepBuilder(self.model, self.mesh, self.rules).build_decode()

    # -- serving: slot-granular cache + compile-cached serve steps -------------
    def slot_cache_specs(self, n_slots: int, max_len: int):
        """Abstract KV/recurrent cache for a bank of ``n_slots`` independent
        request slots (each row one request, ``max_len`` positions)."""
        return self._abstract_cache(
            self.model.cache_defs(n_slots, max_len, self.cache_dtype))

    def slot_cache_shardings(self, n_slots: int, max_len: int):
        return self._cache_shardings(
            self.model.cache_defs(n_slots, max_len, self.cache_dtype))

    def init_slot_cache(self, n_slots: int, max_len: int):
        """Zero-initialised slot cache, placed per the image's shardings."""
        specs = self.slot_cache_specs(n_slots, max_len)
        sh = self.slot_cache_shardings(n_slots, max_len)
        return jax.tree.map(
            lambda s, nsh: jax.device_put(jnp.zeros(s.shape, s.dtype), nsh),
            specs, sh)

    # -- paged serving: global page pool shared by all slots -------------------
    def paged_cache_specs(self, n_pages: int, page_size: int):
        """Abstract paged KV pool: per attention layer (n_kv, n_pages,
        page_size, hd); slots address it through the host PagePool's table."""
        return self._abstract_cache(
            self.model.paged_cache_defs(n_pages, page_size, self.cache_dtype))

    def paged_cache_shardings(self, n_pages: int, page_size: int):
        return self._cache_shardings(
            self.model.paged_cache_defs(n_pages, page_size, self.cache_dtype))

    def init_paged_cache(self, n_pages: int, page_size: int):
        """Zero-initialised page pool, placed per the image's shardings."""
        specs = self.paged_cache_specs(n_pages, page_size)
        sh = self.paged_cache_shardings(n_pages, page_size)
        return jax.tree.map(
            lambda s, nsh: jax.device_put(jnp.zeros(s.shape, s.dtype), nsh),
            specs, sh)

    def _cache_shardings(self, cache_defs):
        from repro.models.params import is_def
        return jax.tree.map(
            lambda d: NamedSharding(self.mesh, _safe_spec(
                d.shape, d.logical, self.mesh, self.rules)),
            cache_defs, is_leaf=is_def)

    def _batch_sharding(self, shape):
        return NamedSharding(self.mesh, _safe_spec(
            shape, ("batch",) + (None,) * (len(shape) - 1), self.mesh,
            self.rules))

    def lower_serve_step(self, kind: str, *, batch: int | None = None,
                         prompt_len: int | None = None,
                         cache_len: int | None = None,
                         gen_steps: int | None = None,
                         n_pages: int | None = None,
                         page_size: int | None = None,
                         max_pages: int | None = None,
                         frontend_len: int | None = None,
                         prefix_len: int | None = None,
                         per_row: bool | None = None, donate: bool = True):
        """jit + lower a serving step at arbitrary (non-cell) shapes.

        kinds: ``prefill`` (B,P -> last_logits+cache), ``prefill_slot``
        (B,P bucket + lengths -> first tokens + cache; ``frontend_len``
        adds a modality-prefix buffer + per-row prefix lengths ahead of the
        prompt), ``decode_slots`` (slot bank, per-row positions),
        ``generate`` (scanned greedy loop; ``per_row`` makes the start
        position a (B,) vector for mixed-length waves), plus the
        ``*_paged`` variants (KV as a global page pool + per-slot page
        table; see kernels/paged_attention).
        All carry explicit in/out shardings -- replicated-output caches
        would all-gather the full KV (see lower_step NOTE).
        """
        from repro.models.layers import padded_vocab
        b = ServeStepBuilder(self.model, self.mesh, self.rules)
        pspec = self.param_shardings()
        rep = NamedSharding(self.mesh, P())
        vp = padded_vocab(self.arch.vocab_size)
        aparams = self.abstract_params()
        tok = jnp.int32

        if kind == "prefill":
            fn = b.build_prefill(cache_len)
            toks = jax.ShapeDtypeStruct((batch, prompt_len), tok)
            cache_sh = self._cache_shardings(
                self.model.cache_defs(batch, cache_len, self.cache_dtype))
            logits_sh = NamedSharding(self.mesh, _safe_spec(
                (batch, vp), ("batch", "vocab"), self.mesh, self.rules))
            jitted = jax.jit(
                fn, in_shardings=(pspec, self._batch_sharding(toks.shape)),
                out_shardings=(logits_sh, cache_sh))
            return jitted.lower(aparams, toks)
        if kind == "prefill_slot":
            B = batch or 1
            fe_len = frontend_len or 0
            fn = b.build_prefill_slot(cache_len, fe_len)
            toks = jax.ShapeDtypeStruct((B, prompt_len), tok)
            # B=1 (orchestrator slot prefill): scalar length, replicated
            # outputs; B>1 (static wave prefill): per-row length vectors
            length = (jax.ShapeDtypeStruct((B,), tok) if B > 1
                      else jax.ShapeDtypeStruct((), tok))
            len_sh = self._batch_sharding((B,)) if B > 1 else rep
            first_sh = self._batch_sharding((B,)) if B > 1 else rep
            cache_sh = self._cache_shardings(
                self.model.cache_defs(B, cache_len, self.cache_dtype))
            args = [aparams, toks, length]
            arg_sh = [pspec, self._batch_sharding(toks.shape), len_sh]
            if fe_len:
                fe = jax.ShapeDtypeStruct((B, fe_len, self.arch.d_model),
                                          self.cache_dtype)
                args += [fe, length]
                arg_sh += [self._batch_sharding(fe.shape), len_sh]
            jitted = jax.jit(fn, in_shardings=tuple(arg_sh),
                             out_shardings=(first_sh, cache_sh))
            return jitted.lower(*args)
        if kind == "decode_slots":
            fn = b.build_decode_slots()
            cache = self.slot_cache_specs(batch, cache_len)
            cache_sh = self.slot_cache_shardings(batch, cache_len)
            toks = jax.ShapeDtypeStruct((batch, 1), tok)
            pos = jax.ShapeDtypeStruct((batch,), tok)
            tok_sh = self._batch_sharding(toks.shape)
            jitted = jax.jit(
                fn,
                in_shardings=(pspec, cache_sh, tok_sh,
                              self._batch_sharding(pos.shape)),
                out_shardings=(self._batch_sharding(pos.shape), cache_sh),
                donate_argnums=(1,) if donate else (),
            )
            return jitted.lower(aparams, cache, toks, pos)
        if kind == "decode_chunk":
            fn = b.build_decode_chunk(gen_steps)
            cache = self.slot_cache_specs(batch, cache_len)
            cache_sh = self.slot_cache_shardings(batch, cache_len)
            toks = jax.ShapeDtypeStruct((batch, 1), tok)
            pos = jax.ShapeDtypeStruct((batch,), tok)
            tok_sh = self._batch_sharding(toks.shape)
            pos_sh = self._batch_sharding(pos.shape)
            jitted = jax.jit(
                fn,
                in_shardings=(pspec, cache_sh, tok_sh, pos_sh),
                out_shardings=(self._batch_sharding((batch, gen_steps)),
                               tok_sh, pos_sh, cache_sh),
                donate_argnums=(1,) if donate else (),
            )
            return jitted.lower(aparams, cache, toks, pos)
        if kind == "prefill_slot_paged":
            fe_len = frontend_len or 0
            pfx = prefix_len or 0
            fn = b.build_prefill_slot_paged(prompt_len, page_size, fe_len,
                                            pfx)
            toks = jax.ShapeDtypeStruct((1, prompt_len), tok)
            length = jax.ShapeDtypeStruct((), tok)
            if pfx:
                # prefix-registry hit: suffix-only prefill reading the
                # matched chain's pages straight out of the live pool
                # (undonated). pfx may end mid-page (radix partial match):
                # the page list rounds UP to cover the boundary page, and
                # the output cache covers the merged front-partial rows too
                frac = pfx % page_size
                np_ = -(-(frac + prompt_len) // page_size)
                cache_sh = self._cache_shardings(
                    self.model.paged_cache_defs(np_, page_size,
                                                self.cache_dtype))
                pool = self.paged_cache_specs(n_pages, page_size)
                pool_sh = self.paged_cache_shardings(n_pages, page_size)
                pages = jax.ShapeDtypeStruct((-(-pfx // page_size),), tok)
                jitted = jax.jit(
                    fn,
                    in_shardings=(pspec, pool_sh,
                                  self._batch_sharding(toks.shape), rep, rep),
                    out_shardings=(rep, cache_sh))
                return jitted.lower(aparams, pool, toks, length, pages)
            np_ = -(-(prompt_len + fe_len) // page_size)
            # the page-major small cache reuses the pool defs at np_ pages
            cache_sh = self._cache_shardings(
                self.model.paged_cache_defs(np_, page_size, self.cache_dtype))
            args = [aparams, toks, length]
            arg_sh = [pspec, self._batch_sharding(toks.shape), rep]
            if fe_len:
                fe = jax.ShapeDtypeStruct((1, fe_len, self.arch.d_model),
                                          self.cache_dtype)
                args += [fe, length]
                arg_sh += [self._batch_sharding(fe.shape), rep]
            jitted = jax.jit(fn, in_shardings=tuple(arg_sh),
                             out_shardings=(rep, cache_sh))
            return jitted.lower(*args)
        if kind in ("decode_slots_paged", "decode_chunk_paged"):
            chunked = kind == "decode_chunk_paged"
            fn = (b.build_decode_chunk_paged(gen_steps) if chunked
                  else b.build_decode_slots_paged())
            cache = self.paged_cache_specs(n_pages, page_size)
            cache_sh = self.paged_cache_shardings(n_pages, page_size)
            toks = jax.ShapeDtypeStruct((batch, 1), tok)
            pos = jax.ShapeDtypeStruct((batch,), tok)
            table = jax.ShapeDtypeStruct((batch, max_pages), tok)
            tok_sh = self._batch_sharding(toks.shape)
            pos_sh = self._batch_sharding(pos.shape)
            table_sh = self._batch_sharding(table.shape)
            out_sh = ((self._batch_sharding((batch, gen_steps)),
                       tok_sh, pos_sh, cache_sh) if chunked
                      else (pos_sh, cache_sh))
            jitted = jax.jit(
                fn,
                in_shardings=(pspec, cache_sh, tok_sh, pos_sh, table_sh),
                out_shardings=out_sh,
                donate_argnums=(1,) if donate else (),
            )
            return jitted.lower(aparams, cache, toks, pos, table)
        if kind == "generate":
            fn = b.build_generate_loop(gen_steps)
            cache = self._abstract_cache(
                self.model.cache_defs(batch, cache_len, self.cache_dtype))
            cache_sh = self._cache_shardings(
                self.model.cache_defs(batch, cache_len, self.cache_dtype))
            first = jax.ShapeDtypeStruct((batch, 1), tok)
            # per_row: mixed-length waves decode from per-row start
            # positions (decode_attn already takes (B,) idx vectors)
            start = (jax.ShapeDtypeStruct((batch,), tok) if per_row
                     else jax.ShapeDtypeStruct((), tok))
            start_sh = self._batch_sharding((batch,)) if per_row else rep
            out_sh = self._batch_sharding((batch, gen_steps))
            jitted = jax.jit(
                fn,
                in_shardings=(pspec, cache_sh,
                              self._batch_sharding(first.shape), start_sh),
                out_shardings=(out_sh, cache_sh),
                donate_argnums=(1,) if donate else (),
            )
            return jitted.lower(aparams, cache, first, start)
        raise ValueError(f"unknown serve step kind {kind!r}")

    def _serve_cache_digest(self) -> str:
        """Cache identity for serve steps: only the image config sections
        that determine the lowered computation (arch/mesh/precision/
        settings). Keying on the raw image digest would defeat the rollover
        warm-start -- a release that only re-points a tag at an image with
        new LABEL/COLLECTIVES layers would always miss despite lowering the
        byte-identical serve step."""
        import hashlib
        cfg = self.image.config()
        rel = {k: cfg.get(k) for k in ("arch", "mesh", "precision",
                                       "settings")}
        return hashlib.sha256(
            json.dumps(rel, sort_keys=True, default=str).encode()).hexdigest()

    def compile_serve_step(self, kind: str, **shapes):
        """lower+compile a serve step through the CompileCache when attached.

        This is the import-problem fix applied to serving: every replica of
        a Pod, a rerun of the same driver, or a rollover to a re-tagged
        image whose serving-relevant layers are unchanged deserializes the
        executable instead of re-tracing (see _serve_cache_digest).
        """
        from repro.serve.serve_step import dispatch_class
        acct = self.serve_compile_stats.setdefault(
            dispatch_class(kind), {"hits": 0, "misses": 0, "seconds": 0.0})
        if self.compile_cache is None:
            import time
            t0 = time.perf_counter()
            exe = self.lower_serve_step(kind, **shapes).compile()
            acct["misses"] += 1
            acct["seconds"] += time.perf_counter() - t0
            return exe
        sig = ",".join(f"{k}={v}" for k, v in sorted(shapes.items())
                       if v is not None)
        key = self.compile_cache.key(
            image_digest=self._serve_cache_digest(),
            step_kind=f"serve:{kind}[{sig}]",
            mesh=self.mesh, args_tree=None)
        stats = self.compile_cache.stats
        hits0, miss0 = stats.hits_l1 + stats.hits_l2, stats.misses
        exe = self.compile_cache.get_or_build(
            key, lambda: self.lower_serve_step(kind, **shapes))
        acct["hits"] += (stats.hits_l1 + stats.hits_l2) - hits0
        acct["misses"] += stats.misses - miss0
        acct["seconds"] += stats.last_seconds
        return exe

    # -- lowering (the dry-run entry) ------------------------------------------
    def lower_step(self, kind: str | None = None, donate: bool = True):
        """jit + lower the step for this image's shape cell. Returns Lowered."""
        kind = kind or (self.cell.kind if self.cell else "train")
        specs = self.input_specs(kind)
        in_sh = self.input_shardings(specs)
        pspec = self.param_shardings()

        if kind == "train":
            step = self.train_step_fn()
            ospec = self.opt_state_shardings()
            rep = NamedSharding(self.mesh, P())
            mspec = {"loss": rep, "aux_loss": rep, "grad_norm": rep, "lr": rep}
            jitted = jax.jit(
                step,
                in_shardings=(pspec, ospec, in_sh["batch"]),
                out_shardings=(pspec, ospec, mspec),
                donate_argnums=(0, 1) if donate else (),
            )
            return jitted.lower(self.abstract_params(),
                                self.abstract_opt_state(), specs["batch"])
        if kind == "prefill":
            fn = self.prefill_fn()
            args = [self.abstract_params(), specs["tokens"]]
            arg_sh = [pspec, in_sh["tokens"]]
            if "frontend_embeds" in specs:
                args.append(specs["frontend_embeds"])
                arg_sh.append(in_sh["frontend_embeds"])
            # outputs: (last_logits, cache) -- cache MUST come out sharded
            # (replicated-output caches would all-gather 100s of GB)
            cell = self.cell
            cache_defs = self.model.cache_defs(cell.global_batch,
                                               cell.seq_len, self.cache_dtype)
            from repro.models.params import is_def
            cache_out_sh = jax.tree.map(
                lambda d: NamedSharding(self.mesh, _safe_spec(
                    d.shape, d.logical, self.mesh, self.rules)),
                cache_defs, is_leaf=is_def)
            from repro.models.layers import padded_vocab
            logits_sh = NamedSharding(self.mesh, _safe_spec(
                (cell.global_batch, padded_vocab(self.arch.vocab_size)),
                ("batch", "vocab"), self.mesh, self.rules))
            jitted = jax.jit(fn, in_shardings=tuple(arg_sh),
                             out_shardings=(logits_sh, cache_out_sh))
            return jitted.lower(*args)
        if kind == "decode":
            fn = self.decode_fn()
            cache_sh = in_sh["cache"]
            jitted = jax.jit(
                fn,
                in_shardings=(pspec, cache_sh, in_sh["tokens"], in_sh["idx"]),
                out_shardings=(NamedSharding(self.mesh, P()), cache_sh),
                donate_argnums=(1,) if donate else (),
            )
            return jitted.lower(self.abstract_params(), specs["cache"],
                                specs["tokens"], specs["idx"])
        raise ValueError(kind)

    def lower_unit_probe(self, si: int, kind: str | None = None):
        """Lower the per-unit cost probe for stage ``si`` (scan correction).

        Returns (lowered, count) where count is the stage's scan trip count.
        """
        kind = kind or (self.cell.kind if self.cell else "train")
        st = self.model.stages[si]
        cell = self.cell
        B = cell.global_batch
        S = cell.seq_len if kind != "decode" else 1
        D = self.arch.d_model
        act = self.model.act_dtype

        udefs = self.model.unit_param_defs(si)
        u_abs = abstract(udefs, self.param_dtype)
        u_sh = def_shardings(udefs, self.mesh, self.rules)
        x_abs = jax.ShapeDtypeStruct((B, S, D), act)
        x_sh = NamedSharding(self.mesh, _safe_spec(
            (B, S, D), ("batch", "seq", "embed"), self.mesh, self.rules))
        probe = self.model.unit_probe(si, kind)

        # NOTE: probe OUTPUTS carry explicit shardings -- otherwise XLA may
        # choose replicated outputs, paying a full-batch all-gather per unit
        # that the real (scanned) module never pays; this inflated the
        # collective term ~5-10x before it was caught (EXPERIMENTS.md §Perf).
        rep = NamedSharding(self.mesh, P())
        from repro.models.params import is_def

        def _cache_sh(cdefs):
            return jax.tree.map(
                lambda d: NamedSharding(self.mesh, _safe_spec(
                    d.shape, d.logical, self.mesh, self.rules)),
                cdefs, is_leaf=is_def)

        if kind in ("train", "prefill"):
            pos_abs = jax.ShapeDtypeStruct((1, S), jnp.int32)
            if kind == "train":
                out_sh = (u_sh, x_sh)
            else:
                ys_defs = self.model.unit_cache_defs(si, B, S,
                                                     self.cache_dtype)
                out_sh = (x_sh, rep, _cache_sh(ys_defs))
            jitted = jax.jit(probe, in_shardings=(u_sh, x_sh, rep),
                             out_shardings=out_sh)
            return jitted.lower(u_abs, x_abs, pos_abs), st.count
        if kind == "decode":
            cdefs = self.model.unit_cache_defs(si, B, cell.seq_len,
                                               self.cache_dtype)
            c_abs = self._abstract_cache(cdefs)
            c_sh = _cache_sh(cdefs)
            idx_abs = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(
                probe,
                in_shardings=(u_sh, c_sh, x_sh, rep),
                out_shardings=(x_sh, c_sh))
            return jitted.lower(u_abs, c_abs, x_abs, idx_abs), st.count
        raise ValueError(kind)

    def compile_step(self, kind: str | None = None):
        """lower+compile, via the CompileCache when one is attached."""
        kind = kind or (self.cell.kind if self.cell else "train")
        if self.compile_cache is None:
            return self.lower_step(kind).compile()
        key = self.compile_cache.key(
            image_digest=self.image.digest, step_kind=kind, mesh=self.mesh,
            args_tree=self.input_specs(kind))
        return self.compile_cache.get_or_build(
            key, lambda: self.lower_step(kind))

    # -- overlay (writable layer) ----------------------------------------------
    def ensure_overlay(self) -> Path:
        self.overlay.mkdir(parents=True, exist_ok=True)
        meta = self.overlay / "container.json"
        if not meta.exists():
            meta.write_text(json.dumps({
                "image": self.image.digest,
                "platform": self.platform,
                "arch": self.arch.name,
                "cell": self.cell.name if self.cell else None,
                "abi": self.abi.describe(),
            }, indent=2))
        return self.overlay

    def log_metrics(self, step: int, metrics: dict) -> None:
        self.ensure_overlay()
        rec = {"step": step}
        for k, v in metrics.items():
            rec[k] = float(v) if hasattr(v, "__float__") or isinstance(
                v, (int, float, np.floating)) else v
        with open(self._metrics_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
