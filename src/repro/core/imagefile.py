"""Imagefile: the Dockerfile analog (paper §2.2).

A plain-text, line-oriented, deterministic description of an EnvImage build::

    FROM scratch                      # or FROM <tag-or-digest> (needs a registry)
    ARCH llama3.2-3b n_layers=28
    SHAPE train_4k
    MESH pod
    PRECISION compute=bfloat16 params=float32
    COLLECTIVES host zero1=true grad_compression=bfloat16
    SET remat=selective scan_layers=true
    LABEL maintainer=stevedore tier=stable

Values parse as JSON scalars when possible (true/false/ints/floats), else as
strings -- so ``zero1=true`` is a bool and ``window=2048`` an int, mirroring
how a Dockerfile's build args stay uninterpreted until used.
"""

from __future__ import annotations

import json
from typing import Any

from repro.core.image import EnvImage, ImageBuilder

DIRECTIVES = ("FROM", "ARCH", "SHAPE", "MESH", "PRECISION", "COLLECTIVES", "SET", "LABEL")


class ImagefileError(ValueError):
    pass


def _parse_value(raw: str) -> Any:
    try:
        return json.loads(raw)
    except (json.JSONDecodeError, ValueError):
        return raw


def _parse_kv(tokens: list[str], directive: str, lineno: int) -> dict[str, Any]:
    out: dict[str, Any] = {}
    for tok in tokens:
        if "=" not in tok:
            raise ImagefileError(f"line {lineno}: {directive} expects key=value, got {tok!r}")
        k, _, v = tok.partition("=")
        out[k] = _parse_value(v)
    return out


def parse_imagefile(text: str, registry=None) -> EnvImage:
    """Build an EnvImage from Imagefile text.

    ``FROM <ref>`` other than ``scratch`` resolves through ``registry``
    (a repro.core.registry.Registry), inheriting all base layers -- the
    paper's `FROM quay.io/fenicsproject/stable` pattern.
    """
    builder: ImageBuilder | None = None
    for lineno, raw_line in enumerate(text.splitlines(), start=1):
        line = raw_line.split("#", 1)[0].strip()
        if not line:
            continue
        tokens = line.split()
        directive, args = tokens[0].upper(), tokens[1:]
        if directive not in DIRECTIVES:
            raise ImagefileError(f"line {lineno}: unknown directive {directive!r}")

        if directive == "FROM":
            if builder is not None:
                raise ImagefileError(f"line {lineno}: FROM must be the first directive")
            if len(args) != 1:
                raise ImagefileError(f"line {lineno}: FROM takes exactly one ref")
            ref = args[0]
            if ref == "scratch":
                builder = ImageBuilder.from_scratch()
            else:
                if registry is None:
                    raise ImagefileError(
                        f"line {lineno}: FROM {ref!r} needs a registry to resolve against"
                    )
                builder = ImageBuilder.from_image(registry.pull(ref))
            continue

        if builder is None:
            raise ImagefileError(f"line {lineno}: first directive must be FROM")

        if directive in ("ARCH", "SHAPE", "MESH", "COLLECTIVES"):
            if not args:
                raise ImagefileError(f"line {lineno}: {directive} needs a name")
            name, kv = args[0], _parse_kv(args[1:], directive, lineno)
            if directive == "ARCH":
                builder.arch(name, **kv)
            elif directive == "SHAPE":
                builder.shape(name, **kv)
            elif directive == "MESH":
                builder.mesh(name, **kv)
            else:
                builder.collectives(name, **kv)
        elif directive == "PRECISION":
            builder.precision(**_parse_kv(args, directive, lineno))
        elif directive == "SET":
            builder.set(**_parse_kv(args, directive, lineno))
        elif directive == "LABEL":
            builder.label(**{k: str(v) for k, v in _parse_kv(args, directive, lineno).items()})

    if builder is None:
        raise ImagefileError("empty Imagefile")
    return builder.build()


def render_imagefile(image: EnvImage) -> str:
    """Inverse of parse: emit Imagefile text for an image (``docker history``
    in reusable form). parse(render(img)) reproduces img's digest when the
    image was built from scratch."""
    lines: list[str] = []
    for layer in image.layers:
        p = dict(layer.payload)
        if layer.kind == "base":
            lines.append("FROM scratch")
        elif layer.kind == "arch":
            kv = " ".join(f"{k}={json.dumps(v)}" for k, v in sorted(p.get("overrides", {}).items()))
            lines.append(f"ARCH {p['name']}" + (f" {kv}" if kv else ""))
        elif layer.kind in ("shape", "mesh", "collectives"):
            key = {"shape": "SHAPE", "mesh": "MESH", "collectives": "COLLECTIVES"}[layer.kind]
            name = p.pop("name", None) or p.pop("platform", None)
            kv = " ".join(f"{k}={json.dumps(v)}" for k, v in sorted(p.items()))
            lines.append(f"{key} {name}" + (f" {kv}" if kv else ""))
        elif layer.kind == "precision":
            kv = " ".join(f"{k}={v}" for k, v in sorted(p.items()))
            lines.append(f"PRECISION {kv}")
        elif layer.kind == "set":
            kv = " ".join(f"{k}={json.dumps(v)}" for k, v in sorted(p.items()))
            lines.append(f"SET {kv}")
        elif layer.kind == "label":
            kv = " ".join(f"{k}={v}" for k, v in sorted(p.items()))
            lines.append(f"LABEL {kv}")
    return "\n".join(lines) + "\n"
