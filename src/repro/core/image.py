"""EnvImage: immutable, layered, content-addressed environment images.

Direct analog of the paper's Docker/OCI images (paper §2.1-2.2):

* an image is an ordered chain of *layers*; each layer stores only the
  difference (here: a config delta) relative to its parent;
* every layer and every image is identified by a sha256 content hash, so two
  images built from the same Imagefile prefix share layer objects byte-for-byte
  (the "layered file system" benefit of §2.2);
* images are immutable: runtime mutation happens in a Container's writable
  overlay (container.py), never in the image.

The merged-config semantics are "later layer wins", exactly like Docker's
union mount: the final environment is the left-fold of layer deltas.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Iterable, Mapping

# Layer kinds, in the spirit of Dockerfile directives.
LAYER_KINDS = (
    "base",         # FROM scratch: framework + format version pin
    "arch",         # ARCH: model architecture selection + overrides
    "shape",        # SHAPE: input-shape cell (train_4k / prefill_32k / ...)
    "mesh",         # MESH: platform / mesh layout selection
    "precision",    # PRECISION: param/compute/grad dtypes
    "collectives",  # COLLECTIVES: collective-ABI selection + options
    "set",          # SET: free-form runtime settings (remat, scan, ...)
    "label",        # LABEL: inert metadata (does not affect behaviour hash-wise
                    #        it still hashes -- images are bit-exact artifacts)
)


def canonical_json(obj: Any) -> str:
    """Deterministic JSON used for all content hashes."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"), default=_json_default)


def _json_default(o: Any):
    # tuples arrive as lists already; dataclasses / sets get normalised here.
    if isinstance(o, (set, frozenset)):
        return sorted(o)
    raise TypeError(f"not canonically serialisable: {type(o)}")


def _sha256(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()


@dataclass(frozen=True)
class Layer:
    """One immutable config delta. ``parent`` chains layers into an image."""

    kind: str
    payload: Mapping[str, Any]
    parent: str | None = None  # parent layer digest, None for the first layer

    def __post_init__(self):
        if self.kind not in LAYER_KINDS:
            raise ValueError(f"unknown layer kind {self.kind!r}; one of {LAYER_KINDS}")
        # freeze payload
        object.__setattr__(self, "payload", dict(self.payload))

    @property
    def digest(self) -> str:
        body = canonical_json({"kind": self.kind, "payload": self.payload, "parent": self.parent})
        return _sha256(body)

    def to_json(self) -> str:
        return canonical_json({"kind": self.kind, "payload": self.payload, "parent": self.parent})

    @staticmethod
    def from_json(text: str) -> "Layer":
        d = json.loads(text)
        return Layer(kind=d["kind"], payload=d["payload"], parent=d["parent"])


@dataclass(frozen=True)
class EnvImage:
    """An immutable chain of layers.

    ``digest`` identifies the image; because each layer hashes its parent,
    the top layer digest alone pins the whole chain, but we also hash the
    explicit list so an image object is self-verifying.
    """

    layers: tuple[Layer, ...]

    def __post_init__(self):
        if not self.layers:
            raise ValueError("an image needs at least one layer")
        if self.layers[0].parent is not None:
            raise ValueError("first layer must have parent=None")
        for prev, cur in zip(self.layers, self.layers[1:]):
            if cur.parent != prev.digest:
                raise ValueError(
                    f"broken layer chain: {cur.kind} parent {cur.parent!r} != {prev.digest!r}"
                )

    @property
    def digest(self) -> str:
        return _sha256(canonical_json([l.digest for l in self.layers]))

    @property
    def short_digest(self) -> str:
        return self.digest[:12]

    # ---- merged config ------------------------------------------------
    def config(self) -> dict[str, Any]:
        """Left-fold of layer deltas -> the complete environment description.

        Shape of the result:
          {"base": {...}, "arch": {"name":..., "overrides": {...}},
           "shape": {...}, "mesh": {...}, "precision": {...},
           "collectives": {...}, "settings": {...}, "labels": {...}}
        """
        cfg: dict[str, Any] = {
            "base": {},
            "arch": None,
            "shape": None,
            "mesh": None,
            "precision": {"params": "float32", "compute": "bfloat16", "grads": "float32"},
            "collectives": {"name": "generic"},
            "settings": {},
            "labels": {},
        }
        for layer in self.layers:
            p = dict(layer.payload)
            if layer.kind == "base":
                cfg["base"].update(p)
            elif layer.kind == "arch":
                cfg["arch"] = p
            elif layer.kind == "shape":
                cfg["shape"] = p
            elif layer.kind == "mesh":
                cfg["mesh"] = p
            elif layer.kind == "precision":
                cfg["precision"].update(p)
            elif layer.kind == "collectives":
                cfg["collectives"] = p
            elif layer.kind == "set":
                cfg["settings"].update(p)
            elif layer.kind == "label":
                cfg["labels"].update(p)
        return cfg

    def history(self) -> list[tuple[str, str, str]]:
        """(digest12, kind, payload-summary) per layer -- `docker history` analog."""
        out = []
        for l in self.layers:
            summary = canonical_json(l.payload)
            if len(summary) > 72:
                summary = summary[:69] + "..."
            out.append((l.digest[:12], l.kind, summary))
        return out


class ImageBuilder:
    """Programmatic Dockerfile: appends layers, builds an EnvImage.

    ``ImageBuilder.from_image(img)`` is the `FROM <tag>` directive -- the new
    image shares every existing layer object with its base (layer dedupe).
    """

    FORMAT_VERSION = 1

    def __init__(self, layers: Iterable[Layer] = ()):
        self._layers: list[Layer] = list(layers)

    # -- FROM ------------------------------------------------------------
    @classmethod
    def from_scratch(cls, framework_version: str | None = None) -> "ImageBuilder":
        from repro import __version__

        b = cls()
        b._append(
            "base",
            {
                "format": cls.FORMAT_VERSION,
                "framework": "stevedore",
                "framework_version": framework_version or __version__,
            },
        )
        return b

    @classmethod
    def from_image(cls, image: EnvImage) -> "ImageBuilder":
        return cls(image.layers)

    # -- directives --------------------------------------------------------
    def arch(self, name: str, **overrides: Any) -> "ImageBuilder":
        return self._append("arch", {"name": name, "overrides": overrides})

    def shape(self, name: str, **overrides: Any) -> "ImageBuilder":
        return self._append("shape", {"name": name, **overrides})

    def mesh(self, platform: str, **overrides: Any) -> "ImageBuilder":
        return self._append("mesh", {"platform": platform, **overrides})

    def precision(self, **dtypes: str) -> "ImageBuilder":
        bad = set(dtypes) - {"params", "compute", "grads"}
        if bad:
            raise ValueError(f"unknown precision keys {bad}")
        return self._append("precision", dtypes)

    def collectives(self, name: str, **options: Any) -> "ImageBuilder":
        return self._append("collectives", {"name": name, **options})

    def set(self, **settings: Any) -> "ImageBuilder":
        return self._append("set", settings)

    def label(self, **labels: str) -> "ImageBuilder":
        return self._append("label", labels)

    # -- build -------------------------------------------------------------
    def build(self) -> EnvImage:
        if not self._layers:
            raise ValueError("empty build: start with from_scratch()/from_image()")
        return EnvImage(tuple(self._layers))

    def _append(self, kind: str, payload: Mapping[str, Any]) -> "ImageBuilder":
        parent = self._layers[-1].digest if self._layers else None
        self._layers.append(Layer(kind=kind, payload=payload, parent=parent))
        return self
