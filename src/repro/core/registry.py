"""Registry: content-addressed image store with tags (paper §2.2, §3.4).

Mirrors quay.io / Docker Hub mechanics:

* ``layers/<digest>``  -- one JSON blob per layer, stored once no matter how
  many images reference it (the layered-FS dedupe of §2.2);
* ``images/<digest>``  -- manifest: ordered list of layer digests;
* ``tags/<name>``      -- mutable pointer to an image digest
  (``stable`` / ``dev`` / ``2016.1.0r1`` style tags, §3.4).

``push``/``pull`` return transfer stats so tests (and the fig2 benchmark) can
assert the dedupe property: pushing a derived image moves only its new layers.
"""

from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass
from pathlib import Path

from repro.core.image import EnvImage, Layer

_TAG_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._\-/]*$")
_HEX_RE = re.compile(r"^[0-9a-f]{12,64}$")


@dataclass(frozen=True)
class TransferStats:
    """Bytes/objects moved vs reused -- the layer-dedupe receipt."""

    layers_total: int
    layers_transferred: int
    layers_reused: int
    bytes_transferred: int

    @property
    def dedupe_fraction(self) -> float:
        return self.layers_reused / max(1, self.layers_total)


class RegistryError(KeyError):
    pass


class Registry:
    def __init__(self, root: str | os.PathLike):
        self.root = Path(root)
        for sub in ("layers", "images", "tags"):
            (self.root / sub).mkdir(parents=True, exist_ok=True)

    # -- push ------------------------------------------------------------
    def push(self, image: EnvImage, tag: str | None = None) -> TransferStats:
        transferred = reused = nbytes = 0
        for layer in image.layers:
            p = self.root / "layers" / layer.digest
            if p.exists():
                reused += 1
            else:
                blob = layer.to_json()
                _atomic_write(p, blob)
                transferred += 1
                nbytes += len(blob)
        manifest = json.dumps([l.digest for l in image.layers])
        mp = self.root / "images" / image.digest
        if not mp.exists():
            _atomic_write(mp, manifest)
            nbytes += len(manifest)
        if tag is not None:
            self.tag(image.digest, tag)
        return TransferStats(len(image.layers), transferred, reused, nbytes)

    # -- pull ------------------------------------------------------------
    def pull(self, ref: str) -> EnvImage:
        digest = self.resolve(ref)
        mp = self.root / "images" / digest
        if not mp.exists():
            raise RegistryError(f"image {ref!r} ({digest[:12]}) not in registry")
        layer_digests = json.loads(mp.read_text())
        layers = []
        for ld in layer_digests:
            lp = self.root / "layers" / ld
            if not lp.exists():
                raise RegistryError(f"corrupt registry: missing layer {ld[:12]}")
            layer = Layer.from_json(lp.read_text())
            if layer.digest != ld:
                raise RegistryError(f"content-hash mismatch for layer {ld[:12]}")
            layers.append(layer)
        image = EnvImage(tuple(layers))
        if image.digest != digest:
            raise RegistryError(f"content-hash mismatch for image {digest[:12]}")
        return image

    # -- tags --------------------------------------------------------------
    def tag(self, digest_or_ref: str, tag: str) -> None:
        if not _TAG_RE.match(tag):
            raise ValueError(f"bad tag {tag!r}")
        digest = self.resolve(digest_or_ref)
        _atomic_write(self.root / "tags" / tag.replace("/", "__"), digest)

    def resolve(self, ref: str) -> str:
        """tag | full digest | unique digest prefix -> full digest."""
        tp = self.root / "tags" / ref.replace("/", "__")
        if tp.exists():
            return tp.read_text().strip()
        if _HEX_RE.match(ref):
            hits = [p.name for p in (self.root / "images").iterdir() if p.name.startswith(ref)]
            if len(hits) == 1:
                return hits[0]
            if len(hits) > 1:
                raise RegistryError(f"ambiguous digest prefix {ref!r}")
        raise RegistryError(f"unknown ref {ref!r}")

    def tags(self) -> dict[str, str]:
        return {
            p.name.replace("__", "/"): p.read_text().strip()
            for p in (self.root / "tags").iterdir()
        }

    def images(self) -> list[str]:
        return sorted(p.name for p in (self.root / "images").iterdir())

    def layer_count(self) -> int:
        return sum(1 for _ in (self.root / "layers").iterdir())


def _atomic_write(path: Path, text: str) -> None:
    tmp = path.with_suffix(".tmp")
    tmp.write_text(text)
    os.replace(tmp, path)
