"""The paper's primary contribution: container-style environment runtime.

EnvImage/Imagefile/Registry  -- layered content-addressed images (paper §2)
Container/Runtime            -- runtime instantiation + writable overlay (§3)
CollectiveABI                -- swappable generic/host collectives (§3.3/§4.2)
CompileCache                 -- the Python-import-problem fix (§4.2/Fig.4)

Lazy attribute resolution keeps submodules (train <-> core.abi) cycle-free.
"""

_EXPORTS = {
    "CollectiveABI": "repro.core.abi",
    "abi_from_image_config": "repro.core.abi",
    "make_abi": "repro.core.abi",
    "CompileCache": "repro.core.compile_cache",
    "Container": "repro.core.container",
    "EnvImage": "repro.core.image",
    "ImageBuilder": "repro.core.image",
    "Layer": "repro.core.image",
    "parse_imagefile": "repro.core.imagefile",
    "render_imagefile": "repro.core.imagefile",
    "Registry": "repro.core.registry",
    "TransferStats": "repro.core.registry",
    "Runtime": "repro.core.runtime",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib

        mod = importlib.import_module(_EXPORTS[name])
        return getattr(mod, name)
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
