"""Collective ABI: runtime-swappable collective strategies (paper §3.3, §4.2).

The paper's key HPC result (their Fig. 3): an image ships a *generic* MPICH;
at run time the host's *ABI-compatible, vendor-optimized* Cray MPI is linked
in via ``LD_LIBRARY_PATH`` -- no rebuild, no source change -- and performance
matches native, while the generic library collapses across node boundaries.

TPU adaptation: on TPU the collective implementation is chosen at *trace /
compile* time by XLA, not at dynamic-link time. So the ABI here is a stable
*strategy interface* consumed by the train/serve step builders; images select
an implementation by name (``COLLECTIVES generic`` / ``COLLECTIVES host``)
and the binding happens when the Container traces the step -- still with zero
model-code change, which is the property the paper actually cares about.

Implementations:

``generic``  -- the "container MPICH": flat fp32 all-reduce of gradients,
                replicated optimizer states, single-level collectives, no
                pod-topology awareness. Correct everywhere, slow at scale.

``host``     -- the "Cray MPI": the vendor-tuned path.
                * ZeRO-1: optimizer states sharded over the batch axes, so the
                  partitioner emits reduce-scatter(grads) + all-gather(params)
                  instead of all-reduce (halves gradient-sync bytes, overlaps
                  with optimizer compute);
                * gradient compression: cross-replica sums run in bfloat16
                  (2x fewer bytes on the wire), params updated in fp32;
                * hierarchical collectives: on multi-pod meshes, reduce within
                  a pod over fast ICI first, then across pods over the slower
                  inter-pod links (explicit two-level psum in the shard_map
                  path) -- the topology-aware trick every vendor MPI does.

``host mode=explicit`` additionally accepts ``compression=powersgd rank=R``:
rank-R PowerSGD gradient compression with per-replica error feedback
(train/compression.py) -- wire per tensor drops from m*n to R(m+n) floats
(e.g. 1500x on a deepseek MLP gradient at R=16). Beyond-paper, but expressed
entirely through this layer: the paper's swap-the-library contract holds.

Both implement the same CollectiveABI interface: swapping them NEVER changes
model code or the image's arch/shape layers, only the ``collectives`` layer.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CollectiveABI:
    """Stable interface contract between step builders and collective impls.

    Fields are consumed in two places:
      * implicit (pjit) path: ``zero1`` decides optimizer-state shardings so
        XLA's SPMD partitioner emits RS+AG instead of AR;
      * explicit (shard_map) path: ``grad_sync`` is called with per-device
        gradient shards and performs the cross-replica reduction itself.
    """

    name: str
    zero1: bool = False
    grad_dtype: str = "float32"       # wire dtype for gradient sums
    hierarchical: bool = False        # two-level (pod-aware) reductions
    error_feedback: bool = False      # residual feedback for lossy compression
    options: dict = field(default_factory=dict)

    # ---- explicit path ---------------------------------------------------
    def grad_sync(self, grads, batch_axes: Sequence[str]):
        """Cross-replica mean of gradient pytree over ``batch_axes``.

        Called inside shard_map. ``batch_axes`` is ordered fast-to-slow,
        e.g. ("data",) single-pod or ("data", "pod") multi-pod.
        """
        wire = jnp.dtype(self.grad_dtype)

        def sync(g):
            orig = g.dtype
            g = g.astype(wire)
            if self.hierarchical and len(batch_axes) > 1:
                # vendor-MPI trick: reduce over fast intra-pod ICI first,
                # then over the slow inter-pod links with already-reduced data.
                for ax in batch_axes:
                    g = jax.lax.pmean(g, ax)
            else:
                g = jax.lax.pmean(g, tuple(batch_axes))
            return g.astype(orig)

        return jax.tree.map(sync, grads)

    # ---- implicit path hints ----------------------------------------------
    def opt_state_batch_spec(self, batch_axes: Sequence[str]):
        """Mesh axes over which 1st-moment/2nd-moment/master params shard.

        ZeRO-1: shard over all batch axes. Generic: replicate (None).
        """
        return tuple(batch_axes) if self.zero1 else None

    def describe(self) -> str:
        bits = [self.name]
        if self.zero1:
            bits.append("zero1(RS+AG)")
        if self.grad_dtype != "float32":
            bits.append(f"wire={self.grad_dtype}")
        if self.hierarchical:
            bits.append("hierarchical")
        return "+".join(bits)


# ---------------------------------------------------------------------------
# The two shipped implementations + a registry so images select by name.
# ---------------------------------------------------------------------------

def make_abi(name: str, **options: Any) -> CollectiveABI:
    if name == "generic":
        # container MPICH: nothing clever, correct everywhere.
        return CollectiveABI(name="generic", options=options)
    if name == "host":
        # Cray MPI: every vendor trick on by default; image options can
        # switch individual tricks off (e.g. grad_compression=float32).
        return CollectiveABI(
            name="host",
            zero1=bool(options.pop("zero1", True)),
            grad_dtype=str(options.pop("grad_compression", "bfloat16")),
            hierarchical=bool(options.pop("hierarchical", True)),
            error_feedback=bool(options.pop("error_feedback", False)),
            options=options,
        )
    raise ValueError(f"unknown collective ABI {name!r} (have: generic, host)")


def abi_from_image_config(cfg: dict) -> CollectiveABI:
    c = dict(cfg.get("collectives") or {"name": "generic"})
    return make_abi(c.pop("name"), **c)
