"""SLO-aware serving: priority lanes, admission deadlines, router
shedding, and page-level preemption.

The QoS acceptance bars, end-to-end: interactive traffic admits ahead of
batch (strict priority, FIFO within a class), a batch head past its
admission deadline is shed -- not served uselessly late, the router sheds
batch submissions when every fitting pod is over the overload threshold
(interactive is never shed), and an interactive arrival blocked by a full
slot bank / page pool preempts the youngest running batch request --
whose resume via suffix re-prefill continues the generation bitwise
(token parity with a pressure-free run, pool invariants intact after
every tick, zero requests lost).
"""

import numpy as np
import pytest

from repro.core.runtime import Runtime
from repro.orchestrator import (ContinuousScheduler, GenRequest, Pod,
                                PodRouter, RequestQueue)

pytestmark = pytest.mark.orchestrator

IMAGEFILE = """
FROM scratch
ARCH llama3.2-3b-smoke
SHAPE decode_32k seq_len=64 global_batch=4
MESH local
PRECISION compute=float32 params=float32
COLLECTIVES generic
"""

PS = 8                              # page size used throughout


@pytest.fixture(scope="module")
def rt(tmp_path_factory):
    rt = Runtime(tmp_path_factory.mktemp("stevedore"))
    rt.build(IMAGEFILE, tag="stable")
    return rt


def _req(rid, plen=8, gen=4, **kw):
    rng = np.random.default_rng(rid + 1)
    return GenRequest(rid=rid, prompt=rng.integers(0, 256, plen),
                      max_new_tokens=gen, **kw)


# ---------------------------------------------------------------------------
# priority lanes (pure queue -- no pod)
# ---------------------------------------------------------------------------

def test_lanes_strict_priority_fifo_within_class():
    q = RequestQueue()
    b0, b1 = _req(0, priority="batch"), _req(1, priority="batch")
    i0, i1 = _req(2), _req(3)           # interactive is the default
    for r in (b0, b1, i0, i1):
        q.submit(r)
    assert len(q) == 4
    assert q.pending_by_class() == {"interactive": 2, "batch": 2}
    # arrived interactive heads drain first; FIFO within each class
    order = [q.pop_ready(0).rid for _ in range(4)]
    assert order == [i0.rid, i1.rid, b0.rid, b1.rid]
    assert q.pop_ready(0) is None


def test_lane_arrival_blocks_only_its_own_lane():
    q = RequestQueue()
    late_i = _req(0, arrival=5)
    early_b = _req(1, priority="batch", arrival=0)
    q.submit(late_i)
    q.submit(early_b)
    # the interactive head has not arrived: it must NOT stall batch work
    assert q.peek_ready(0) is early_b
    assert q.pop_ready(0) is early_b
    assert not q.has_ready(0)
    # once arrived, interactive resumes priority
    assert q.pop_ready(5) is late_i


def test_requeue_front_of_lane_and_preempted_only():
    q = RequestQueue()
    b0, b1 = _req(0, priority="batch"), _req(1, priority="batch")
    q.submit(b0)
    q.submit(b1)
    victim = q.pop_ready(0)
    with pytest.raises(ValueError, match="only preempted"):
        q.requeue(victim)               # state is still "queued"
    victim.state = "preempted"
    q.requeue(victim)
    # a preempted request resumes BEFORE everything queued in its class
    assert q.pop_ready(0) is victim
    assert q.pop_ready(0) is b1


def test_qos_field_validation():
    with pytest.raises(ValueError, match="priority"):
        _req(0, priority="bulk")
    with pytest.raises(ValueError, match="deadline_ticks"):
        _req(0, deadline_ticks=-1)
    r = _req(0, priority="batch", deadline_ticks=0)
    assert r.priority == "batch" and r.deadline_ticks == 0


# ---------------------------------------------------------------------------
# admission deadline (scheduler tier)
# ---------------------------------------------------------------------------

def test_deadline_miss_sheds_at_admission(rt):
    from repro.orchestrator.obs import (completion_snapshot,
                                        recompute_registry)
    pod = Pod(rt, "stable", replicas=1, n_slots=1, max_len=64)
    sched = ContinuousScheduler(pod)
    hog = _req(0, gen=12)                               # occupies the slot
    doomed = _req(1, priority="batch", deadline_ticks=2)
    ok = _req(2, priority="batch")                      # no deadline: waits
    sched.submit([hog, doomed, ok])
    sched.run(max_ticks=2000)
    assert hog.state == "done" and ok.state == "done"
    assert doomed.state == "shed"
    assert doomed.finish_reason == "deadline"
    assert "deadline" in doomed.error
    assert doomed.done_tick > 2
    assert sched.shedded == [doomed]
    assert pod.shed == 1
    assert sched.metrics.total("requests_shed") == 1
    spans = [e.name for e in pod.trace.events() if e.rid == doomed.rid]
    assert spans == ["submit", "shed"]
    # the shed is a first-class lifecycle outcome: the span-log recompute
    # counts it exactly like the live registry (bitwise snapshot match)
    rec = recompute_registry([pod.trace])
    assert (completion_snapshot(rec.snapshot())
            == completion_snapshot(sched.metrics.snapshot()))


# ---------------------------------------------------------------------------
# router overload shedding
# ---------------------------------------------------------------------------

def test_router_sheds_batch_when_every_fitting_pod_overloaded(rt):
    pod = Pod(rt, "stable", replicas=1, n_slots=2, max_len=64)
    router = PodRouter([pod], shed_queue_depth=2)
    backlog = [_req(i, gen=8) for i in range(3)]
    router.submit(backlog)              # queue_depth gauge now 3 >= 2
    shed_req = _req(3, priority="batch")
    keep_req = _req(4)                  # interactive is NEVER shed
    router.submit([shed_req, keep_req])
    assert shed_req.state == "shed"
    assert shed_req.finish_reason == "shed"
    assert "overloaded" in shed_req.error
    assert router.shedded == [shed_req] and router.shed_total == 1
    assert keep_req.state == "queued"
    router.run(max_ticks=2000)
    assert all(r.state == "done" for r in backlog + [keep_req])
    st = router.status()
    assert st["shed"] == 1
    assert st["by_policy"][router.policy]["shed"] == 1
    shed_spans = [e for e in router.trace.events() if e.rid == shed_req.rid
                  and e.name == "shed"]
    assert len(shed_spans) == 1
    assert shed_spans[0].attr("reason") == "overload"
    # once the backlog drains the gauge drops: batch traffic flows again
    late = _req(5, priority="batch")
    router.submit(late)
    router.run(max_ticks=2000)
    assert late.state == "done"


def test_router_spills_batch_to_non_overloaded_pod_before_shedding(rt):
    pods = [Pod(rt, "stable", replicas=1, n_slots=2, max_len=64)
            for _ in range(2)]
    router = PodRouter(pods, shed_queue_depth=2)
    # load ONLY the shortest-queue-preferred pod over the threshold
    backlog = [_req(i, gen=10) for i in range(3)]
    first = router.place(backlog[0])
    for r in backlog:
        r.pod = None
    loaded = router.scheduler_for(first)
    loaded.submit(backlog)              # direct: all 3 on one pod's queue
    batch = _req(7, priority="batch")
    router.submit(batch)
    # overload-spill before shed: the other pod is under threshold
    assert batch.state == "queued"
    other = next(p for p in pods if p is not first)
    assert batch.pod == other.pod_id
    assert router.shed_total == 0


def test_overloaded_reads_ttft_p99_from_live_registry(rt):
    pod = Pod(rt, "stable", replicas=1, n_slots=2, max_len=64)
    router = PodRouter([pod], shed_ttft_p99=10)
    assert not router.overloaded(pod)   # no samples yet: never overloaded
    from repro.orchestrator.obs.report import TICK_HIST
    # test harness injects a fake overload sample directly; production
    # writes stay routed through observe_completion
    pod.metrics.histogram("ttft_ticks", **TICK_HIST).record(25)  # repro: lint-ok[metrics-writer]
    assert router.overloaded(pod)
    assert not PodRouter([pod]).overloaded(pod)     # thresholds off


# ---------------------------------------------------------------------------
# page-level preemption: pressure sweep, parity, invariants, zero loss
# ---------------------------------------------------------------------------

def _mixed_trace():
    """Two long batch requests that saturate a 2-slot paged engine, then
    interactive arrivals that can only admit by preempting one."""
    reqs = [_req(0, gen=40, priority="batch"),
            _req(1, gen=40, priority="batch")]
    for k, tick in enumerate((4, 8, 12)):
        reqs.append(_req(2 + k, gen=3, arrival=tick))
    return reqs


def test_preemption_parity_invariants_and_zero_loss(rt):
    # tight pod: 2 slots, pool sized for exactly 2 in-flight spans, so an
    # arrived interactive head finds neither a free slot nor free pages
    span_pages = -(-(8 + 40 + 4) // PS)             # prompt+gen+chunk
    tight = Pod(rt, "stable", replicas=1, n_slots=2, max_len=64,
                paged=True, page_size=PS, n_pages=2 * span_pages + 1,
                decode_chunk=4)
    sched = ContinuousScheduler(tight)
    reqs = _mixed_trace()
    sched.submit(reqs)
    while sched.busy:
        sched.step()
        for e in tight.engines:
            e.pool.check()              # pool invariants after EVERY tick
        assert sched.tick < 2000
    eng = tight.engines[0]
    assert eng.preemptions >= 1         # pressure actually forced a pause
    assert eng.preemptions == eng.resumes       # every victim came back
    # zero lost: every request reached a terminal completed state
    assert all(r.state == "done" for r in reqs)
    assert all(r.finish_reason in ("eos", "length") for r in reqs)
    victims = [r for r in reqs if r.preemptions]
    assert victims and all(r.priority == "batch" for r in victims)
    # preempt/resume span bracketing per victim, and the TTFT anchor
    # (admit span) recorded exactly once -- resumes never re-admit
    by_rid = tight.trace.by_request()
    for r in victims:
        names = [e.name for e in by_rid[r.rid]]
        assert names.count("preempt") == names.count("resume") \
            == r.preemptions
        assert names.count("admit") == 1
        assert names.index("preempt") < names.index("resume")
    assert eng.pool.status()["paused_slots"] == 0   # nothing left paused

    # parity: the same trace on a roomy pod (no pressure, no preemption)
    # produces bitwise-identical tokens request-for-request
    roomy = Pod(rt, "stable", replicas=1, n_slots=8, max_len=64,
                paged=True, page_size=PS, n_pages=8 * span_pages + 1,
                decode_chunk=4)
    ref_sched = ContinuousScheduler(roomy)
    ref = _mixed_trace()
    ref_sched.submit(ref)
    ref_sched.run(max_ticks=2000)
    assert all(e.preemptions == 0 for e in roomy.engines)
    assert {r.rid: list(r.tokens) for r in reqs} \
        == {r.rid: list(r.tokens) for r in ref}


def test_interactive_head_never_preempts_interactive(rt):
    # same pressure, but the running work is interactive too: strict QoS
    # means the head WAITS (no same-class preemption, FIFO preserved)
    span_pages = -(-(8 + 40 + 4) // PS)
    pod = Pod(rt, "stable", replicas=1, n_slots=2, max_len=64,
              paged=True, page_size=PS, n_pages=2 * span_pages + 1,
              decode_chunk=4)
    sched = ContinuousScheduler(pod)
    reqs = [_req(0, gen=40), _req(1, gen=40), _req(2, gen=3, arrival=4)]
    sched.submit(reqs)
    sched.run(max_ticks=2000)
    assert all(r.state == "done" for r in reqs)
    assert pod.engines[0].preemptions == 0
    assert sched.admission_order == [0, 1, 2]


def test_preempted_request_resumes_across_engines(rt):
    # the resume is a plain admission: any fitting engine may take it,
    # including a different replica than the one that paused it
    span_pages = -(-(8 + 40 + 4) // PS)
    pod = Pod(rt, "stable", replicas=2, n_slots=1, max_len=64,
              paged=True, page_size=PS, n_pages=span_pages + 1, decode_chunk=4)
    sched = ContinuousScheduler(pod)
    reqs = [_req(0, gen=40, priority="batch"),
            _req(1, gen=40, priority="batch"),
            _req(2, gen=3, arrival=4)]
    sched.submit(reqs)
    sched.run(max_ticks=2000)
    assert all(r.state == "done" for r in reqs)
    assert sum(e.preemptions for e in pod.engines) >= 1
    for e in pod.engines:
        e.pool.check()
