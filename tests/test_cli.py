"""The stevedore CLI (docker-shaped wrapper, paper §3.2)."""

import json

import pytest

from repro.cli import main

IMAGEFILE = """
FROM scratch
ARCH llama3.2-3b-smoke
SHAPE train_4k seq_len=16 global_batch=4
MESH local
PRECISION params=float32 compute=float32
COLLECTIVES generic
SET optimizer={"lr":0.01,"warmup_steps":1,"total_steps":50}
"""


def test_cli_build_images_history_tag_ps_run(tmp_path, capsys):
    f = tmp_path / "Imagefile"
    f.write_text(IMAGEFILE)
    root = str(tmp_path / "rt")

    assert main(["--root", root, "build", "-t", "stable", str(f)]) == 0
    out = capsys.readouterr().out
    assert "built" in out and "arch" in out

    assert main(["--root", root, "images"]) == 0
    assert "stable" in capsys.readouterr().out

    assert main(["--root", root, "history", "stable"]) == 0
    assert "collectives" in capsys.readouterr().out

    assert main(["--root", root, "inspect", "stable"]) == 0
    cfg = json.loads(capsys.readouterr().out)
    assert cfg["arch"]["name"] == "llama3.2-3b-smoke"

    assert main(["--root", root, "tag", "stable", "prod"]) == 0
    capsys.readouterr()

    assert main(["--root", root, "run", "prod", "--steps", "2"]) == 0
    out = capsys.readouterr().out
    assert "loss=" in out

    assert main(["--root", root, "ps"]) == 0
    assert "llama3.2-3b-smoke" in capsys.readouterr().out


def test_cli_resume_continues(tmp_path, capsys):
    f = tmp_path / "Imagefile"
    f.write_text(IMAGEFILE)
    root = str(tmp_path / "rt")
    main(["--root", root, "build", "-t", "s", str(f)])
    main(["--root", root, "run", "s", "--steps", "2"])
    capsys.readouterr()
    # resume uses the latest overlay checkpoint... each run makes a new
    # container; resume within the same overlay is exercised by the
    # launch/train tests -- here we just assert a fresh run also works
    assert main(["--root", root, "run", "s", "--steps", "1"]) == 0
    assert "loss=" in capsys.readouterr().out
