"""Copy-on-write prefix page cache through the paged serving stack.

The serving analogue of the paper's shared immutable image layers: requests
declaring the same leading token block (a fleet system prompt) share its KV
pages copy-on-write instead of re-prefilling them. These tests pin the
acceptance bars end-to-end: tokens are identical with the cache on vs off
(the suffix prefill with offset positions changes nothing observable), hits
skip exactly the shared positions, a digest collision over different tokens
misses (full-block compare), the whole-prompt edge keeps one real suffix
token, and the warm cache survives request completion.
"""

import io
from contextlib import redirect_stdout
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.runtime import Runtime
from repro.orchestrator import ContinuousScheduler, GenRequest, Pod

pytestmark = pytest.mark.orchestrator

IMAGEFILE = """
FROM scratch
ARCH llama3.2-3b-smoke
SHAPE decode_32k seq_len=64 global_batch=4
MESH local
PRECISION compute=float32 params=float32
COLLECTIVES generic
"""

PS = 8                       # page size used throughout
SHARED = 20                  # system-prompt tokens: 2 whole pages + remainder


@pytest.fixture(scope="module")
def rt(tmp_path_factory):
    rt = Runtime(tmp_path_factory.mktemp("stevedore"))
    rt.build(IMAGEFILE, tag="stable")
    return rt


def _pod(rt, prefix_cache, **kw):
    kw.setdefault("n_slots", 3)
    kw.setdefault("max_len", 64)
    return Pod(rt, "stable", replicas=1, paged=True, page_size=PS,
               prefix_cache=prefix_cache, **kw)


def _shared_trace(n=6, seed=1):
    rng = np.random.default_rng(seed)
    shared = np.random.default_rng(99).integers(0, 256, SHARED)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, 256, int(rng.integers(3, 10)))
        reqs.append(GenRequest(rid=i, prompt=np.concatenate([shared, tail]),
                               max_new_tokens=int(rng.integers(2, 6)),
                               prefix_len=SHARED))
    return reqs


def _run(pod, reqs, max_ticks=2000):
    sched = ContinuousScheduler(pod)
    sched.submit(reqs)
    sched.run(max_ticks=max_ticks)
    assert all(r.state == "done" for r in reqs), [r.state for r in reqs]
    return sched


# ---------------------------------------------------------------------------
# parity + hit accounting
# ---------------------------------------------------------------------------

def test_cache_on_off_token_parity_with_hits(rt):
    """The acceptance bar: bitwise-identical request tokens with the cache
    on vs off, with real hits (suffix-only prefill) on the cached run."""
    results = {}
    for cache in (False, True):
        pod = _pod(rt, cache)
        reqs = _shared_trace()
        _run(pod, reqs)
        eng = pod.engines[0]
        eng.pool.check()
        results[cache] = [list(r.tokens) for r in reqs]
        if cache:
            # every request after the first (miss, promotes) hits
            assert eng.prefix_hits == len(reqs) - 1
            assert eng.prefix_misses == 1
            # each hit skipped the whole-page floor of the shared block
            assert eng.prefix_tokens_saved == \
                (len(reqs) - 1) * (SHARED // PS) * PS
            # only the cached prefix pages stay resident after the trace
            assert eng.pool.in_use == eng.pool.cached_pages == SHARED // PS
        else:
            assert eng.prefix_hits == eng.prefix_misses == 0
            assert eng.pool.in_use == 0
        assert sorted(eng.free) == list(range(eng.n_slots))
    assert results[False] == results[True]


def test_hits_skip_prefill_positions(rt):
    """prefill_positions counts only what was actually computed: the cached
    run computes SHARED fewer positions per hit than the cold run."""
    counts = {}
    for cache in (False, True):
        pod = _pod(rt, cache)
        reqs = _shared_trace()
        _run(pod, reqs)
        counts[cache] = pod.engines[0].prefill_positions
    total = sum(r.prompt_len for r in _shared_trace())
    assert counts[False] == total
    saved = (len(_shared_trace()) - 1) * (SHARED // PS) * PS
    assert counts[True] == total - saved


def test_warm_cache_survives_completion_and_rehits(rt):
    """Refcount-0 cached pages stay resident after every sharer exits: a
    request arriving later still hits the warm entry."""
    pod = _pod(rt, True)
    first = _shared_trace(n=1)
    _run(pod, first)
    eng = pod.engines[0]
    assert eng.prefix_misses == 1 and eng.prefix_hits == 0
    assert eng.pool.in_use == eng.pool.cached_pages        # warm, refs 0
    late = _shared_trace(n=2, seed=7)
    _run(pod, late)
    assert eng.prefix_hits == 2
    eng.pool.check()


def test_replica_prefix_affinity_within_pod(rt):
    """With two replicas (two pools), admission prefers the engine whose
    pool already caches the request's prefix over plain least-loaded."""
    pod = Pod(rt, "stable", replicas=2, n_slots=2, max_len=64, paged=True,
              page_size=PS, prefix_cache=True)
    sched = ContinuousScheduler(pod)
    reqs = _shared_trace(n=3)
    sched.submit(reqs[0])
    sched.run(max_ticks=500)
    sched.submit(reqs[1:])
    sched.run(max_ticks=500)
    assert len({r.replica for r in reqs}) == 1, \
        "prefix hits were scattered across replica pools"
    hits = sum(e.prefix_hits for e in pod.engines)
    assert hits == 2


# ---------------------------------------------------------------------------
# adversarial edges
# ---------------------------------------------------------------------------

def test_digest_collision_at_engine_misses_and_stays_correct(rt, monkeypatch):
    """Two requests forced onto the SAME chained digest with different
    blocks: the second must miss (the radix walk byte-compares the full
    block, never trusts the digest) and decode exactly the tokens an
    uncached engine produces for its prompt."""
    from repro.orchestrator import prefix_registry
    monkeypatch.setattr(prefix_registry, "chained_digest",
                        lambda parent, block: f"{parent}|X")
    rng = np.random.default_rng(11)
    block_a = rng.integers(0, 256, 16)
    block_b = rng.integers(0, 256, 16)
    assert not np.array_equal(block_a, block_b)
    tail = rng.integers(0, 256, 5)
    r1 = GenRequest(rid=0, prompt=np.concatenate([block_a, tail]),
                    max_new_tokens=4, prefix_len=16)
    r2 = GenRequest(rid=1, prompt=np.concatenate([block_b, tail]),
                    max_new_tokens=4, prefix_len=16)

    pod = _pod(rt, True)
    _run(pod, [r1])
    _run(pod, [r2])
    eng = pod.engines[0]
    assert eng.prefix_hits == 0 and eng.prefix_misses == 2
    # first-writer-wins: r2's colliding promotion must not replace or
    # corrupt r1's registered blocks
    assert eng.pool.radix.node_count == 2
    eng.pool.check()

    ref = GenRequest(rid=2, prompt=np.concatenate([block_b, tail]),
                     max_new_tokens=4)
    _run(_pod(rt, False), [ref])
    assert list(r2.tokens) == list(ref.tokens), \
        "collision served another block's prefix pages"


def test_whole_prompt_equals_prefix_keeps_one_suffix_token(rt):
    """prompt == declared block (page-aligned): the hit caps its share so
    at least one real token remains to prefill (the position the first
    sampled token comes from), and tokens still match the uncached run."""
    block = np.random.default_rng(13).integers(0, 256, 2 * PS)
    mk = lambda rid: GenRequest(rid=rid, prompt=block.copy(),
                                max_new_tokens=4, prefix_len=2 * PS)
    pod = _pod(rt, True)
    _run(pod, [mk(0)])
    hit_req = mk(1)
    _run(pod, [hit_req])
    eng = pod.engines[0]
    assert eng.prefix_hits == 1
    # shared only the first page: the second holds the last real token
    assert eng.prefix_tokens_saved == PS
    ref = mk(2)
    _run(_pod(rt, False), [ref])
    assert list(hit_req.tokens) == list(ref.tokens)


def test_promotion_never_caches_unreachable_pages(rt):
    """Every page a MISS promotes into the index must be reachable by a
    matching lookup. Promotion used to cache ``prefix_len // page_size``
    pages while lookups cap at ``min(prefix_len, P-1) // page_size``: a
    page-aligned whole-prompt block cached one page no hit could ever
    share -- pinned in the index until eviction, a pure leak."""
    block = np.random.default_rng(17).integers(0, 256, 2 * PS)
    mk = lambda rid: GenRequest(rid=rid, prompt=block.copy(),
                                max_new_tokens=2, prefix_len=2 * PS)
    pod = _pod(rt, True)
    _run(pod, [mk(0)])
    eng = pod.engines[0]
    pool = eng.pool
    assert pool.radix.node_count == 1
    hit = eng.prefix_hit(mk(1))
    assert hit is not None
    # the lookup reaches EVERY registered node: nothing promoted beyond
    # what min(prefix_len, P-1) allows (no unreachable second page)
    assert len(hit.nodes) == 1 and hit.partial is None
    assert pool.cached_pages == 1
    pool.check()


def test_sub_page_prefix_never_caches(rt):
    """A declared block smaller than one page has no whole page to share:
    no promotion, no hit, correct tokens."""
    rng = np.random.default_rng(17)
    block = rng.integers(0, 256, PS - 1)
    reqs = [GenRequest(rid=i,
                       prompt=np.concatenate([block,
                                              rng.integers(0, 256, 4)]),
                       max_new_tokens=3, prefix_len=PS - 1)
            for i in range(2)]
    pod = _pod(rt, True)
    _run(pod, reqs)
    eng = pod.engines[0]
    assert eng.prefix_hits == 0 and eng.pool.cached_pages == 0
    eng.pool.check()


def test_eviction_under_serving_pressure_keeps_parity(rt):
    """A pool too small to keep every prefix resident evicts cold entries
    mid-trace; requests still complete with the exact uncached tokens."""
    rng = np.random.default_rng(19)
    blocks = [rng.integers(0, 256, 2 * PS) for _ in range(3)]

    def trace():
        out = []
        for i in range(9):
            blk = blocks[i % 3]
            tail = np.random.default_rng(100 + i).integers(0, 256, 4)
            out.append(GenRequest(rid=i, prompt=np.concatenate([blk, tail]),
                                  max_new_tokens=3, prefix_len=2 * PS))
        return out

    results = {}
    for cache in (False, True):
        # tight pool: ~enough for 2 in-flight requests + 2 cached prefixes
        pod = Pod(rt, "stable", replicas=1, n_slots=2, max_len=64,
                  paged=True, page_size=PS, n_pages=13, prefix_cache=cache)
        reqs = trace()
        _run(pod, reqs, max_ticks=5000)
        pod.engines[0].pool.check()
        results[cache] = [list(r.tokens) for r in reqs]
    assert results[False] == results[True]


def test_chunked_attend_honors_suffix_position_offset():
    """The flash-style chunked softmax skips fully-causal KV chunks at
    trace time assuming 0-based q positions; the suffix prefill's queries
    start at the prefix length instead. With the offset threaded through
    (attend(q_offset=)) the chunked path matches the dense one; ignoring
    it (the would-be bug) silently drops every prefix chunk past the
    0-based horizon."""
    import math
    from repro.models.attention import _sdpa_chunked, _sdpa_dense
    rng = np.random.default_rng(23)
    B, Hkv, G, hd = 1, 2, 2, 16
    L, S = 96, 8                                  # prefix, suffix
    scale = 1.0 / math.sqrt(hd)
    q = rng.standard_normal((B, S, Hkv, G, hd)).astype(np.float32)
    k = rng.standard_normal((B, L + S, Hkv, hd)).astype(np.float32)
    v = rng.standard_normal((B, L + S, Hkv, hd)).astype(np.float32)
    q_pos = (L + np.arange(S))[None, :]
    k_pos = np.arange(L + S)[None, :]
    dense = np.asarray(_sdpa_dense(q, k, v, q_pos, k_pos, 0, scale))
    good = np.asarray(_sdpa_chunked(
        q, k, v, q_pos, k_pos, 0, scale,
        q_chunk=16, kv_chunk=32, q_offset=L))
    np.testing.assert_allclose(good, dense, atol=3e-5)
    wrong = np.asarray(_sdpa_chunked(
        q, k, v, q_pos, k_pos, 0, scale,
        q_chunk=16, kv_chunk=32, q_offset=0))
    assert not np.allclose(wrong, dense), \
        "0-based skipping should have dropped visible prefix chunks"


# ---------------------------------------------------------------------------
# driver-level parity (serve --prefix-cache)
# ---------------------------------------------------------------------------

def _serve_args(**kw):
    args = SimpleNamespace(slots=3, prompt_len=8, gen=6, requests=6, seed=0,
                           platform=None, replicas=1, fairness_cap=4,
                           arrive_per_tick=8, paged=True, page_size=8,
                           prefix_cache=False, shared_prefix=16, pods=1,
                           policy="shortest-queue")
    for k, v in kw.items():
        setattr(args, k, v)
    return args


def test_cli_serve_prefix_cache_forwards_page_size(rt, capsys):
    """Regression: `repro serve --prefix-cache --page-size N` without an
    explicit --paged must still forward the page size (prefix-cache
    implies paged downstream); `ps` then shows the hit counters and the
    page-granular shared count."""
    from repro.cli import main as cli_main
    root = str(rt.root)
    assert cli_main(["--root", root, "serve", "stable", "--replicas", "1",
                     "--slots", "3", "--requests", "4", "--prompt-len", "6",
                     "--gen", "3", "--prefix-cache", "--shared-prefix", "16",
                     "--page-size", "8"]) == 0
    out = capsys.readouterr().out
    assert "prefix cache: 3 hits (0 ancestor, 0 partial) / 1 misses" in out
    # 16-token block at page size 8 = 2 whole pages (16 positions) per hit
    assert "48 prefill tokens skipped" in out
    assert cli_main(["--root", root, "ps"]) == 0
    ps = capsys.readouterr().out
    assert "phits=3/1 shared=2" in ps
    # registry stats ride the same line: 2 registered nodes, depth 2,
    # nothing spilled at this pool size
    assert "radix=2n:2d" in ps and "spilled=0" in ps


def test_serve_driver_prefix_cache_parity(rt):
    """`serve --paged --shared-prefix N` with and without --prefix-cache:
    identical request tokens, and the cached run reports hits + saved
    prefill tokens in its output."""
    from repro.launch.serve import serve_continuous
    with redirect_stdout(io.StringIO()):
        cold = serve_continuous(rt, "stable", _serve_args())
        warm = serve_continuous(rt, "stable",
                                _serve_args(prefix_cache=True))
    assert cold["request_tokens"] == warm["request_tokens"]
    assert not cold["prefix_cache"]["enabled"]
    assert warm["prefix_cache"]["enabled"]
    assert warm["prefix_cache"]["hits"] >= 1
    assert warm["prefix_cache"]["tokens_saved"] > 0
    assert warm["prefill_positions"] < cold["prefill_positions"]
