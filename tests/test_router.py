"""Router-tier invariants: FIFO preserved per pod under shortest-queue,
consistent-hash stability across drains, spillover-before-reject, fleet
rolling upgrades at >= N-1 pods of capacity with zero kills, and
continuous-vs-static token parity unchanged when the trace is routed."""

import io
import json
from contextlib import redirect_stdout
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.runtime import Runtime
from repro.orchestrator import (
    GenRequest,
    Pod,
    PodRouter,
    RollingDeployer,
)

pytestmark = pytest.mark.orchestrator

IMAGEFILE = """
FROM scratch
ARCH {arch}
SHAPE decode_32k seq_len=64 global_batch=4
MESH local
PRECISION compute=float32 params=float32
COLLECTIVES generic
"""


@pytest.fixture(scope="module")
def rt(tmp_path_factory):
    rt = Runtime(tmp_path_factory.mktemp("stevedore"))
    for arch in ("llama3.2-3b-smoke", "musicgen-medium-smoke"):
        rt.build(IMAGEFILE.format(arch=arch), tag=arch)
    rt.registry.tag(rt.registry.resolve("llama3.2-3b-smoke"), "stable")
    return rt


def _requests(rng, n, *, base_rid=0, arrive_per_tick=6, max_gen=10):
    return [
        GenRequest(rid=base_rid + i,
                   prompt=rng.integers(0, 256, int(rng.integers(3, 14))),
                   max_new_tokens=int(rng.integers(2, max_gen)),
                   arrival=i // arrive_per_tick)
        for i in range(n)
    ]


def _fleet(rt, n_pods=2, *, policy="shortest-queue", n_slots=2, max_len=56,
           **kw):
    pods = [Pod(rt, "stable", replicas=1, n_slots=n_slots, max_len=max_len)
            for _ in range(n_pods)]
    return PodRouter(pods, policy=policy, **kw)


def _subsequence(sub, full):
    it = iter(full)
    return all(x in it for x in sub)


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------

def test_shortest_queue_fifo_preserved_per_pod(rt):
    """Every pod's admission order is a subsequence of router submission
    order (placement never reorders a pod's share of the trace), and the
    trace spreads across pods."""
    router = _fleet(rt, 2)
    reqs = _requests(np.random.default_rng(0), 18)
    router.submit(reqs)
    done = router.run(max_ticks=5000)
    assert len(done) == 18 and all(r.state == "done" for r in reqs)
    assert all(len(r.tokens) == r.max_new_tokens for r in reqs)
    submitted = [r.rid for r in reqs]
    by_pod = {p.pod_id: s.admission_order
              for p, s in zip(router.pods, router.schedulers)}
    assert all(by_pod.values()), "shortest-queue left a pod idle"
    for order in by_pod.values():
        assert _subsequence(order, submitted)
    # the two pods partition the trace
    assert sorted(x for o in by_pod.values() for x in o) == submitted


def test_shortest_queue_balances_outstanding_work(rt):
    """Load is measured in outstanding TOKENS, not request count: a trace
    whose long budgets correlate with submit order must still split its
    decode work roughly evenly across pods."""
    router = _fleet(rt, 2, n_slots=3)
    # every 2nd request is long -- a count-based metric alternates pods and
    # piles all the long ones onto pod 1
    reqs = [GenRequest(rid=i, prompt=np.arange(1, 6),
                       max_new_tokens=(20 if i % 2 else 2))
            for i in range(12)]
    router.submit(reqs)
    work = {p.pod_id: sum(r.max_new_tokens for r in reqs
                          if r.pod == p.pod_id) for p in router.pods}
    lo, hi = sorted(work.values())
    assert hi - lo <= 20, work       # within one long request of even
    router.run(max_ticks=5000)
    assert all(r.state == "done" for r in reqs)


def test_consistent_hash_stable_under_drain(rt):
    """Draining a pod moves ONLY that pod's keys (to ring successors);
    un-draining brings them home. Other keys never move."""
    router = _fleet(rt, 3, policy="consistent-hash")
    probes = [GenRequest(rid=i, prompt=np.arange(4), max_new_tokens=2)
              for i in range(60)]
    before = {q.rid: router.place(q).pod_id for q in probes}
    assert len(set(before.values())) == 3   # vnodes spread the keyspace
    victim = router.pods[1]
    router.drain_pod(victim)
    during = {q.rid: router.place(q).pod_id for q in probes}
    moved = {r for r in before if before[r] != during[r]}
    assert moved == {r for r in before if before[r] == victim.pod_id}
    assert all(during[r] != victim.pod_id for r in moved)
    router.undrain_pod(victim)
    assert {q.rid: router.place(q).pod_id for q in probes} == before


def test_consistent_hash_serves_and_respects_placement(rt):
    """Routed requests land on the pod place() predicted (session
    affinity), and the fleet completes the trace."""
    router = _fleet(rt, 3, policy="consistent-hash")
    reqs = _requests(np.random.default_rng(1), 15, base_rid=500)
    predicted = {r.rid: router.place(r).pod_id for r in reqs}
    router.submit(reqs)
    assert {r.rid: r.pod for r in reqs} == predicted
    router.run(max_ticks=5000)
    assert all(r.state == "done" for r in reqs)


def test_drained_pod_gets_no_new_traffic(rt):
    router = _fleet(rt, 2)
    router.drain_pod(router.pods[0])
    reqs = _requests(np.random.default_rng(2), 6, base_rid=700)
    router.submit(reqs)
    assert all(r.pod == router.pods[1].pod_id for r in reqs)
    assert router.capacity == router.pods[1].capacity
    router.undrain_pod(router.pods[0])
    router.run(max_ticks=5000)
    assert all(r.state == "done" for r in reqs)


# ---------------------------------------------------------------------------
# spillover / rejection
# ---------------------------------------------------------------------------

def test_spillover_before_reject(rt):
    """A request the preferred pod can NEVER fit re-routes to a pod that
    can -- for both policies -- and is marked spilled."""
    for policy in ("shortest-queue", "consistent-hash"):
        small = Pod(rt, "stable", replicas=1, n_slots=2, max_len=24)
        big = Pod(rt, "stable", replicas=1, n_slots=2, max_len=96)
        router = PodRouter([small, big], policy=policy)
        # long requests: span 20+20+chunk > 24, fits 96. Pod ids are
        # uuid4-random, so a short fixed rid range can (rarely) hash
        # every probe to the big pod under consistent-hash: probe widely
        # (placement-only, cheap), then SERVE just a few of each kind.
        probes = [GenRequest(rid=i, prompt=np.arange(1, 21),
                             max_new_tokens=20) for i in range(64)]
        prefer_small = [r for r in probes
                        if router._candidates(r)[0] is small][:5]
        assert prefer_small, "no probe preferred the small pod"
        longs = prefer_small + [r for r in probes
                                if router._candidates(r)[0] is big][:5]
        router.submit(longs)
        assert all(r.pod == big.pod_id for r in longs)
        assert all(r.spilled for r in prefer_small)
        assert router.spilled >= len(prefer_small)
        router.run(max_ticks=5000)
        assert all(r.state == "done" and len(r.tokens) == 20 for r in longs)


def test_feasible_only_on_draining_pod_waits_not_rejected(rt):
    """A request only the DRAINING pod can ever fit is routed there (last
    resort) instead of being terminally rejected during a transient drain
    -- it waits in that pod's queue and completes."""
    small = Pod(rt, "stable", replicas=1, n_slots=2, max_len=24)
    big = Pod(rt, "stable", replicas=1, n_slots=2, max_len=96)
    router = PodRouter([small, big])
    router.drain_pod(big)
    long = GenRequest(rid=0, prompt=np.arange(1, 21), max_new_tokens=20)
    ok = GenRequest(rid=1, prompt=np.arange(1, 5), max_new_tokens=2)
    router.submit([long, ok])
    assert long.state == "queued" and long.pod == big.pod_id
    assert ok.pod == small.pod_id       # live pods still preferred
    assert router.rejected_total == 0
    router.undrain_pod(big)
    router.run(max_ticks=5000)
    assert long.state == "done" and len(long.tokens) == 20


def test_rejected_only_when_every_pod_agrees(rt):
    """Fleet-wide infeasibility is the ONLY router rejection: the error
    aggregates per-pod reasons and the fleet keeps serving."""
    small = Pod(rt, "stable", replicas=1, n_slots=2, max_len=24)
    big = Pod(rt, "stable", replicas=1, n_slots=2, max_len=56)
    router = PodRouter([small, big])
    huge = GenRequest(rid=0, prompt=np.arange(1, 41), max_new_tokens=40)
    ok = GenRequest(rid=1, prompt=np.arange(1, 7), max_new_tokens=4)
    router.submit([huge, ok])
    assert huge.state == "rejected" and huge.finish_reason == "oversized"
    assert "slot capacity" in huge.error
    assert huge in router.rejected and router.rejected_total == 1
    # submit-time rejections happen BETWEEN ticks: the router state file
    # must reflect them immediately, not after the next slot event
    rec = json.loads(
        (rt.root / "pods" / f"{router.router_id}.json").read_text())
    assert rec["rejected"] == 1
    router.run(max_ticks=1000)
    assert ok.state == "done" and len(ok.tokens) == 4


# ---------------------------------------------------------------------------
# fleet rolling upgrade
# ---------------------------------------------------------------------------

def test_fleet_upgrade_n_minus_1_capacity_zero_kills(rt):
    """Pod-by-pod roll: capacity never below N-1 pods, nothing killed,
    non-rolling pods keep completing work, every replica lands on the new
    digest, and the same router keeps serving afterwards."""
    pods = [Pod(rt, "stable", replicas=1, n_slots=2, max_len=56)
            for _ in range(3)]
    router = PodRouter(pods)
    old_digest = pods[0].image.digest
    reqs = [GenRequest(rid=i, prompt=np.arange(1, 5), max_new_tokens=24)
            for i in range(9)]
    router.submit(reqs)
    router.step()
    assert sum(len(e.active) for p in pods for e in p.engines) > 0

    rt.build(IMAGEFILE.format(arch="llama3.2-3b-smoke") + "LABEL rel=r2\n",
             tag="stable")
    done_before = len(router.completed)
    report = RollingDeployer(router).upgrade()
    assert report["changed"] and len(report["pods"]) == 3
    # capacity floor: with one pod drained, the other two stay admissible
    assert report["capacity_floor"] >= 2 * 2
    # non-rolling pods kept finishing requests during the roll
    assert len(router.completed) > done_before
    router.run(max_ticks=5000)
    assert all(r.state == "done" and len(r.tokens) == 24 for r in reqs)
    assert router.rejected_total == 0
    for p in pods:
        assert p.image.digest != old_digest
        for e in p.engines:
            assert e.container.image.digest == p.image.digest
            assert not e.draining and not e.stopped
    assert not router._draining
    # the upgraded fleet still serves
    post = _requests(np.random.default_rng(3), 5, base_rid=900)
    router.submit(post)
    router.run(max_ticks=5000)
    assert all(r.state == "done" for r in post)
    # an IDLE fleet upgrade (instant drains, zero drain ticks) still
    # records the observed capacity floor, not None
    rt.build(IMAGEFILE.format(arch="llama3.2-3b-smoke") + "LABEL rel=r3\n",
             tag="stable")
    idle = RollingDeployer(router).upgrade()
    assert idle["changed"] and idle["capacity_floor"] == 2 * 2


def test_fleet_state_reads_as_one_unit(rt):
    """Router state file sits next to pod state (kind=router), members
    carry the router id, and `repro ps` renders the fleet line."""
    from repro.cli import main as cli_main
    router = _fleet(rt, 2)
    state = rt.root / "pods" / f"{router.router_id}.json"
    assert state.exists()
    rec = json.loads(state.read_text())
    assert rec["kind"] == "router" and rec["policy"] == "shortest-queue"
    assert len(rec["members"]) == 2
    for p in router.pods:
        pod_rec = json.loads(
            (rt.root / "pods" / f"{p.pod_id}.json").read_text())
        assert pod_rec["router"] == router.router_id
    buf = io.StringIO()
    with redirect_stdout(buf):
        assert cli_main(["--root", str(rt.root), "ps"]) == 0
    out = buf.getvalue()
    assert router.router_id in out
    assert f"router={router.router_id}" in out


# ---------------------------------------------------------------------------
# routed serving parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["shortest-queue", "consistent-hash",
                                    "prefix-hash"])
def test_routed_parity_with_static_on_shared_trace(rt, policy):
    """Routing must not change tokens: --pods 2 replays the shared
    frontend trace (tests/test_frontend_serving.py) token-identical to the
    static baseline and the single-pod continuous path."""
    from repro.launch.serve import serve_continuous, serve_static

    def args(**kw):
        a = SimpleNamespace(slots=3, prompt_len=8, gen=6, requests=7, seed=0,
                            platform=None, replicas=1, fairness_cap=4,
                            arrive_per_tick=8, paged=False, page_size=8,
                            pods=1, policy=policy)
        for k, v in kw.items():
            setattr(a, k, v)
        return a

    with redirect_stdout(io.StringIO()):
        routed = serve_continuous(rt, "musicgen-medium-smoke", args(pods=2))
        single = serve_continuous(rt, "musicgen-medium-smoke", args())
        static = serve_static(rt, "musicgen-medium-smoke", args())
    assert len(routed["request_tokens"]) == 7
    assert routed["request_tokens"] == single["request_tokens"]
    assert routed["request_tokens"] == static["request_tokens"]
    assert routed["fleet"]["pods"] and routed["fleet"]["rejected"] == 0


# ---------------------------------------------------------------------------
# prefix-hash placement (prefix-cache affinity)
# ---------------------------------------------------------------------------

def _prefix_fleet(rt, n_pods=3, *, prefix_cache=True, n_slots=2,
                  max_len=64, **kw):
    pods = [Pod(rt, "stable", replicas=1, n_slots=n_slots, max_len=max_len,
                paged=True, page_size=8, prefix_cache=prefix_cache)
            for _ in range(n_pods)]
    return PodRouter(pods, policy="prefix-hash", **kw)


def _prefix_trace(shared, n, *, base_rid=0, seed=0, prefix_len=None):
    rng = np.random.default_rng(seed)
    return [GenRequest(
        rid=base_rid + i,
        prompt=np.concatenate([shared, rng.integers(0, 256,
                                                    int(rng.integers(3, 8)))]),
        max_new_tokens=int(rng.integers(2, 5)),
        prefix_len=prefix_len if prefix_len is not None else len(shared))
        for i in range(n)]


def test_prefix_hash_places_by_digest_with_rid_fallback(rt):
    """Every request sharing a prefix digest lands on ONE pod (cache
    affinity); digest-less requests fall back to rid-hash and spread."""
    router = _prefix_fleet(rt)
    shared_a = np.arange(100, 116)
    shared_b = np.arange(200, 216)
    a = _prefix_trace(shared_a, 8, base_rid=0, seed=1)
    b = _prefix_trace(shared_b, 8, base_rid=100, seed=2)
    plain = [GenRequest(rid=1000 + i, prompt=np.arange(1, 6),
                        max_new_tokens=2) for i in range(40)]
    router.submit(a + b + plain)
    assert len({r.pod for r in a}) == 1, "digest family split across pods"
    assert len({r.pod for r in b}) == 1
    assert len({r.pod for r in plain}) > 1, "rid fallback lost the spread"
    router.run(max_ticks=5000)
    assert all(r.state == "done" for r in a + b + plain)
    # affinity made the cache work: one miss per family, rest hits
    hits = sum(e.prefix_hits for p in router.pods for e in p.engines)
    misses = sum(e.prefix_misses for p in router.pods for e in p.engines)
    assert misses == 2 and hits == 14


def test_prefix_hash_parity_with_uncached_routing(rt):
    """prefix-hash + prefix-cache must not change tokens: the same shared
    trace routed with caching off (same policy) is token-identical."""
    shared = np.arange(50, 70)
    results = []
    for cache in (False, True):
        router = _prefix_fleet(rt, prefix_cache=cache)
        reqs = _prefix_trace(shared, 10, seed=3)
        router.submit(reqs)
        router.run(max_ticks=5000)
        assert all(r.state == "done" for r in reqs)
        results.append([list(r.tokens) for r in reqs])
    assert results[0] == results[1]


def test_draining_pod_prefixes_rematerialize_on_spillover(rt):
    """Drain the pod that owns a cached prefix: new same-prefix traffic
    walks to the ring successor, misses once, re-materializes the prefix
    in THAT pod's pool, then hits there -- and returns home on undrain."""
    router = _prefix_fleet(rt)
    shared = np.arange(300, 324)
    warm = _prefix_trace(shared, 3, base_rid=0, seed=4)
    router.submit(warm)
    router.run(max_ticks=5000)
    home = next(p for p in router.pods if p.pod_id == warm[0].pod)
    assert all(r.pod == home.pod_id for r in warm)
    assert home.engines[0].prefix_misses == 1
    assert home.engines[0].prefix_hits == 2
    assert home.engines[0].pool.cached_pages > 0

    router.drain_pod(home)
    moved = _prefix_trace(shared, 3, base_rid=100, seed=5)
    router.submit(moved)
    router.run(max_ticks=5000)
    assert all(r.state == "done" for r in moved)
    spill = next(p for p in router.pods if p.pod_id == moved[0].pod)
    assert spill is not home, "drained pod still took prefix traffic"
    assert len({r.pod for r in moved}) == 1
    # the prefix re-materialized on the spillover pod: one miss, then hits
    assert spill.engines[0].prefix_misses == 1
    assert spill.engines[0].prefix_hits == 2
    assert spill.engines[0].pool.cached_pages > 0

    router.undrain_pod(home)
    back = _prefix_trace(shared, 2, base_rid=200, seed=6)
    router.submit(back)
    router.run(max_ticks=5000)
    assert all(r.pod == home.pod_id for r in back)
    # home pool still warm from before the drain: straight hits
    assert home.engines[0].prefix_misses == 1
    assert home.engines[0].prefix_hits == 4
