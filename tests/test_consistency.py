"""Serving-path invariants: prefill+decode must reproduce the training
forward pass (f32, all 10 architectures), and generation must be causal."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import params as P
from repro.models.transformer import Model
from repro.serve.serve_step import ServeStepBuilder, greedy_sample
from repro.dist.mesh import make_platform_mesh
from repro.dist.sharding import ShardingRules


def _setup(arch, dropless=True):
    cfg = get_config(arch).reduced()
    if cfg.n_experts and dropless:
        cfg = cfg.with_overrides(capacity_factor=float(cfg.n_experts))
    m = Model(cfg, tp=1, act_dtype=jnp.float32)
    prm = P.materialize(m.param_defs(), jax.random.key(0))
    return cfg, m, prm


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward_f32(arch):
    cfg, m, prm = _setup(arch)
    B, S = 2, 24
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                              cfg.vocab_size)
    fe = (jnp.full((B, cfg.frontend_len, cfg.d_model), 0.01, jnp.float32)
          if cfg.frontend else None)
    Stot = S + 1 + cfg.frontend_len
    full_logits, *_ = m.forward(prm, toks, frontend_embeds=fe)
    want = full_logits[:, -1]
    _, cache, _ = m.forward(prm, toks[:, :S], frontend_embeds=fe,
                            collect_cache=True, cache_len=Stot)
    got, _ = m.decode_step(prm, cache, toks[:, S:S + 1],
                           jnp.int32(S + cfg.frontend_len))
    assert float(jnp.abs(want - got[:, 0]).max()) < 1e-4


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-2.7b",
                                  "recurrentgemma-2b"])
def test_multi_step_generation_stable(arch):
    """8 greedy decode steps: finite logits, tokens in canonical vocab."""
    cfg, m, prm = _setup(arch)
    mesh = make_platform_mesh("local")
    b = ServeStepBuilder(m, mesh, ShardingRules.default())
    B, S, n_new = 2, 16, 8
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    prefill = b.build_prefill(cache_len=S + n_new + 1)
    last_logits, cache = prefill(prm, toks)
    first = greedy_sample(last_logits, cfg.vocab_size)[:, None]
    gen = b.build_generate_loop(n_new)
    out_toks, _ = gen(prm, cache, first, jnp.int32(S))
    assert out_toks.shape == (B, n_new)
    assert int(out_toks.max()) < cfg.vocab_size
    assert int(out_toks.min()) >= 0


def test_forward_is_causal():
    """Perturbing future tokens must not change past logits (any arch with
    every block kind: use recurrentgemma = rec+local-attn, plus ssm)."""
    for arch in ["recurrentgemma-2b", "mamba2-2.7b", "llama3.2-3b"]:
        cfg, m, prm = _setup(arch)
        B, S = 1, 24
        toks = jax.random.randint(jax.random.key(1), (B, S), 0,
                                  cfg.vocab_size)
        toks2 = toks.at[:, -1].set((toks[:, -1] + 7) % cfg.vocab_size)
        l1, _ = m.forward(prm, toks)
        l2, _ = m.forward(prm, toks2)
        assert float(jnp.abs(l1[:, :-1] - l2[:, :-1]).max()) < 1e-5, arch


def test_padded_vocab_never_sampled():
    cfg, m, prm = _setup("internvl2-2b")       # vocab 92553 -> padded 92672
    logits = jnp.zeros((4, 92672)).at[:, 92553:].set(100.0)
    s = greedy_sample(logits, cfg.vocab_size)
    assert int(s.max()) < cfg.vocab_size
