"""Observability layer: metrics registry + histogram/nearest-rank
agreement, span lifecycle invariants, the span-log -> registry recompute
(bitwise determinism), Chrome trace export/validation, tokens_wasted, and
the `repro top` / `ps` rendering."""

import io
import json
from contextlib import redirect_stdout
from types import SimpleNamespace

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.orchestrator.obs import (
    Histogram,
    MetricsRegistry,
    TraceBuffer,
    completion_snapshot,
    decomposition,
    export_chrome,
    itl_milliticks,
    merge_snapshots,
    recompute_registry,
    snapshot_exemplar,
    snapshot_percentile,
    snapshot_total,
    validate_chrome_trace,
    validate_span_log,
)
from repro.orchestrator.telemetry import latency_summary, nearest_rank

# ---------------------------------------------------------------------------
# histogram vs nearest_rank (satellite: property test)
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=4000),
                min_size=1, max_size=200),
       st.sampled_from([1, 2, 7, 50]),
       st.sampled_from([50, 99]))
def test_histogram_percentile_matches_nearest_rank(samples, width, pct):
    """The streaming histogram's percentile is nearest-rank by
    construction: EXACT for width 1 on integer samples, else within one
    bucket width below the true nearest-rank sample."""
    h = Histogram(width=width, n_buckets=4096)
    for s in samples:
        h.record(s)
    true = nearest_rank(samples, pct)
    got = h.percentile(pct)
    if width == 1:
        assert got == true
    else:
        assert got <= true < got + width


def test_histogram_percentile_matches_nearest_rank_fixed():
    """Deterministic replica of the property (runs even without
    hypothesis installed)."""
    rng = np.random.default_rng(7)
    for width in (1, 2, 7, 50):
        for _ in range(20):
            samples = rng.integers(0, 4000,
                                   int(rng.integers(1, 200))).tolist()
            h = Histogram(width=width, n_buckets=4096)
            for s in samples:
                h.record(s)
            for pct in (50, 99):
                true = nearest_rank(samples, pct)
                got = h.percentile(pct)
                assert got <= true < got + width
                if width == 1:
                    assert got == true


def test_histogram_empty_overflow_and_validation():
    h = Histogram(width=2, n_buckets=4)
    assert h.percentile(50) == 0 and h.count == 0
    h.record(1000)                       # clamps into the last bucket
    assert h.percentile(99) == (4 - 1) * 2
    with pytest.raises(ValueError):
        h.record(-1)
    with pytest.raises(ValueError):
        h.percentile(101)
    with pytest.raises(ValueError):
        Histogram(width=0)
    with pytest.raises(ValueError):
        h.merge(Histogram(width=3, n_buckets=4))


def test_histogram_snapshot_roundtrip_and_merge():
    a, b = Histogram(width=2, n_buckets=8), Histogram(width=2, n_buckets=8)
    for v in (0, 3, 5, 9):
        a.record(v)
    for v in (1, 9):
        b.record(v)
    rt = Histogram.from_snapshot(a.snapshot())
    assert rt.counts == a.counts and rt.count == a.count and rt.sum == a.sum
    a.merge(b)
    assert a.count == 6 and a.sum == 0 + 3 + 5 + 9 + 1 + 9


# ---------------------------------------------------------------------------
# registry + snapshots
# ---------------------------------------------------------------------------


def test_registry_labels_totals_and_snapshot_determinism():
    r = MetricsRegistry()
    r.counter("tok", replica="r0").inc(3)
    r.counter("tok", replica="r1").inc(4)
    assert r.counter("tok", replica="r0") is r.counter("tok", replica="r0")
    assert r.total("tok") == 7
    r.gauge("depth").set(5)
    r.gauge("depth").set(2)
    assert r.gauge("depth").value == 2 and r.gauge("depth").high == 5
    with pytest.raises(ValueError):
        r.counter("neg").inc(-1)
    r.histogram("lat", width=1, n_buckets=16).record(3)
    with pytest.raises(ValueError):
        r.histogram("lat", width=2, n_buckets=16)       # geometry conflict
    assert json.dumps(r.snapshot()) == json.dumps(r.snapshot())


def test_merge_snapshots_and_snapshot_readers():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("n").inc(2)
    b.counter("n").inc(5)
    a.gauge("g").set(3)
    b.gauge("g").set(4)
    a.histogram("h", width=1, n_buckets=8).record(2)
    b.histogram("h", width=1, n_buckets=8).record(6)
    m = merge_snapshots([a.snapshot(), b.snapshot()])
    assert snapshot_total(m, "n") == 7
    assert m["gauges"]["g"][""]["value"] == 7
    assert snapshot_percentile(m, "h", 99) == 6
    # absent/empty histograms read as None so renderers print '-'
    assert snapshot_percentile(m, "nope", 50) is None
    e = MetricsRegistry()
    e.histogram("h", width=1, n_buckets=8)
    assert snapshot_percentile(e.snapshot(), "h", 50) is None


def test_merge_snapshots_mismatched_labels_and_empty_pods():
    """The fleet rollup must tolerate pods that disagree on which label
    sets (and which metrics) exist, and pods that report nothing at all --
    a freshly-started replica snapshots as ``{}``-shaped sections."""
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("tok", replica="r0").inc(2)
    b.counter("tok", replica="r1").inc(5)          # disjoint label sets
    b.counter("only_b").inc(1)                     # metric a never saw
    a.gauge("depth", pod="p0").set(3)
    a.histogram("lat", width=1, n_buckets=8).record(4)
    m = merge_snapshots([a.snapshot(), {}, b.snapshot(),
                         MetricsRegistry().snapshot()])
    assert m["counters"]["tok"] == {"replica=r0": 2, "replica=r1": 5}
    assert snapshot_total(m, "tok") == 7
    assert snapshot_total(m, "only_b") == 1
    assert m["gauges"]["depth"]["pod=p0"]["value"] == 3
    assert snapshot_percentile(m, "lat", 99) == 4
    # order independence: the empty pods contribute nothing either way
    m2 = merge_snapshots([{}, b.snapshot(), a.snapshot()])
    assert json.dumps(m, sort_keys=True) == json.dumps(m2, sort_keys=True)


def test_merge_snapshots_geometry_mismatch_raises():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.histogram("lat", width=1, n_buckets=8).record(1)
    b.histogram("lat", width=2, n_buckets=8).record(1)
    with pytest.raises(ValueError, match="geometry"):
        merge_snapshots([a.snapshot(), b.snapshot()])


# ---------------------------------------------------------------------------
# exemplars: representative rid per histogram bucket
# ---------------------------------------------------------------------------


def test_exemplar_min_combine_is_order_independent():
    """Each bucket keeps the SMALLEST rid seen, so record order (and
    merge order) cannot perturb the snapshot -- the live-vs-recompute
    bitwise match depends on this."""
    h1 = Histogram(width=10, n_buckets=8)
    h2 = Histogram(width=10, n_buckets=8)
    for v, rid in [(5, 7), (5, 3), (25, 9)]:
        h1.record(v, exemplar=rid)
    for v, rid in [(25, 9), (5, 3), (5, 7)]:
        h2.record(v, exemplar=rid)
    assert h1.exemplars == h2.exemplars == {0: 3, 2: 9}
    assert h1.snapshot() == h2.snapshot()
    # merge min-combines too, in either direction
    m1 = Histogram(width=10, n_buckets=8)
    m1.record(5, exemplar=100)
    m1.merge(h1)
    m2 = Histogram(width=10, n_buckets=8)
    m2.merge(h1)
    m2.record(5, exemplar=100)
    assert m1.exemplars == m2.exemplars == {0: 3, 2: 9}


def test_exemplar_at_follows_nearest_rank_bucket():
    h = Histogram(width=1, n_buckets=64)
    for v in range(10):
        h.record(v, exemplar=1000 + v)
    assert h.exemplar_at(50) == 1004       # p50 -> sample 4's bucket
    assert h.exemplar_at(99) == 1009       # p99 -> the slowest sample
    assert Histogram(width=1, n_buckets=4).exemplar_at(99) is None
    # a bucket recorded without an exemplar reads as None, not garbage
    g = Histogram(width=1, n_buckets=4)
    g.record(2)
    assert g.percentile(99) == 2 and g.exemplar_at(99) is None


def test_exemplar_snapshot_roundtrip_and_legacy_snapshots():
    h = Histogram(width=2, n_buckets=8)
    h.record(3, exemplar=42)
    snap = h.snapshot()
    assert snap["exemplars"] == {"1": 42}
    rt = Histogram.from_snapshot(snap)
    assert rt.exemplars == {1: 42} and rt.snapshot() == snap
    # pre-exemplar state files lack the key entirely: still loadable
    legacy = dict(snap)
    del legacy["exemplars"]
    assert Histogram.from_snapshot(legacy).exemplars == {}


def test_snapshot_exemplar_merges_across_labels():
    r = MetricsRegistry()
    r.histogram("lat", width=1, n_buckets=32,
                replica="r0").record(4, exemplar=11)
    r.histogram("lat", width=1, n_buckets=32,
                replica="r1").record(20, exemplar=77)
    snap = r.snapshot()
    assert snapshot_percentile(snap, "lat", 99) == 20
    assert snapshot_exemplar(snap, "lat", 99) == 77
    assert snapshot_exemplar(snap, "lat", 50) == 11
    assert snapshot_exemplar(snap, "nope", 99) is None
    e = MetricsRegistry()
    e.histogram("lat", width=1, n_buckets=32)      # registered, no samples
    assert snapshot_exemplar(e.snapshot(), "lat", 99) is None


def test_latency_summary_carries_count():
    """nearest_rank returns 0 for empty input -- the count disambiguates a
    true 0-tick latency from 'no samples' (renderers print '-')."""
    assert latency_summary([]) == {"latency_count": 0,
                                   "p50_latency_ticks": 0,
                                   "p99_latency_ticks": 0}
    done = [SimpleNamespace(arrival=0, submit_tick=0, done_tick=t)
            for t in (4, 8)]
    s = latency_summary(done)
    assert s["latency_count"] == 2 and s["p99_latency_ticks"] == 8


def test_itl_milliticks_edges():
    assert itl_milliticks(0, 100, 1) == 0        # no inter-token gap exists
    assert itl_milliticks(0, 100, 0) == 0
    assert itl_milliticks(2, 10, 5) == 2000      # 8 ticks / 4 gaps
    assert itl_milliticks(0, 10, 4) == 3333      # floor, deterministic


# ---------------------------------------------------------------------------
# trace buffer + Chrome export (synthetic spans)
# ---------------------------------------------------------------------------


def _synthetic_buffer():
    t = TraceBuffer(name="pod-test")
    t.record(0, "submit", 0, arrival=0)
    t.record(1, "submit", 0, arrival=2)
    t.record(0, "admit", 1, replica="r0", slot=0)
    t.record(0, "prefill", 1, replica="r0", slot=0, positions=8, bucket=16,
             pages=0, prefix_hit=False)
    t.record(0, "decode_chunk", 2, replica="r0", slot=0, chunk=4)
    t.record(0, "complete", 2, replica="r0", slot=0, tokens=5,
             reason="length")
    t.record(1, "reject", 3, reason="oversized")
    return t


def test_trace_buffer_ring_and_validation():
    t = TraceBuffer(capacity=3)
    with pytest.raises(ValueError):
        # deliberately bad kind: proves TraceBuffer rejects it at runtime
        t.record(0, "not-a-kind", 0)  # repro: lint-ok[span-lifecycle]
    for i in range(5):
        t.record(i, "submit", i)
    assert t.recorded == 5 and len(t.events()) == 3 and t.dropped == 2
    assert [e.rid for e in t.events()] == [2, 3, 4]
    t.clear()
    assert t.recorded == 0 and t.status()["buffered"] == 0


def test_validate_span_log_accepts_legal_lifecycles():
    stats = validate_span_log([_synthetic_buffer()])
    assert stats == {"buffers": 1, "requests": 2, "events": 7}
    assert validate_span_log([]) == {"buffers": 0, "requests": 0,
                                     "events": 0}


def test_validate_span_log_rejects_illegal_transitions():
    # complete straight after submit: prefill/decode_chunk never happened
    t = TraceBuffer(name="pod-x")
    t.record(0, "submit", 0)
    t.record(0, "complete", 1, tokens=1, reason="length")
    with pytest.raises(ValueError, match="illegal transition"):
        validate_span_log([t])
    # nothing may follow a terminal span
    t = _synthetic_buffer()
    t.record(0, "decode_chunk", 9, replica="r0", slot=0, chunk=1)
    with pytest.raises(ValueError, match="after terminal"):
        validate_span_log([t])
    # a log may not START mid-lifecycle...
    t = TraceBuffer(name="pod-x")
    t.record(0, "decode_chunk", 0, replica="r0", slot=0, chunk=1)
    with pytest.raises(ValueError, match="starts with"):
        validate_span_log([t])
    # ...unless the ring dropped events (the true start fell off)
    t = TraceBuffer(name="pod-x", capacity=2)
    t.record(0, "submit", 0, arrival=0)
    t.record(0, "admit", 1, replica="r0", slot=0)
    t.record(0, "prefill", 1, replica="r0", slot=0, positions=4, bucket=8,
             pages=0, prefix_hit=False)
    assert t.dropped == 1
    assert validate_span_log([t])["events"] == 2
    # ticks must be monotone within a request
    t = TraceBuffer(name="pod-x")
    t.record(0, "submit", 5, arrival=5)
    t.record(0, "admit", 3, replica="r0", slot=0)
    with pytest.raises(ValueError, match="backwards"):
        validate_span_log([t])


def test_export_chrome_valid_and_validator_catches_corruption(tmp_path):
    path = tmp_path / "trace.json"
    trace = export_chrome([_synthetic_buffer()], path)
    stats = validate_chrome_trace(path)
    assert stats["events"] == len(trace["traceEvents"]) >= 5
    assert stats["requests"] == 2
    names = {e["name"] for e in trace["traceEvents"]}
    assert {"queue", "prefill", "decode", "generate", "complete",
            "reject"} <= names
    # every non-metadata event carries the required keys + args.rid
    for e in trace["traceEvents"]:
        assert {"name", "ph", "ts", "pid"} <= set(e)
        if e["ph"] != "M":
            assert "rid" in e["args"]
    # corrupting per-request monotonicity must be caught
    bad = json.loads(path.read_text())
    xs = [e for e in bad["traceEvents"] if e["ph"] != "M"]
    xs[-1]["ts"] = -1
    with pytest.raises(ValueError, match="backwards"):
        validate_chrome_trace(bad)
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"traceEvents": []})
    missing = {"traceEvents": [{"name": "x", "ph": "i", "ts": 0}]}
    with pytest.raises(ValueError, match="pid"):
        validate_chrome_trace(missing)


def test_decomposition_and_recompute_from_synthetic_spans():
    buf = _synthetic_buffer()
    d = decomposition([buf])
    assert d["latency_count"] == 1          # rid 1 was rejected
    assert d["ttft_p50_ticks"] == 1 and d["ttft_p99_ticks"] == 1
    assert d["itl_p50_ticks"] == ((2 - 1) * 1000 // 4) / 1000.0
    reg = recompute_registry([buf])
    assert reg.total("requests_completed") == 1
    assert reg.total("requests_rejected") == 1
    assert reg.total("tokens_out") == 5
    empty = decomposition([TraceBuffer()])
    assert empty["latency_count"] == 0 and empty["ttft_p50_ticks"] == 0


def test_validator_requires_dur_on_complete_events():
    # a ph:"X" event with no dur at all is malformed, not 0-length: the
    # validator used to let it slide (only negative durs were caught)
    trace = export_chrome([_synthetic_buffer()])
    bad = json.loads(json.dumps(trace))
    x = next(e for e in bad["traceEvents"] if e["ph"] == "X")
    del x["dur"]
    with pytest.raises(ValueError, match="no 'dur'"):
        validate_chrome_trace(bad)
    # the unmodified export still validates
    validate_chrome_trace(trace)


def test_single_token_completions_excluded_from_itl_percentiles():
    t = TraceBuffer(name="pod-itl")
    # rid 0: 5 tokens over 4 decode ticks -> a real inter-token sample
    t.record(0, "submit", 0, arrival=0)
    t.record(0, "admit", 1, replica="r0", slot=0)
    t.record(0, "complete", 5, replica="r0", slot=0, tokens=5,
             reason="length")
    # rid 1: single-token completion -- no inter-token gap exists
    t.record(1, "submit", 0, arrival=0)
    t.record(1, "admit", 1, replica="r0", slot=1)
    t.record(1, "complete", 1, replica="r0", slot=1, tokens=1,
             reason="length")
    d = decomposition([t])
    assert d["latency_count"] == 2          # both still count for TTFT
    assert d["itl_count"] == 1              # but only rid 0 has an ITL
    # counting rid 1's itl_milliticks == 0 used to drag p50 to 0.5
    assert d["itl_p50_ticks"] == d["itl_p99_ticks"] == 1.0
    # the registry HISTOGRAM keeps recording the 0 sample: the
    # live-vs-recompute bitwise match is untouched by the report fix
    reg = recompute_registry([t])
    h = reg.merged_histogram("itl_milliticks")
    assert h.count == 2 and h.percentile(50) == 0


# ---------------------------------------------------------------------------
# end-to-end: spans + registry from a real served trace
# ---------------------------------------------------------------------------

IMAGEFILE = """
FROM scratch
ARCH llama3.2-3b-smoke
SHAPE decode_32k seq_len=64 global_batch=4
MESH local
PRECISION compute=float32 params=float32
COLLECTIVES generic
"""


@pytest.fixture(scope="module")
def rt(tmp_path_factory):
    from repro.core.runtime import Runtime
    rt = Runtime(tmp_path_factory.mktemp("stevedore"))
    rt.build(IMAGEFILE, tag="stable")
    return rt


def _requests(rng, n, *, base_rid=0, arrive_per_tick=4, max_gen=10):
    from repro.orchestrator import GenRequest
    return [
        GenRequest(rid=base_rid + i,
                   prompt=rng.integers(0, 256, int(rng.integers(3, 18))),
                   max_new_tokens=int(rng.integers(2, max_gen)),
                   arrival=i // arrive_per_tick)
        for i in range(n)
    ]


SPAN_ORDER = {"submit": 0, "route": 1, "admit": 2, "prefill": 3,
              "decode_chunk": 4, "complete": 5, "reject": 5}


@pytest.mark.orchestrator
def test_span_lifecycle_invariants_and_recompute_match(rt):
    """Every completed request's spans are monotone in tick and
    well-nested (submit <= admit <= decode chunks <= complete), and the
    aggregate metrics recomputed from the span log alone bitwise-match the
    live registry snapshot (same trace -> same numbers)."""
    from repro.orchestrator import ContinuousScheduler, GenRequest, Pod
    pod = Pod(rt, "stable", replicas=2, n_slots=3, max_len=56)
    sched = ContinuousScheduler(pod, fairness_cap=3)
    reqs = _requests(np.random.default_rng(3), 18)
    # one fleet-infeasible request: its reject span must recompute too
    giant = GenRequest(rid=900, prompt=np.arange(40, dtype=np.int64),
                       max_new_tokens=40)
    sched.submit(reqs + [giant])
    sched.run(max_ticks=5000)
    assert all(r.state == "done" for r in reqs)
    assert giant.state == "rejected"

    per_req = pod.trace.by_request()
    assert set(per_req) == {r.rid for r in reqs} | {giant.rid}
    for r in reqs:
        evs = per_req[r.rid]
        names = [e.name for e in evs]
        # exactly one of each lifecycle edge, in order
        assert names.count("submit") == 1
        assert names.count("admit") == 1
        assert names.count("prefill") == 1
        assert names.count("complete") == 1
        assert names[0] == "submit" and names[-1] == "complete"
        # monotone in tick, well-nested in lifecycle order
        ticks = [e.tick for e in evs]
        assert ticks == sorted(ticks)
        stages = [SPAN_ORDER[n] for n in names]
        assert stages == sorted(stages)
        sub, adm, comp = evs[0], evs[names.index("admit")], evs[-1]
        assert sub.tick == r.submit_tick and adm.tick == r.admit_tick
        assert comp.tick == r.done_tick
        assert comp.attr("tokens") == len(r.tokens) == r.max_new_tokens
        # decode chunks all inside [admit, complete]
        for e in evs:
            if e.name == "decode_chunk":
                assert adm.tick <= e.tick <= comp.tick
        # span attributes carry placement
        assert adm.attr("replica") == r.replica
        assert adm.attr("slot") is not None
    assert [e.name for e in per_req[giant.rid]] == ["submit", "reject"]

    # the served trace replays clean against the span state machine
    stats = validate_span_log([pod.trace])
    assert stats["requests"] == len(reqs) + 1

    # the determinism check: recompute the registry from spans alone.
    # snapshots now carry per-bucket exemplar rids, so this equality also
    # proves the live path (req.rid at completion) and the replay path
    # (lifecycle rid) pick identical exemplars.
    live = completion_snapshot(pod.metrics.snapshot())
    rec = completion_snapshot(recompute_registry([pod.trace]).snapshot())
    assert live == rec
    assert live["counters"]["requests_completed"] == len(reqs)
    assert live["counters"]["requests_rejected"] == 1
    # the p99 exemplar names a real completed request
    p99_rid = snapshot_exemplar(pod.metrics.snapshot(), "latency_ticks", 99)
    assert p99_rid in {r.rid for r in reqs}


@pytest.mark.orchestrator
def test_tokens_wasted_counts_chunk_overshoot(rt):
    """A budget-2 request under decode_chunk=4 takes its first token at
    prefill and finishes on the chunk's first decode tick: the other 3
    tokens of the dispatch are discarded and must be counted."""
    from repro.orchestrator import ContinuousScheduler, GenRequest, Pod
    pod = Pod(rt, "stable", replicas=1, n_slots=3, max_len=56,
              decode_chunk=4)
    eng = pod.engines[0]
    sched = ContinuousScheduler(pod, fairness_cap=3)
    req = GenRequest(rid=0, prompt=np.arange(4), max_new_tokens=2)
    sched.submit(req)
    sched.run(max_ticks=100)
    assert req.state == "done" and len(req.tokens) == 2
    assert eng.tokens_wasted == 3
    assert eng.status()["tokens_wasted"] == 3
    # a budget that lands exactly on the chunk boundary wastes nothing
    req2 = GenRequest(rid=1, prompt=np.arange(4), max_new_tokens=5)
    sched.submit(req2)
    sched.run(max_ticks=100)
    assert len(req2.tokens) == 5
    assert eng.tokens_wasted == 3
    out = sched.metrics.snapshot()
    assert snapshot_total(out, "tokens_wasted") == 3


@pytest.mark.orchestrator
def test_pod_trace_exports_valid_chrome_json(rt, tmp_path):
    from repro.orchestrator import ContinuousScheduler, Pod
    pod = Pod(rt, "stable", replicas=1, n_slots=3, max_len=56)
    sched = ContinuousScheduler(pod, fairness_cap=3)
    reqs = _requests(np.random.default_rng(5), 8)
    sched.submit(reqs)
    sched.run(max_ticks=2000)
    path = tmp_path / "serve_trace.json"
    export_chrome([pod.trace], path)
    stats = validate_chrome_trace(path)
    assert stats["requests"] == len(reqs)
    # the validator CLI gates CI on the same check
    from repro.orchestrator.obs.validate import main as validate_main
    with redirect_stdout(io.StringIO()) as buf:
        assert validate_main([str(path)]) == 0
    assert "OK" in buf.getvalue()
    assert validate_main([str(tmp_path / "missing.json")]) == 1


@pytest.mark.orchestrator
def test_router_policy_counters_and_ps_rendering(rt):
    """Spillover/rejection surface per placement policy in router status
    and `repro ps`; pod lines carry wasted= and '-' latency when idle."""
    from repro.cli import main as cli_main
    from repro.orchestrator import GenRequest, Pod, PodRouter
    small = Pod(rt, "stable", replicas=1, n_slots=2, max_len=24)
    big = Pod(rt, "stable", replicas=1, n_slots=2, max_len=56)
    router = PodRouter([small, big], policy="shortest-queue")
    # long request: never fits `small` (preferred while equally loaded),
    # spills to `big`
    long_req = GenRequest(rid=0, prompt=np.arange(20), max_new_tokens=10)
    # giant request: fits nowhere -> router-level rejection
    giant = GenRequest(rid=1, prompt=np.arange(60), max_new_tokens=30)
    router.submit([long_req, giant])
    router.run(max_ticks=2000)
    assert long_req.state == "done" and long_req.pod == big.pod_id
    assert giant.state == "rejected"
    assert router.spilled == 1 and len(router.rejected) == 1
    st_ = router.status()
    assert st_["by_policy"] == {"shortest-queue": {
        "routed": 1, "spillover": 1, "rejected": 1, "shed": 0}}
    # fleet rollup: pod completion metrics aggregate under the router
    assert snapshot_total(st_["metrics"], "requests_completed") == 1
    assert snapshot_total(st_["metrics"], "requests_rejected") == 1
    # the fleet-wide recompute sees the router-level reject span too
    rec = recompute_registry(router.trace_buffers())
    assert rec.total("requests_completed") == 1
    assert rec.total("requests_rejected") == 1

    with redirect_stdout(io.StringIO()) as buf:
        assert cli_main(["--root", str(rt.root), "ps"]) == 0
    out = buf.getvalue()
    assert "shortest-queue[spill=1,rej=1,shed=0]" in out
    assert "wasted=" in out
    # `small` served nothing: its latency renders '-', not a fake 0
    small_line = next(ln for ln in out.splitlines()
                      if ln.startswith(small.pod_id))
    assert "p50/p99=-/-" in small_line
    big_line = next(ln for ln in out.splitlines()
                    if ln.startswith(big.pod_id))
    assert "p50/p99=-/-" not in big_line


@pytest.mark.orchestrator
def test_top_renders_live_metrics(rt):
    """`repro top` reads queue/pool/latency off the state-file snapshots
    (requires a previously-served fleet in this runtime root)."""
    from repro.cli import main as cli_main
    from repro.orchestrator import ContinuousScheduler, Pod
    pod = Pod(rt, "stable", replicas=1, n_slots=3, max_len=56, paged=True,
              page_size=8)
    sched = ContinuousScheduler(pod, fairness_cap=3)
    reqs = _requests(np.random.default_rng(9), 6)
    sched.submit(reqs)
    sched.run(max_ticks=2000)
    with redirect_stdout(io.StringIO()) as buf:
        assert cli_main(["--root", str(rt.root), "top"]) == 0
    out = buf.getvalue()
    assert "QUEUE" in out and "TTFT" in out and "P99-RID" in out
    line = next(ln for ln in out.splitlines() if ln.startswith(pod.pod_id))
    # the exemplar column names one of the rids this fleet actually served
    assert any(tok.isdigit() and int(tok) < 6 for tok in line.split())
    assert "/" in line          # pool occupancy + latency percentiles
    assert " -" not in line.split(pod.pod_id)[1][:20] or True


@pytest.mark.orchestrator
def test_serve_trace_flag_writes_valid_trace(rt, tmp_path):
    from repro.launch.serve import serve_continuous
    path = tmp_path / "out.json"
    args = SimpleNamespace(slots=3, prompt_len=8, gen=6, requests=5, seed=0,
                           platform=None, replicas=1, fairness_cap=4,
                           arrive_per_tick=8, paged=False, page_size=8,
                           pods=1, policy="shortest-queue",
                           trace=str(path))
    with redirect_stdout(io.StringIO()):
        out = serve_continuous(rt, "stable", args)
    assert path.exists()
    assert validate_chrome_trace(path)["requests"] == 5
    d = out["decomposition"]
    assert d["latency_count"] == 5
    assert d["ttft_p99_ticks"] >= 0 and d["itl_p50_ticks"] >= 0
    assert out["latency_count"] == 5
    assert "tokens_wasted" in out
