"""Image / Imagefile / Registry tests incl. hypothesis property tests for
the content-addressing invariants (paper §2.2's layered-FS semantics)."""

import json

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.image import EnvImage, ImageBuilder, Layer
from repro.core.imagefile import ImagefileError, parse_imagefile, render_imagefile
from repro.core.registry import Registry, RegistryError


def build_basic(**kw):
    return (ImageBuilder.from_scratch()
            .arch("llama3.2-3b", **kw)
            .shape("train_4k")
            .mesh("pod")
            .collectives("generic")
            .build())


# ---------------------------------------------------------------------------
# hashing invariants
# ---------------------------------------------------------------------------

def test_same_build_same_digest():
    assert build_basic().digest == build_basic().digest


def test_any_payload_change_changes_digest():
    assert build_basic().digest != build_basic(n_layers=27).digest


def test_layer_chain_integrity_enforced():
    img = build_basic()
    tampered = list(img.layers)
    tampered[2] = Layer(kind=tampered[2].kind, payload={"name": "evil"},
                        parent=tampered[2].parent)
    with pytest.raises(ValueError, match="broken layer chain"):
        EnvImage(tuple(tampered))


def test_derived_image_shares_layer_objects():
    base = build_basic()
    derived = (ImageBuilder.from_image(base)
               .set(remat="dots")
               .build())
    assert derived.layers[:len(base.layers)] == base.layers
    assert derived.digest != base.digest


scalars = st.one_of(st.integers(-1000, 1000), st.booleans(),
                    st.text(st.characters(codec="ascii",
                                          exclude_characters='"\\\n\r '),
                            min_size=1, max_size=8))


@given(st.dictionaries(st.text(st.characters(min_codepoint=97,
                                             max_codepoint=122),
                               min_size=1, max_size=8),
                       scalars, max_size=5))
@settings(max_examples=50, deadline=None)
def test_property_digest_deterministic_and_order_free(payload):
    """Layer digest depends only on content, not dict insertion order."""
    l1 = Layer(kind="set", payload=payload)
    l2 = Layer(kind="set", payload=dict(reversed(list(payload.items()))))
    assert l1.digest == l2.digest
    assert Layer.from_json(l1.to_json()).digest == l1.digest


@given(st.dictionaries(st.text(st.characters(min_codepoint=97,
                                             max_codepoint=122),
                               min_size=1, max_size=8),
                       st.integers(0, 100), min_size=1, max_size=4))
@settings(max_examples=30, deadline=None)
def test_property_different_payload_different_digest(payload):
    l1 = Layer(kind="set", payload=payload)
    k = next(iter(payload))
    changed = dict(payload)
    changed[k] = changed[k] + 1
    assert Layer(kind="set", payload=changed).digest != l1.digest


# ---------------------------------------------------------------------------
# imagefile
# ---------------------------------------------------------------------------

IMAGEFILE = """
# paper-style build file
FROM scratch
ARCH llama3.2-3b n_layers=27
SHAPE train_4k global_batch=64
MESH pod
PRECISION compute=bfloat16 params=float32
COLLECTIVES host zero1=true grad_compression=bfloat16
SET remat=dots microbatches=2
LABEL tier=stable
"""


def test_imagefile_parse_and_config():
    img = parse_imagefile(IMAGEFILE)
    cfg = img.config()
    assert cfg["arch"]["overrides"]["n_layers"] == 27
    assert cfg["shape"]["global_batch"] == 64
    assert cfg["collectives"]["zero1"] is True
    assert cfg["settings"]["microbatches"] == 2
    assert cfg["labels"]["tier"] == "stable"


def test_imagefile_roundtrip_preserves_digest():
    img = parse_imagefile(IMAGEFILE)
    again = parse_imagefile(render_imagefile(img))
    assert again.digest == img.digest


def test_imagefile_rejects_garbage():
    with pytest.raises(ImagefileError):
        parse_imagefile("ARCH before-from")
    with pytest.raises(ImagefileError):
        parse_imagefile("FROM scratch\nBOGUS directive")


def test_imagefile_from_registry_tag(tmp_path):
    reg = Registry(tmp_path)
    base = parse_imagefile(IMAGEFILE)
    reg.push(base, tag="stable")
    derived = parse_imagefile("FROM stable\nSET extra=1\n", registry=reg)
    assert derived.layers[:len(base.layers)] == base.layers
    assert derived.config()["settings"]["extra"] == 1


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_push_pull_roundtrip(tmp_path):
    reg = Registry(tmp_path)
    img = build_basic()
    reg.push(img, tag="v1")
    assert reg.pull("v1").digest == img.digest
    assert reg.pull(img.digest).digest == img.digest
    assert reg.pull(img.digest[:12]).digest == img.digest


def test_registry_layer_dedupe(tmp_path):
    """Pushing a derived image transfers ONLY the new layers (paper §2.2)."""
    reg = Registry(tmp_path)
    base = build_basic()
    s1 = reg.push(base, tag="base")
    assert s1.layers_transferred == len(base.layers)
    derived = ImageBuilder.from_image(base).set(remat="dots").build()
    s2 = reg.push(derived, tag="derived")
    assert s2.layers_transferred == 1
    assert s2.layers_reused == len(base.layers)
    assert s2.dedupe_fraction > 0.8


def test_registry_detects_corruption(tmp_path):
    reg = Registry(tmp_path)
    img = build_basic()
    reg.push(img, tag="v1")
    # corrupt one layer blob
    victim = next((tmp_path / "layers").iterdir())
    victim.write_text(json.dumps({"kind": "set", "payload": {"evil": 1},
                                  "parent": None}))
    with pytest.raises(RegistryError, match="hash mismatch"):
        reg.pull("v1")


def test_registry_unknown_ref(tmp_path):
    with pytest.raises(RegistryError):
        Registry(tmp_path).pull("nope")
