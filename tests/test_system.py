"""End-to-end behaviour of the paper's system (deliverable c, integration):

the full paper workflow -- write an Imagefile, build + push the image, run a
container, train with checkpointing, kill it, restore into a FRESH container
(possibly on a different platform = elastic restart), and verify bitwise
training continuity. Plus the ABI-swap contract: same image, collectives
layer swapped, model code untouched.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.checkpoint.elastic import reshard_restore
from repro.core.image import ImageBuilder
from repro.core.runtime import Runtime
from repro.data.pipeline import DataConfig, SyntheticLM

IMAGEFILE = """
FROM scratch
ARCH llama3.2-3b-smoke
SHAPE train_4k seq_len=16 global_batch=4
MESH local
PRECISION compute=float32 params=float32
COLLECTIVES generic
SET optimizer={"lr":0.005,"warmup_steps":2,"total_steps":50}
"""


def make_batches(cfg, n, start=0):
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=4, seed=11))
    return [{k: jnp.asarray(v) for k, v in data.batch(i).items()}
            for i in range(start, start + n)]


def train(container, params, opt, batches, store=None, save_every=2):
    step = jax.jit(container.train_step_fn())
    losses = []
    for i, b in enumerate(batches):
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
        if store is not None and (i + 1) % save_every == 0:
            store.save(i + 1, {"params": params, "opt": opt}, blocking=True)
    return params, opt, losses


def test_full_paper_workflow(tmp_path):
    rt = Runtime(tmp_path / "rt")
    rt.build(IMAGEFILE, tag="stable")

    # ---- phase 1: train 4 steps, checkpoint at 2 and 4, then "crash" ----
    c1 = rt.run("stable")
    p = c1.init_params(0)
    o = c1.init_opt_state(p)
    store = CheckpointStore(c1.overlay / "ckpt")
    cfg = c1.arch
    p, o, losses1 = train(c1, p, o, make_batches(cfg, 4), store)
    assert store.latest_step() == 4

    # ---- phase 2: fresh container (same image), restore, continue -------
    c2 = rt.run("stable")
    t = {"params": c2.abstract_params(), "opt": c2.abstract_opt_state()}
    sh = {"params": c2.param_shardings(), "opt": c2.opt_state_shardings()}
    restored = reshard_restore(store, t, sh)
    p2, o2 = restored["params"], restored["opt"]
    assert int(o2["step"]) == 4

    # continuity: step 5 from restore == step 5 from the uninterrupted run
    b5 = make_batches(cfg, 1, start=4)
    pa, oa, la = train(c1, p, o, b5)
    pb, ob, lb = train(c2, p2, o2, b5)
    assert la[0] == pytest.approx(lb[0], abs=1e-6)
    diffs = [float(jnp.abs(x - y).max()) for x, y in
             zip(jax.tree.leaves(pa), jax.tree.leaves(pb))]
    assert max(diffs) < 1e-6, "restart must be bitwise-continuous"


def test_abi_swap_changes_only_collectives_layer(tmp_path):
    """Same arch/shape layers; swapping COLLECTIVES host<->generic changes
    the image digest (different artifact) but shares every other layer --
    the MPICH->Cray swap with zero model-code change."""
    rt = Runtime(tmp_path / "rt")
    img_g = rt.build(IMAGEFILE, tag="generic")
    img_h = rt.build(IMAGEFILE.replace("COLLECTIVES generic",
                                       "COLLECTIVES host mode=explicit "
                                       "zero1=false "
                                       "grad_compression=float32"),
                     tag="host")
    assert img_g.digest != img_h.digest
    shared = sum(a == b for a, b in zip(img_g.layers, img_h.layers))
    assert shared >= 5                      # everything before COLLECTIVES

    cg, ch = rt.run("generic"), rt.run("host")
    pg = cg.init_params(0)
    ph = ch.init_params(0)
    og, oh = cg.init_opt_state(pg), ch.init_opt_state(ph)
    batches = make_batches(cg.arch, 2)
    _, _, lg = train(cg, pg, og, batches)
    _, _, lh = train(ch, ph, oh, batches)
    # one device: the two ABIs must agree numerically
    assert lg[0] == pytest.approx(lh[0], abs=1e-5)
    assert lg[1] == pytest.approx(lh[1], abs=1e-4)


def test_node_failure_recovery_drill(tmp_path):
    """Simulated failure mid-run: the latest atomic checkpoint is intact
    even though a save was in flight, and training resumes deterministically
    (the elastic.py §story, executable form)."""
    rt = Runtime(tmp_path / "rt")
    rt.build(IMAGEFILE, tag="stable")
    c = rt.run("stable")
    p = c.init_params(0)
    o = c.init_opt_state(p)
    store = CheckpointStore(c.overlay / "ckpt")
    batches = make_batches(c.arch, 3)
    step = jax.jit(c.train_step_fn())
    p, o, _ = step(p, o, batches[0])
    store.save(1, {"params": p, "opt": o}, blocking=False)  # async, in flight
    p, o, _ = step(p, o, batches[1])
    store.wait()                            # "crash" after this point
    # recovery
    c2 = rt.run("stable")
    t = {"params": c2.abstract_params(), "opt": c2.abstract_opt_state()}
    sh = {"params": c2.param_shardings(), "opt": c2.opt_state_shardings()}
    restored = reshard_restore(store, t, sh)
    assert int(restored["opt"]["step"]) == 1
    # deterministic data replay from the restored step
    step2 = jax.jit(c2.train_step_fn())
    p2, o2, m2 = step2(restored["params"], restored["opt"], batches[1])
    diffs = [float(jnp.abs(x - y).max()) for x, y in
             zip(jax.tree.leaves(p), jax.tree.leaves(p2))]
    assert max(diffs) < 1e-6
