"""Checkpoint store: CAS dedupe, atomic publish, async save, gc, restore,
elastic re-shard; straggler monitor behaviour."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.checkpoint.elastic import reshard_restore
from repro.checkpoint.straggler import StragglerMonitor


def tree(seed=0, scale=1.0):
    k = jax.random.key(seed)
    return {
        "w": scale * jax.random.normal(k, (32, 16)),
        "nested": {"b": jnp.arange(8, dtype=jnp.float32),
                   "step": jnp.int32(3)},
    }


def test_save_restore_roundtrip(tmp_path):
    store = CheckpointStore(tmp_path)
    t = tree()
    store.save(10, t, blocking=True)
    out = store.restore(t, 10)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_steps(tmp_path):
    store = CheckpointStore(tmp_path)
    for s in (1, 5, 3):
        store.save(s, tree(s), blocking=True)
    assert store.steps() == [1, 3, 5]
    assert store.latest_step() == 3          # LATEST points at last written


def test_blob_dedupe_across_checkpoints(tmp_path):
    """Unchanged tensors are stored once (layered-FS discipline)."""
    store = CheckpointStore(tmp_path)
    t = tree()
    store.save(1, t, blocking=True)
    s1 = dict(store.last_stats)
    t2 = {**t, "w": t["w"] + 1}             # only w changes
    store.save(2, t2, blocking=True)
    s2 = dict(store.last_stats)
    assert s1["new_blobs"] == 3
    assert s2["new_blobs"] == 1
    assert s2["reused_blobs"] == 2


def test_async_save_then_wait(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save(7, tree(), blocking=False)
    store.wait()
    assert store.latest_step() == 7


def test_gc_keeps_live_blobs(tmp_path):
    store = CheckpointStore(tmp_path)
    for s in range(5):
        store.save(s, tree(s), blocking=True)
    removed = store.gc(keep_last=2)
    assert store.steps() == [3, 4]
    assert removed > 0
    # survivors still restore
    out = store.restore(tree(4), 4)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree(4)["w"]))


def test_restore_shape_mismatch_raises(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save(1, tree(), blocking=True)
    bad = {**tree(), "w": jnp.zeros((4, 4))}
    with pytest.raises(ValueError, match="shape"):
        store.restore(bad, 1)


def test_elastic_reshard_restore(tmp_path):
    """Restore onto a (trivially) different mesh layout: the store is
    layout-agnostic, placement comes from the target shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    store = CheckpointStore(tmp_path)
    t = tree()
    store.save(1, t, blocking=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    out = reshard_restore(store, t, sh, 1)
    assert out["w"].sharding == NamedSharding(mesh, P())
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(t["w"]))


# ---------------------------------------------------------------------------
# straggler monitor
# ---------------------------------------------------------------------------

def test_straggler_flags_outlier():
    mon = StragglerMonitor(window=16, trip_threshold=2)
    for _ in range(16):
        mon.observe(1.0)
    r = mon.observe(10.0)
    assert r["flagged"] and not r["tripped"]
    r = mon.observe(10.0)
    assert r["tripped"]


def test_straggler_tolerates_noise():
    mon = StragglerMonitor(window=16)
    rng = np.random.default_rng(0)
    flags = sum(mon.observe(1.0 + 0.01 * rng.standard_normal())["flagged"]
                for _ in range(200))
    assert flags <= 2


def test_straggler_outliers_excluded_from_window():
    mon = StragglerMonitor(window=16, trip_threshold=99)
    for _ in range(16):
        mon.observe(1.0)
    for _ in range(10):                      # sustained slowness keeps flagging
        assert mon.observe(5.0)["flagged"]
