"""Frontend-embedding serving (musicgen/internvl2) through the orchestrator
and the static baseline.

PR 1's orchestrator rewrite regressed the audio/vision frontend archs the
old driver served: both serve modes raised NotImplementedError. These tests
pin the restored path end-to-end -- admission with per-request prefix
embeddings, prefill parity against the raw model forward, continuous vs
static token parity on a shared trace (contiguous AND paged), and the
rejection paths for prefixes an engine cannot take.
"""

import io
from contextlib import redirect_stdout
from types import SimpleNamespace

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.runtime import Runtime
from repro.launch.serve import serve_continuous, serve_static
from repro.orchestrator import ContinuousScheduler, GenRequest, Pod

pytestmark = pytest.mark.orchestrator

IMAGEFILE = """
FROM scratch
ARCH {arch}
SHAPE decode_32k seq_len=64 global_batch=4
MESH local
PRECISION compute=float32 params=float32
COLLECTIVES generic
"""

FRONTEND_ARCHS = ("musicgen-medium-smoke", "internvl2-2b-smoke")


@pytest.fixture(scope="module")
def rt(tmp_path_factory):
    rt = Runtime(tmp_path_factory.mktemp("stevedore"))
    for arch in FRONTEND_ARCHS + ("llama3.2-3b-smoke",):
        rt.build(IMAGEFILE.format(arch=arch), tag=arch)
    return rt


def _frontend(rng, fe_len, d_model):
    return 0.02 * rng.standard_normal((fe_len, d_model)).astype(np.float32)


def _serve_args(**kw):
    args = SimpleNamespace(slots=3, prompt_len=8, gen=6, requests=7, seed=0,
                           platform=None, replicas=1, fairness_cap=4,
                           arrive_per_tick=8, paged=False, page_size=8)
    for k, v in kw.items():
        setattr(args, k, v)
    return args


@pytest.mark.parametrize("arch", FRONTEND_ARCHS)
def test_frontend_archs_serve_in_both_modes(rt, arch):
    """Regression: the two NotImplementedError guards (SlotEngine.__init__
    and serve_static) stay gone -- both modes complete for frontend archs."""
    pod = Pod(rt, arch, replicas=1, n_slots=2, max_len=40)   # no raise
    assert pod.engines[0].fe_len == 4
    args = _serve_args(requests=2)
    with redirect_stdout(io.StringIO()):
        res = serve_static(rt, arch, args)                   # no raise
    assert res["requests"] == 2
    assert all(len(t) >= 1 for t in res["request_tokens"].values())


@pytest.mark.parametrize("arch", FRONTEND_ARCHS)
def test_continuous_matches_static_on_shared_trace(rt, arch):
    """The acceptance bar: continuous (contiguous AND paged) and static
    modes produce identical tokens request-for-request on the same trace
    of prompts + frontend prefixes + budgets."""
    outs = {}
    with redirect_stdout(io.StringIO()):
        outs["continuous"] = serve_continuous(rt, arch, _serve_args())
        outs["static"] = serve_static(rt, arch, _serve_args())
        outs["paged"] = serve_continuous(rt, arch, _serve_args(paged=True))
    ref = outs["continuous"]["request_tokens"]
    assert len(ref) == 7
    assert outs["static"]["request_tokens"] == ref
    assert outs["paged"]["request_tokens"] == ref
    # budgets were honored (heavy-tailed trace: lengths differ)
    assert len({len(t) for t in ref.values()}) > 1


def test_prefill_matches_model_forward(rt):
    """The engine's first sampled token equals greedy argmax of the raw
    model forward over [frontend prefix, prompt] -- right-padded bucket
    prefill and the packing gather change nothing numerically."""
    pod = Pod(rt, "musicgen-medium-smoke", replicas=1, n_slots=2, max_len=40)
    eng = pod.engines[0]
    c, params = eng.container, eng.params
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, c.arch.vocab_size, 6)
    fe = _frontend(rng, eng.fe_len, eng.d_model)
    req = GenRequest(rid=0, prompt=prompt, max_new_tokens=3, frontend=fe)
    sched = ContinuousScheduler(pod)
    sched.submit(req)
    sched.run(max_ticks=100)
    logits, _ = c.model.forward(
        params, jnp.asarray(prompt[None]),
        frontend_embeds=jnp.asarray(fe[None], c.cache_dtype))
    ref = int(jnp.argmax(logits[0, -1, :c.arch.vocab_size]))
    assert req.tokens[0] == ref
    # decode continued from position fe_len + prompt_len
    assert req.state == "done" and len(req.tokens) == 3


def test_partial_and_absent_prefixes_paged_parity(rt):
    """Prefix shorter than the arch's frontend buffer, and no prefix at
    all, both serve -- and paged/contiguous agree token-for-token."""
    def trace():
        rng = np.random.default_rng(11)
        reqs = []
        for i in range(5):
            fl = (None, 1, 2, 4, 3)[i]
            fe = _frontend(rng, fl, 64) if fl else None
            reqs.append(GenRequest(
                rid=i, prompt=rng.integers(0, 256, int(rng.integers(3, 9))),
                max_new_tokens=int(rng.integers(2, 6)), frontend=fe))
        return reqs

    results = []
    for paged in (False, True):
        pod = Pod(rt, "musicgen-medium-smoke", replicas=1, n_slots=2,
                  max_len=40, paged=paged, page_size=8)
        sched = ContinuousScheduler(pod)
        reqs = trace()
        sched.submit(reqs)
        sched.run(max_ticks=2000)
        assert all(r.state == "done" for r in reqs)
        assert all(len(r.tokens) == r.max_new_tokens for r in reqs)
        results.append([r.tokens for r in reqs])
        eng = pod.engines[0]
        assert sorted(eng.free) == list(range(eng.n_slots))
        if paged:
            eng.pool.check()
            assert eng.pool.in_use == 0
    assert results[0] == results[1]


def test_prefix_actually_conditions_output(rt):
    """Two requests with the same prompt but different frontend prefixes
    must be able to diverge (the prefix is consumed, not dropped)."""
    pod = Pod(rt, "internvl2-2b-smoke", replicas=1, n_slots=2, max_len=40)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, 256, 6)
    a = GenRequest(rid=0, prompt=prompt, max_new_tokens=4,
                   frontend=_frontend(rng, 4, 64))
    b = GenRequest(rid=1, prompt=prompt, max_new_tokens=4,
                   frontend=5.0 * _frontend(rng, 4, 64))
    sched = ContinuousScheduler(pod)
    sched.submit([a, b])
    sched.run(max_ticks=200)
    assert a.tokens != b.tokens


def test_frontend_rejections(rt):
    """A prefix on a text-only arch, or wider than the arch's buffer, or
    with the wrong embedding width, is rejected with a named reason -- the
    fleet keeps serving."""
    rng = np.random.default_rng(9)
    # text-only engine
    pod = Pod(rt, "llama3.2-3b-smoke", replicas=1, n_slots=2, max_len=56)
    sched = ContinuousScheduler(pod)
    bad = GenRequest(rid=0, prompt=np.arange(4), max_new_tokens=2,
                     frontend=_frontend(rng, 4, 64))
    ok = GenRequest(rid=1, prompt=np.arange(4), max_new_tokens=2)
    sched.submit([bad, ok])
    sched.run(max_ticks=100)
    assert bad.state == "rejected" and "text-only" in bad.error
    assert ok.state == "done"

    # frontend engine: prefix wider than the arch buffer / wrong width
    pod = Pod(rt, "musicgen-medium-smoke", replicas=1, n_slots=2, max_len=40)
    sched = ContinuousScheduler(pod)
    wide = GenRequest(rid=2, prompt=np.arange(4), max_new_tokens=2,
                      frontend=_frontend(rng, 9, 64))
    thin = GenRequest(rid=3, prompt=np.arange(4), max_new_tokens=2,
                      frontend=_frontend(rng, 4, 32))
    fine = GenRequest(rid=4, prompt=np.arange(4), max_new_tokens=2,
                      frontend=_frontend(rng, 4, 64))
    sched.submit([wide, thin, fine])
    sched.run(max_ticks=100)
    assert wide.state == "rejected" and "exceeds arch frontend_len" in wide.error
    assert thin.state == "rejected" and "d_model" in thin.error
    assert fine.state == "done"


def test_prefix_cache_on_off_parity_on_shared_frontend_trace(rt):
    """Frontend requests bypass the prefix page cache (their leading KV
    rows are per-request embeddings, not shareable prompt pages): enabling
    --prefix-cache on the shared frontend trace -- even with a declared
    shared token block -- changes no tokens and records no hits."""
    outs = {}
    with redirect_stdout(io.StringIO()):
        for cache in (False, True):
            args = _serve_args(paged=True)
            args.prefix_cache = cache
            args.shared_prefix = 16
            outs[cache] = serve_continuous(rt, "musicgen-medium-smoke", args)
    assert len(outs[False]["request_tokens"]) == 7
    assert outs[False]["request_tokens"] == outs[True]["request_tokens"]
    assert outs[True]["prefix_cache"]["enabled"]
    assert outs[True]["prefix_cache"]["hits"] == 0
    assert outs[True]["prefix_cache"]["misses"] == 0
    assert outs[True]["prefill_positions"] == outs[False]["prefill_positions"]


def test_frontend_span_counts_against_max_len(rt):
    """Admission accounts the STATIC frontend buffer in the request span:
    a prompt+gen that would fit a text slot is rejected when the frontend
    rows push it past max_len."""
    pod = Pod(rt, "musicgen-medium-smoke", replicas=1, n_slots=1, max_len=20)
    eng = pod.engines[0]
    # span = 4 (frontend) + 8 + 8 = 20 > 20 - chunk
    bad = GenRequest(rid=0, prompt=np.arange(8), max_new_tokens=8)
    sched = ContinuousScheduler(pod)
    sched.submit(bad)
    sched.run(max_ticks=50)
    assert bad.state == "rejected"
    assert "frontend+prompt+gen" in bad.error
    assert not eng.active
