"""Refcounted PagePool property/invariant suite (the PR's foregrounded
test work).

Random interleaved reserve/share/alloc/COW/release schedules must keep the
full ``check()`` invariant set after EVERY operation: no page both free and
referenced, refcounts equal to page-table occurrences, reservations always
coverable, and full reclaim after all releases (plus draining the prefix
index) returns every page. Plus the adversarial cases: digest collisions
miss on the full-block compare, LRU eviction under pool pressure never
frees a page with live refs, and releasing one sharer never clobbers
another sharer's mapped prefix pages (the PR's release() audit).

Runs under the orchestrator marker (pure host bookkeeping, no device work).
"""

import numpy as np
import pytest

from repro.orchestrator.page_pool import GARBAGE_PAGE, PagePool

pytestmark = pytest.mark.orchestrator


def _block(rng, n):
    return rng.integers(0, 512, n).astype(np.int32)


# ---------------------------------------------------------------------------
# randomized schedules
# ---------------------------------------------------------------------------

def test_random_share_cow_schedules_conserve_pages():
    """800 random admit(miss)/admit(hit)/extend/COW/release/promote/pause
    steps: pages are conserved across the free-list, private ownership and
    the prefix index; ``check()`` asserts the invariants after every op;
    after releasing every slot and dropping the index the pool is fully
    drained."""
    rng = np.random.default_rng(0)
    ps = 8
    pool = PagePool(n_pages=41, page_size=ps, n_slots=6, max_pages=16)
    hi = {}          # slot -> high-water written position
    goal = {}        # slot -> total page rows the slot may cover
    digests = [f"d{i}" for i in range(4)]
    blocks = {d: _block(rng, ps * (1 + i % 3)) for i, d in enumerate(digests)}

    for _ in range(800):
        op = rng.integers(0, 6)
        busy = list(hi)
        free_slots = [s for s in range(6) if s not in hi]
        if op == 0 and free_slots:              # admit, maybe via the cache
            slot = int(rng.choice(free_slots))
            d = str(rng.choice(digests))
            entry = pool.lookup(d, blocks[d], touch=True)
            total = int(rng.integers(2, 10))
            if entry is not None:
                k = min(len(entry.pages), total - 1)
                if k >= 1 and pool.can_reserve(total - k + pool.pin_cost(entry)):
                    pool.reserve(slot, total - k)
                    pool.share(slot, entry, k)
                    goal[slot] = total
                    hi[slot] = k * ps           # first private write position
                    pool.alloc_upto(slot, hi[slot])
            elif pool.can_reserve(total):
                pool.reserve(slot, total)
                goal[slot] = total
                hi[slot] = int(rng.integers(0, total * ps))
                pool.alloc_upto(slot, hi[slot])
                # sometimes promote the leading fully-written pages
                kc = min(len(blocks[d]) // ps, (hi[slot] + 1) // ps)
                if kc >= 1 and rng.integers(0, 2):
                    pool.cache_prefix(d, blocks[d], slot, kc)
        elif op == 1 and busy:                  # decode: extend alloc-on-write
            slot = int(rng.choice(busy))
            cap = (len(pool.shared[slot]) + int(pool.reserved[slot])) * ps - 1
            hi[slot] = min(cap, hi[slot] + int(rng.integers(1, 5)))
            pool.alloc_upto(slot, hi[slot])
        elif op == 2 and busy:                  # release
            slot = int(rng.choice(busy))
            pool.release(slot)
            del hi[slot], goal[slot]
        elif op == 3 and busy:                  # copy-on-write a shared row
            slot = int(rng.choice(busy))
            if pool.shared[slot] and \
                    len(pool.owned[slot]) < pool.reserved[slot] and \
                    (pool.free or pool.evictable_pages):
                old, new = pool.cow(slot)
                assert old != new and new not in pool.free
                assert pool.table[slot, len(pool.shared[slot])] == new
        elif op == 4:                           # cold lookups never mutate
            d = str(rng.choice(digests))
            pool.lookup(d, blocks[d])
        elif op == 5 and busy:                  # page-level preemption
            slot = int(rng.choice(busy))
            pool.pause(slot)
            # a paused slot holds nothing until its resume re-reserves
            # (a later admit on the slot clears the mark via reserve)
            assert slot in pool.paused
            assert not pool.owned[slot] and not pool.shared[slot]
            assert pool.reserved[slot] == 0
            del hi[slot], goal[slot]
        pool.check()

    for slot in list(hi):
        pool.release(slot)
        pool.check()
    assert pool.total_owned == 0 and pool.total_reserved == 0
    # cached pages survive full release (warm cache) ...
    assert pool.in_use == pool.cached_pages
    # ... and draining the index reclaims every page
    pool.drop_prefixes()
    pool.check()
    assert pool.in_use == 0 and len(pool.free) == pool.capacity
    assert not pool.prefix
    assert pool.pages_allocated == pool.pages_freed > 0


def test_refcounts_match_table_occurrences():
    """Three sharers of one prefix: refcount tracks the mapping count
    exactly, and every mapped row resolves to the cached page."""
    ps = 4
    pool = PagePool(n_pages=17, page_size=ps, n_slots=4, max_pages=8)
    blk = _block(np.random.default_rng(1), 2 * ps)
    pool.reserve(0, 4)
    pool.alloc_upto(0, 3 * ps - 1)
    assert pool.cache_prefix("d", blk, 0, 2)
    entry = pool.lookup("d", blk)
    for slot in (1, 2):
        pool.reserve(slot, 2)
        pool.share(slot, entry, 2)
        pool.alloc_upto(slot, 2 * ps)
    pool.check()
    for p in entry.pages:
        assert pool.refcount[p] == 3            # promoter + two sharers
        assert sum(int(pool.table[s, j]) == p
                   for s in range(4) for j in range(8)) == 3
    pool.release(0)
    pool.check()
    assert all(pool.refcount[p] == 2 for p in entry.pages)


# ---------------------------------------------------------------------------
# adversarial: collisions, eviction, sharer isolation
# ---------------------------------------------------------------------------

def test_digest_collision_on_differing_tokens_misses():
    """Same digest, different token block: lookup must MISS (full-block
    compare), never serve the other block's pages -- for both a different
    length and a same-length, different-content block."""
    rng = np.random.default_rng(2)
    ps = 4
    pool = PagePool(n_pages=17, page_size=ps, n_slots=2, max_pages=8)
    blk = _block(rng, 2 * ps)
    pool.reserve(0, 4)
    pool.alloc_upto(0, 3 * ps - 1)
    assert pool.cache_prefix("collide", blk, 0, 2)
    assert pool.lookup("collide", blk) is not None
    other = blk.copy()
    other[3] += 1
    assert pool.lookup("collide", other) is None
    assert pool.lookup("collide", blk[:ps]) is None
    assert pool.lookup("collide", np.concatenate([blk, blk[:1]])) is None
    # a colliding promotion does not overwrite the resident entry
    pool.release(0)
    pool.reserve(1, 4)
    pool.alloc_upto(1, 3 * ps - 1)
    assert not pool.cache_prefix("collide", other, 1, 2)
    got = pool.lookup("collide", blk)
    assert got is not None and np.array_equal(got.tokens, blk)
    pool.check()


def test_eviction_under_pressure_never_frees_live_refs():
    """Pool pressure evicts refcount-0 prefixes LRU-first; a prefix with a
    live sharer survives every eviction, and when nothing is evictable the
    allocator fails cleanly instead of stealing."""
    rng = np.random.default_rng(3)
    ps = 4
    # capacity 12 = three 2-page prefixes + 6 private
    pool = PagePool(n_pages=13, page_size=ps, n_slots=4, max_pages=16)
    blocks = {d: _block(rng, 2 * ps) for d in ("a", "b", "c")}
    for slot, d in enumerate(blocks):
        pool.reserve(slot, 2)
        pool.alloc_upto(slot, 2 * ps - 1)
        assert pool.cache_prefix(d, blocks[d], slot, 2)
    # LRU order: touch "a" so "b" is the coldest refcount-0 entry
    pool.lookup("a", blocks["a"], touch=True)
    live = pool.lookup("c", blocks["c"], touch=True)
    pool.reserve(3, 2)
    pool.share(3, live, 2)                      # "c" now has a live sharer
    for slot in range(3):
        pool.release(slot)
    pool.check()
    assert pool.cached_pages == 6 and len(pool.free) == 6

    # headroom respects the live sharer's outstanding promise (2 pages):
    # 6 free + 4 evictable - 2 promised = 8, never 10
    assert pool.free_unreserved == 8
    assert not pool.can_reserve(9)
    # demand 8 private pages: drains the free list then evicts the
    # COLDEST refcount-0 prefix ("b"); "a" (touched) and "c" (live) survive
    pool.reserve(0, 8)
    pool.alloc_upto(0, 8 * ps - 1)
    pool.check()
    assert "b" not in pool.prefix and {"a", "c"} <= set(pool.prefix)
    assert pool.evictions == 1
    # the live sharer now extends into its promised pages: pressure evicts
    # "a" next -- and NEVER "c", whose pages slot 3 still maps
    pool.alloc_upto(3, 4 * ps - 1)
    pool.check()
    assert "a" not in pool.prefix and "c" in pool.prefix
    assert pool.evictions == 2
    live_pages = set(live.pages)
    assert not (live_pages & set(pool.free))
    assert all(pool.table[3, j] == p for j, p in enumerate(live.pages))
    # nothing evictable left and the free list is dry: admission fails
    # cleanly instead of stealing a live page
    assert not pool.can_reserve(1)
    with pytest.raises(RuntimeError):
        pool.reserve(1, 1)
    pool.check()


def test_release_one_sharer_keeps_other_sharers_pages():
    """The release() audit (PR bugfix): releasing one sharer frees ONLY its
    private pages -- the shared prefix pages stay out of the free list and
    the surviving sharer's table rows still resolve to them, so a
    subsequent allocation cannot clobber a live prefix."""
    rng = np.random.default_rng(4)
    ps = 4
    pool = PagePool(n_pages=21, page_size=ps, n_slots=3, max_pages=16)
    blk = _block(rng, 2 * ps)
    pool.reserve(0, 5)
    pool.alloc_upto(0, 4 * ps - 1)
    assert pool.cache_prefix("sys", blk, 0, 2)
    entry = pool.lookup("sys", blk)
    pool.reserve(1, 3)
    pool.share(1, entry, 2)
    pool.alloc_upto(1, 4 * ps - 1)
    survivor_rows = [int(pool.table[1, j]) for j in range(4)]

    pool.release(0)                             # one sharer exits
    pool.check()
    assert not (set(entry.pages) & set(pool.free)), \
        "release() freed pages another sharer still maps"
    assert [int(pool.table[1, j]) for j in range(4)] == survivor_rows
    assert all(pool.refcount[p] == 1 for p in entry.pages)

    # hammer the free list: new exclusive allocations must not receive the
    # shared pages while slot 1 still maps them
    pool.reserve(2, 10)
    pool.alloc_upto(2, 10 * ps - 1)
    assert not (set(entry.pages) & set(pool.owned[2]))
    pool.check()
    pool.release(1)
    pool.release(2)
    pool.check()
    assert pool.in_use == pool.cached_pages == 2   # warm, evictable now


def test_cow_remaps_last_shared_row():
    """COW gives a sharer a private copy of its last shared page: the table
    row flips to the new page, the old page stays cached for the other
    sharers, and the copy draws against the slot's reservation."""
    rng = np.random.default_rng(5)
    ps = 4
    pool = PagePool(n_pages=17, page_size=ps, n_slots=3, max_pages=8)
    blk = _block(rng, 2 * ps)
    pool.reserve(0, 4)
    pool.alloc_upto(0, 3 * ps - 1)
    assert pool.cache_prefix("sys", blk, 0, 2)
    entry = pool.lookup("sys", blk)
    pool.reserve(1, 3)
    pool.share(1, entry, 2)
    old_expected = entry.pages[1]
    old, new = pool.cow(1)
    assert old == old_expected and new != old
    assert pool.table[1, 1] == new and pool.table[1, 0] == entry.pages[0]
    assert pool.refcount[old] == 1              # only the promoter now
    assert pool.table[0, 1] == old              # other sharer untouched
    assert pool.cow_copies == 1
    pool.check()
    # reservation accounting: the copy + remaining rows still bounded
    pool.alloc_upto(1, 3 * ps - 1)
    pool.check()
    with pytest.raises(RuntimeError):
        pool.alloc_upto(1, 6 * ps - 1)          # beyond the reservation
    pool.release(0)
    pool.release(1)
    pool.check()
    assert pool.in_use == pool.cached_pages


def test_share_requires_clean_slot_and_valid_count():
    rng = np.random.default_rng(6)
    ps = 4
    pool = PagePool(n_pages=17, page_size=ps, n_slots=2, max_pages=8)
    blk = _block(rng, 2 * ps)
    pool.reserve(0, 4)
    pool.alloc_upto(0, 3 * ps - 1)
    assert pool.cache_prefix("d", blk, 0, 2)
    entry = pool.lookup("d", blk)
    with pytest.raises(RuntimeError):
        pool.share(0, entry, 1)                 # slot already maps pages
    pool.reserve(1, 2)
    with pytest.raises(ValueError):
        pool.share(1, entry, 3)                 # more pages than cached
    pool.share(1, entry, 2)
    pool.check()


def test_garbage_page_never_cached_or_shared():
    rng = np.random.default_rng(7)
    pool = PagePool(n_pages=9, page_size=4, n_slots=1, max_pages=8)
    pool.reserve(0, 4)
    pool.alloc_upto(0, 15)
    assert pool.cache_prefix("d", _block(rng, 8), 0, 2)
    assert GARBAGE_PAGE not in pool.shared[0]
    for e in pool.prefix.values():
        assert GARBAGE_PAGE not in e.pages
    pool.release(0)
    pool.drop_prefixes()
    pool.check()
    assert len(pool.free) == pool.capacity
