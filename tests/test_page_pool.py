"""PagePool + PrefixRadix property/invariant suite (the PR's foregrounded
test work).

Random interleaved admit/share/alloc/COW/release/promote/spill schedules
must keep the full ``check()`` invariant set after EVERY operation: no page
both free and referenced, refcounts equal to shared-row occurrences, child
refcounts bounded by the parent's, spilled nodes exactly mirroring the host
store (conservation across tiers), reservations always coverable, and full
reclaim after all releases (plus draining the registry) returns every page.
Plus the adversarial cases: a forced chained-digest collision at an
INTERIOR radix node misses on the byte compare and never corrupts the
existing subtree, LRU eviction under pool pressure never frees a page with
live refs (and breaks last-use ties deterministically by digest), a
spill->restore round trip re-materializes a family by digest like a
registry pull, and ``pin_cost`` dedupes by page id so admission never
double-budgets a page reachable through two match nodes.

Runs under the orchestrator marker (pure host bookkeeping, no device work).
"""

import numpy as np
import pytest

from repro.orchestrator.page_pool import GARBAGE_PAGE, PagePool
from repro.orchestrator.prefix_registry import PrefixMatch

pytestmark = pytest.mark.orchestrator


def _block(rng, n):
    return rng.integers(0, 512, n).astype(np.int32)


def _promote_family(pool, slot, toks, ps):
    """Admit ``slot`` as a miss and register every complete block of
    ``toks`` -- the engine's miss-path promotion, at pool level."""
    kc = len(toks) // ps
    pool.reserve(slot, kc)
    pool.alloc_upto(slot, kc * ps - 1)
    return pool.promote_chain(slot, None,
                              [toks[i * ps:(i + 1) * ps] for i in range(kc)])


# ---------------------------------------------------------------------------
# randomized schedules
# ---------------------------------------------------------------------------

def test_random_radix_schedules_conserve_pages():
    """800 random admit(miss)/admit(hit)/extend/COW/release/promote/pause/
    spill steps over a family tree with ancestor-extension and a mid-block
    tail (so interior promotion and partial in-node matches both arise):
    pages are conserved across the free-list, private ownership, the
    resident registry and the host spill tier; ``check()`` asserts the
    invariants after every op; after releasing every slot and draining the
    registry the pool is fully reclaimed."""
    rng = np.random.default_rng(0)
    ps = 8
    pool = PagePool(n_pages=41, page_size=ps, n_slots=6, max_pages=16,
                    spill_pages=None)
    hi = {}          # slot -> high-water written position
    goal = {}        # slot -> total page rows the slot may cover
    base = _block(rng, 2 * ps)
    fams = [
        base,                                              # 2 blocks
        _block(rng, ps),                                   # 1 block
        np.concatenate([_block(rng, ps), _block(rng, 3)]),  # block + tail
        np.concatenate([base, _block(rng, ps)]),           # extends fams[0]
        base[:ps + 5],                                     # ends mid-block
    ]

    for _ in range(800):
        op = int(rng.integers(0, 9))
        busy = list(hi)
        idle = [s for s in range(6) if s not in hi]
        if op in (0, 1) and idle:           # admit, through the registry
            slot = int(rng.choice(idle))
            toks = fams[int(rng.integers(0, len(fams)))]
            m = pool.match(toks, touch=True)
            k, kc = len(m.nodes), len(toks) // ps
            total = kc + int(rng.integers(1, 5))
            need = total - k
            if pool.can_reserve(need + pool.pin_cost(m)
                                + pool.restore_cost(m)):
                pool.reserve(slot, need)
                if m.all_nodes():
                    pool.share_chain(slot, m)
                    pool.check()            # pinned mid-admission state
                    pool.unpin()
                hi[slot] = int(rng.integers(k * ps, total * ps))
                goal[slot] = total
                pool.alloc_upto(slot, hi[slot])
                # engine promotion: freshly written complete blocks join
                # the registry under the deepest matched ancestor
                if kc > k and m.partial is None and rng.integers(0, 2) \
                        and hi[slot] + 1 >= kc * ps:
                    parent = m.nodes[-1] if m.nodes else None
                    pool.promote_chain(
                        slot, parent,
                        [toks[i * ps:(i + 1) * ps] for i in range(k, kc)])
        elif op == 2 and busy:              # decode: extend alloc-on-write
            slot = int(rng.choice(busy))
            # coverable rows shrink as COW draws against the reservation
            cap = (len(pool.shared[slot])
                   + int(pool.reserved[slot])) * ps - 1
            hi[slot] = min(cap, hi[slot] + int(rng.integers(1, 9)))
            pool.alloc_upto(slot, hi[slot])
        elif op == 3 and busy:              # release
            slot = int(rng.choice(busy))
            pool.release(slot)
            del hi[slot], goal[slot]
        elif op == 4 and busy:              # copy-on-write a shared row
            slot = int(rng.choice(busy))
            if pool.shared[slot] and \
                    len(pool.owned[slot]) < pool.reserved[slot] and \
                    (pool.free or pool.evictable_pages):
                old, new = pool.cow(slot)
                assert old != new and new not in pool.free
                assert pool.table[slot, len(pool.shared[slot])] == new
        elif op == 5 and busy:              # page-level preemption
            slot = int(rng.choice(busy))
            pool.pause(slot)
            assert slot in pool.paused
            assert not pool.owned[slot] and not pool.shared[slot]
            assert pool.reserved[slot] == 0
            del hi[slot], goal[slot]
        elif op == 6:                       # proactive tiering
            pool.spill_one()
        elif op == 7:                       # cold lookups never mutate
            toks = fams[int(rng.integers(0, len(fams)))]
            before = (pool.in_use, pool.spilled_pages)
            pool.match(toks)
            assert (pool.in_use, pool.spilled_pages) == before
        elif op == 8:                       # tier events are well-formed
            assert all(kind in ("spill", "restore")
                       for kind, _ in pool.drain_events())
        pool.check()

    for slot in list(hi):
        pool.release(slot)
        pool.check()
    assert pool.total_owned == 0 and pool.total_reserved == 0
    # resident cached pages survive full release (warm registry) ...
    assert pool.in_use == pool.cached_pages
    # ... and draining the registry reclaims every page and every payload
    pool.drop_prefixes()
    pool.check()
    assert pool.in_use == 0 and len(pool.free) == pool.capacity
    assert pool.radix.node_count == 0 and pool.spilled_pages == 0
    assert pool.pages_allocated == pool.pages_freed > 0


def test_refcounts_match_table_occurrences():
    """Three sharers of one 2-block family: refcount tracks the mapping
    count exactly, and every mapped row resolves to the node's page."""
    ps = 4
    pool = PagePool(n_pages=17, page_size=ps, n_slots=4, max_pages=8)
    blk = _block(np.random.default_rng(1), 2 * ps)
    pool.reserve(0, 4)
    pool.alloc_upto(0, 3 * ps - 1)
    nodes = pool.promote_chain(0, None, [blk[:ps], blk[ps:]])
    assert [n.depth for n in nodes] == [1, 2]
    for slot in (1, 2):
        m = pool.match(blk, touch=True)
        assert len(m.nodes) == 2 and m.partial is None
        pool.reserve(slot, 1)
        pool.share_chain(slot, m)
        pool.unpin()
        pool.alloc_upto(slot, 2 * ps)
    pool.check()
    for n in nodes:
        assert pool.refcount[n.page] == 3       # promoter + two sharers
        assert sum(int(pool.table[s, j]) == n.page
                   for s in range(4) for j in range(8)) == 3
    pool.release(0)
    pool.check()
    assert all(pool.refcount[n.page] == 2 for n in nodes)


# ---------------------------------------------------------------------------
# adversarial: collisions, eviction, sharer isolation
# ---------------------------------------------------------------------------

def test_digest_collision_at_interior_node_misses(monkeypatch):
    """Forced chained-digest collision at an INTERIOR radix node: the walk
    byte-compares blocks, so the colliding request misses at that depth --
    and its promotion (first writer wins) leaves the registered subtree
    untouched instead of corrupting it."""
    from repro.orchestrator import prefix_registry
    monkeypatch.setattr(prefix_registry, "chained_digest",
                        lambda parent, block: f"{parent}|x")
    ps = 4
    pool = PagePool(n_pages=17, page_size=ps, n_slots=2, max_pages=8)
    rng = np.random.default_rng(2)
    blk = _block(rng, 3 * ps)       # forged digests: |x, |x|x, |x|x|x
    pool.reserve(0, 4)
    pool.alloc_upto(0, 4 * ps - 1)
    assert len(pool.promote_chain(
        0, None, [blk[i * ps:(i + 1) * ps] for i in range(3)])) == 3
    pool.release(0)

    # same first block, DIFFERENT second block, whose forged digest
    # collides with the registered depth-2 child
    other = blk.copy()
    other[ps:2 * ps] = blk[ps:2 * ps][::-1] + 1
    assert not np.array_equal(other[ps:2 * ps], blk[ps:2 * ps])
    m = pool.match(other, touch=True)
    assert len(m.nodes) == 1 and m.partial is None   # stops AT the collision
    pool.reserve(1, 4)
    pool.share_chain(1, m)
    pool.unpin()
    pool.alloc_upto(1, 3 * ps - 1)
    got = pool.promote_chain(1, m.nodes[-1], [other[ps:2 * ps],
                                              other[2 * ps:]])
    assert got == []                # nothing registered, nothing replaced
    assert pool.radix.node_count == 3
    full = pool.match(blk)          # original family fully matchable
    assert len(full.nodes) == 3
    assert np.array_equal(full.nodes[1].tokens, blk[ps:2 * ps])
    pool.check()


def test_eviction_under_pressure_never_frees_live_refs():
    """With the spill tier disabled, pool pressure EVICTS refcount-0 nodes
    LRU-first (leaf before parent); a family with a live sharer survives
    every eviction, and when nothing is evictable the allocator fails
    cleanly instead of stealing."""
    rng = np.random.default_rng(3)
    ps = 4
    # capacity 12 = three 2-page families + 6 private
    pool = PagePool(n_pages=13, page_size=ps, n_slots=4, max_pages=16)
    blocks = {d: _block(rng, 2 * ps) for d in ("a", "b", "c")}
    for slot, d in enumerate(blocks):
        assert len(_promote_family(pool, slot, blocks[d], ps)) == 2
    # LRU order: touch "a" so "b"'s nodes are the coldest refcount-0 ones
    pool.match(blocks["a"], touch=True)
    live = pool.match(blocks["c"], touch=True)
    pool.reserve(3, 2)
    pool.share_chain(3, live)       # "c" now has a live sharer
    pool.unpin()
    for slot in range(3):
        pool.release(slot)
    pool.check()
    assert pool.cached_pages == 6 and len(pool.free) == 6

    # headroom respects the live sharer's outstanding promise (2 pages):
    # 6 free + 4 evictable ("a"+"b") - 2 promised = 8, never 10
    assert pool.free_unreserved == 8
    assert not pool.can_reserve(9)
    # demand 8 private pages: drains the free list then evicts the COLDEST
    # refcount-0 family ("b"), leaf first; "a" (touched) + "c" (live) stay
    pool.reserve(0, 8)
    pool.alloc_upto(0, 8 * ps - 1)
    pool.check()
    assert not pool.match(blocks["b"]).nodes
    assert len(pool.match(blocks["a"]).nodes) == 2
    assert pool.evictions == 2
    # the live sharer extends into its promised pages: pressure evicts
    # "a" next -- and NEVER "c", whose pages slot 3 still maps
    pool.alloc_upto(3, 4 * ps - 1)
    pool.check()
    assert not pool.match(blocks["a"]).nodes
    assert len(pool.match(blocks["c"]).nodes) == 2
    assert pool.evictions == 4
    live_pages = [n.page for n in live.nodes]
    assert not (set(live_pages) & set(pool.free))
    assert all(pool.table[3, j] == p for j, p in enumerate(live_pages))
    # nothing evictable left and the free list is dry: admission fails
    # cleanly instead of stealing a live page
    assert not pool.can_reserve(1)
    with pytest.raises(RuntimeError):
        pool.reserve(1, 1)
    pool.check()


def test_eviction_order_deterministic_on_lru_ties():
    """Victims tied on last_used order by digest: two runs over the same
    state reclaim in the same order (satellite: deterministic LRU)."""
    pool = PagePool(n_pages=5, page_size=4, n_slots=2, max_pages=8,
                    spill_pages=None)
    for slot, seed in enumerate((1, 2)):
        _promote_family(pool, slot, _block(np.random.default_rng(seed), 4),
                        4)
        pool.release(slot)
    for n in pool.radix.walk():
        n.last_used = 7             # forced tie
    first, second = pool.spill_one(), pool.spill_one()
    assert [first, second] == sorted([first, second])
    pool.check()


# ---------------------------------------------------------------------------
# the spill tier: registry pulls, store capacity
# ---------------------------------------------------------------------------

def test_spill_restore_round_trip_with_io_callbacks():
    """A spilled family is re-materialized BY DIGEST on the next match
    (the registry pull): payloads round-trip through the registered IO
    callbacks, events drain in order, and the tier counters agree."""
    ps = 4
    saved, loaded = [], []
    pool = PagePool(n_pages=9, page_size=ps, n_slots=2, max_pages=8,
                    spill_pages=None)
    pool.set_spill_io(lambda page: ("payload", page),
                      lambda page, payload: loaded.append((page, payload)))
    blk = _block(np.random.default_rng(9), 2 * ps)
    assert len(_promote_family(pool, 0, blk, ps)) == 2
    pool.release(0)
    d_leaf = pool.spill_one()       # leaf first: parents keep resident kids
    d_root = pool.spill_one()
    assert d_leaf is not None and d_root is not None
    assert pool.spilled_pages == 2 and pool.cached_pages == 0
    assert pool.store.digests() == {d_leaf, d_root}
    pool.check()
    assert pool.drain_events() == [("spill", d_leaf), ("spill", d_root)]

    m = pool.match(blk, touch=True)
    assert len(m.nodes) == 2 and pool.restore_cost(m) == 2
    pool.reserve(1, 1)
    pool.share_chain(1, m)          # restores root-first, then maps
    pool.unpin()
    assert pool.spilled_pages == 0 and pool.cached_pages == 2
    assert pool.spills == 2 and pool.restores == 2
    assert pool.drain_events() == [("restore", d_root), ("restore", d_leaf)]
    # both pages moved through the device callbacks with their payloads
    assert [p for _, (_, p) in loaded] == sorted(p for _, (_, p) in loaded) \
        or len(loaded) == 2
    assert len(loaded) == 2
    pool.check()


def test_spill_store_capacity_prunes_lru_subtrees():
    """A bounded host tier prunes the LRU spilled subtree past capacity:
    the payload leaves the store AND the nodes leave the registry (a
    capped registry, not a leak)."""
    ps = 4
    pool = PagePool(n_pages=9, page_size=ps, n_slots=2, max_pages=8,
                    spill_pages=1)
    rng = np.random.default_rng(10)
    for slot in range(2):
        _promote_family(pool, slot, _block(rng, ps), ps)
        pool.release(slot)
    assert pool.radix.node_count == 2
    d1 = pool.spill_one()
    assert pool.spilled_pages == 1
    d2 = pool.spill_one()
    # capacity 1: the older payload's subtree was pruned outright
    assert pool.spilled_pages == 1 and pool.radix.node_count == 1
    assert d1 not in pool.store and d2 in pool.store
    assert pool.evictions == 1 and pool.spills == 2
    pool.check()


# ---------------------------------------------------------------------------
# admission budgeting
# ---------------------------------------------------------------------------

def test_pin_cost_dedupes_by_page_id():
    """``pin_cost`` budgets the headroom a share removes from the
    evictable set -- BY PAGE ID. A match exposing the same node (same
    page) through both the chain and the partial boundary must cost one
    page, not two (the double-count made admission under-admit)."""
    rng = np.random.default_rng(8)
    ps = 4
    pool = PagePool(n_pages=17, page_size=ps, n_slots=2, max_pages=8)
    blk = _block(rng, 2 * ps)
    nodes = _promote_family(pool, 0, blk, ps)
    pool.release(0)

    m = pool.match(blk)
    assert pool.pin_cost(m) == 2            # honest match: distinct pages
    dup = PrefixMatch(nodes=[nodes[0]], partial=nodes[0], partial_len=3)
    assert pool.pin_cost(dup) == 1          # the regression: was 2
    # property: over random node multisets the cost is exactly the number
    # of DISTINCT evictable pages, never the multiset size
    for _ in range(100):
        k = int(rng.integers(1, 6))
        picks = [nodes[int(i)] for i in rng.integers(0, len(nodes), k)]
        m2 = PrefixMatch(nodes=picks[:-1], partial=picks[-1], partial_len=1)
        assert pool.pin_cost(m2) == len({n.page for n in picks})


# ---------------------------------------------------------------------------
# sharer isolation, COW, API guards
# ---------------------------------------------------------------------------

def test_release_one_sharer_keeps_other_sharers_pages():
    """Releasing one sharer frees ONLY its private pages -- the shared
    family pages stay out of the free list and the surviving sharer's
    table rows still resolve to them, so a subsequent allocation cannot
    clobber a live prefix."""
    rng = np.random.default_rng(4)
    ps = 4
    pool = PagePool(n_pages=21, page_size=ps, n_slots=3, max_pages=16)
    blk = _block(rng, 2 * ps)
    pool.reserve(0, 5)
    pool.alloc_upto(0, 4 * ps - 1)
    assert len(pool.promote_chain(0, None, [blk[:ps], blk[ps:]])) == 2
    m = pool.match(blk, touch=True)
    pool.reserve(1, 2)
    pool.share_chain(1, m)
    pool.unpin()
    pool.alloc_upto(1, 4 * ps - 1)
    survivor_rows = [int(pool.table[1, j]) for j in range(4)]

    pool.release(0)                         # one sharer exits
    pool.check()
    pages = [n.page for n in m.nodes]
    assert not (set(pages) & set(pool.free)), \
        "release() freed pages another sharer still maps"
    assert [int(pool.table[1, j]) for j in range(4)] == survivor_rows
    assert all(pool.refcount[p] == 1 for p in pages)

    # hammer the free list: new exclusive allocations must not receive the
    # shared pages while slot 1 still maps them
    pool.reserve(2, 10)
    pool.alloc_upto(2, 10 * ps - 1)
    assert not (set(pages) & set(pool.owned[2]))
    pool.check()
    pool.release(1)
    pool.release(2)
    pool.check()
    assert pool.in_use == pool.cached_pages == 2   # warm, evictable now


def test_cow_remaps_last_shared_row():
    """COW gives a sharer a private copy of its LAST shared page: the
    table row flips to the new page, the node keeps its page for the other
    sharers, and the copy draws against the slot's reservation."""
    rng = np.random.default_rng(5)
    ps = 4
    pool = PagePool(n_pages=17, page_size=ps, n_slots=3, max_pages=8)
    blk = _block(rng, 2 * ps)
    pool.reserve(0, 4)
    pool.alloc_upto(0, 3 * ps - 1)
    nodes = pool.promote_chain(0, None, [blk[:ps], blk[ps:]])
    m = pool.match(blk, touch=True)
    pool.reserve(1, 3)
    pool.share_chain(1, m)
    pool.unpin()
    old_expected = nodes[1].page
    old, new = pool.cow(1)
    assert old == old_expected and new != old
    assert pool.table[1, 1] == new and pool.table[1, 0] == nodes[0].page
    assert pool.refcount[old] == 1          # only the promoter now
    assert nodes[1].resident                # the node itself is untouched
    assert pool.table[0, 1] == old          # other sharer untouched
    assert pool.cow_copies == 1
    pool.check()
    # reservation accounting: the copy + remaining rows still bounded
    pool.alloc_upto(1, 3 * ps - 1)
    pool.check()
    with pytest.raises(RuntimeError):
        pool.alloc_upto(1, 6 * ps - 1)      # beyond the reservation
    pool.release(0)
    pool.release(1)
    pool.check()
    assert pool.in_use == pool.cached_pages


def test_share_requires_clean_slot_and_nonempty_match():
    rng = np.random.default_rng(6)
    ps = 4
    pool = PagePool(n_pages=17, page_size=ps, n_slots=2, max_pages=8)
    blk = _block(rng, 2 * ps)
    pool.reserve(0, 4)
    pool.alloc_upto(0, 3 * ps - 1)
    assert len(pool.promote_chain(0, None, [blk[:ps], blk[ps:]])) == 2
    m = pool.match(blk)
    with pytest.raises(RuntimeError):
        pool.share_chain(0, m)              # slot already maps pages
    pool.reserve(1, 2)
    with pytest.raises(ValueError):
        pool.share_chain(1, pool.match(_block(rng, ps)))   # empty match
    pool.share_chain(1, m)
    pool.unpin()
    pool.check()


def test_garbage_page_never_cached_or_shared():
    rng = np.random.default_rng(7)
    pool = PagePool(n_pages=9, page_size=4, n_slots=1, max_pages=8)
    pool.reserve(0, 4)
    pool.alloc_upto(0, 15)
    blk = _block(rng, 8)
    assert len(pool.promote_chain(0, None, [blk[:4], blk[4:]])) == 2
    assert GARBAGE_PAGE not in pool.shared[0]
    for n in pool.radix.walk():
        assert n.page != GARBAGE_PAGE
    pool.release(0)
    pool.drop_prefixes()
    pool.check()
    assert len(pool.free) == pool.capacity
