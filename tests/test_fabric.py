"""Cross-host fabric invariants: the codec round-trips requests bit-for-
bit, a transport-connected fleet is token-identical to a single
scheduler, eviction removes exactly the victim's ring entries, a dead
pod's in-flight work re-routes EXACTLY once (and resumes bitwise), a
flapping link (dropped replies, live worker) never evicts or duplicates
work, and the outstanding-token ledger settles to zero -- including the
PodRouter deadline-shed regression that motivated this sweep."""

import numpy as np
import pytest

from repro.core.runtime import Runtime
from repro.orchestrator import (
    ContinuousScheduler,
    FabricRouter,
    GenRequest,
    Pod,
    decode_request,
    encode_request,
    loopback_spawner,
)
from repro.orchestrator.fabric import decode_frame, encode_frame
from repro.orchestrator.obs import validate_fleet_closure, validate_span_log

pytestmark = pytest.mark.orchestrator

IMAGEFILE = """
FROM scratch
ARCH {arch}
SHAPE decode_32k seq_len=64 global_batch=4
MESH local
PRECISION compute=float32 params=float32
COLLECTIVES generic
"""

POD_KWARGS = dict(replicas=1, n_slots=2, max_len=96)
MAX_TICKS = 5000


@pytest.fixture(scope="module")
def rt(tmp_path_factory):
    rt = Runtime(tmp_path_factory.mktemp("stevedore"))
    rt.build(IMAGEFILE.format(arch="llama3.2-3b-smoke"), tag="stable")
    return rt


def _requests(n, *, seed=0, arrive_per_tick=6):
    rng = np.random.default_rng(seed)
    return [GenRequest(
        rid=i,
        prompt=rng.integers(0, 256, int(rng.integers(4, 16))),
        max_new_tokens=int(rng.integers(4, 14)),
        arrival=i // arrive_per_tick) for i in range(n)]


def _fabric(rt, **kw):
    spawn = loopback_spawner(rt, rt.pull("stable"), pod_kwargs=POD_KWARGS)
    kw.setdefault("fleet", f"t{abs(hash(str(sorted(kw.items())))) % 10**8}")
    return FabricRouter(spawn, runtime=rt, **kw)


def _drain(router):
    while router.busy and router.tick < MAX_TICKS:
        router.step()
    assert not router.busy, "fabric run did not converge"


def _oracle(rt, reqs):
    """Single-scheduler token oracle: greedy decode + seeded params make
    tokens a function of (prompt, budget) only, so ONE pod running the
    whole trace is the parity reference for every fleet topology."""
    pod = Pod(rt, "stable", **POD_KWARGS)
    sched = ContinuousScheduler(pod)
    sched.submit(reqs)
    sched.run(max_ticks=MAX_TICKS)
    assert all(r.state == "done" for r in reqs)
    return {r.rid: list(r.tokens) for r in reqs}


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------

def test_frame_codec_skips_stray_output():
    msg = {"t": "hb", "tick": 7, "pod": "fab-0"}
    raw = encode_frame(msg)
    assert raw.startswith(b"\x1e") and raw.endswith(b"\n")
    assert decode_frame(raw) == msg
    # a worker's stdout carries library prints too: only frames parse
    assert decode_frame(b"some library print\n") is None
    assert decode_frame(b"\x1enot json\n") is None
    assert decode_frame(b"\x1e[1,2]\n") is None   # frames are objects
    assert decode_frame(raw.decode()) == msg      # str form too


def test_request_codec_roundtrips_resume_state():
    rng = np.random.default_rng(3)
    req = GenRequest(rid=42, prompt=rng.integers(0, 256, 9),
                     max_new_tokens=12, eos_id=7, arrival=3,
                     frontend=rng.standard_normal((5, 16)).astype(
                         np.float32),
                     prefix_len=4, priority="batch", deadline_ticks=50)
    # mid-flight resume state: what a re-route must carry to a survivor
    req.state = "preempted"
    req.tokens = [11, 22, 33]
    req.submit_tick, req.admit_tick = 2, 5
    req.preemptions, req.reroutes = 1, 1
    back = decode_request(decode_frame(encode_frame(
        {"t": "submit", "req": encode_request(req)}))["req"])
    assert back.rid == req.rid
    np.testing.assert_array_equal(back.prompt, np.asarray(req.prompt))
    assert back.prompt.dtype == np.int32
    np.testing.assert_array_equal(back.frontend, req.frontend)
    assert back.frontend.dtype == np.float32
    for f in ("max_new_tokens", "eos_id", "arrival", "prefix_len",
              "priority", "deadline_ticks", "state", "tokens",
              "submit_tick", "admit_tick", "preemptions", "reroutes"):
        assert getattr(back, f) == getattr(req, f), f
    # no frontend is preserved as None, not a zero-size array
    bare = GenRequest(rid=1, prompt=np.arange(3), max_new_tokens=2)
    assert decode_request(encode_request(bare)).frontend is None


# ---------------------------------------------------------------------------
# serving parity over the transport
# ---------------------------------------------------------------------------

def test_loopback_fleet_token_parity_with_single_scheduler(rt):
    """Framing every request/token through the codec must not change a
    single token: the 2-pod fabric replays the trace bitwise-identical
    to one scheduler owning it all, and the pooled span log closes."""
    oracle = _oracle(rt, _requests(14))
    router = _fabric(rt, pods=2, min_pods=2)
    reqs = _requests(14)
    router.submit(reqs)
    _drain(router)
    assert all(r.state == "done" for r in reqs)
    assert {r.rid: list(r.tokens) for r in reqs} == oracle
    assert router.outstanding_total == 0
    buffers = router.trace_buffers()
    validate_span_log(buffers)
    closure = validate_fleet_closure(buffers)
    assert closure["routed"] == closure["closed"] == 14
    router.close()


# ---------------------------------------------------------------------------
# eviction / reroute
# ---------------------------------------------------------------------------

def _kill_mid_decode(router):
    while router.busy and router.tick < MAX_TICKS:
        victim = next(
            (m for m in router.members.values()
             if any(r.tokens and len(r.tokens) < r.max_new_tokens
                    for r in m.assigned.values())),
            None)
        if victim is not None:
            victim.transport.kill()
            return victim
        router.step()
    raise AssertionError("no member was ever mid-decode")


def test_eviction_removes_exactly_victims_ring_entries(rt):
    """The hash ring after an eviction is the old ring minus precisely
    the victim's vnodes -- survivors' entries (hash AND position) are
    untouched, so only the victim's keyspace reassigns."""
    router = _fabric(rt, pods=3, min_pods=1, policy="consistent-hash",
                     vnodes=16)
    before = list(router._ring)
    victim = list(router.members.values())[1]
    victim.transport.kill()
    router.step()               # eviction sweep fires inside the tick
    assert victim.pod_id not in router.members
    expect = [(h, p) for h, p in before if p != victim.pod_id]
    assert router._ring == expect
    assert len(before) - len(router._ring) == 16
    assert router._ring_keys == [h for h, _ in router._ring]
    _drain(router)
    router.close()


def test_reroute_exactly_once_and_bitwise_resume(rt):
    """Kill a pod mid-decode: every one of its in-flight requests lands
    on a survivor EXACTLY once (reroutes == 1, single re-admission), the
    resumed continuations are token-identical to an unkilled run, and
    the ledger settles to zero."""
    oracle = _oracle(rt, _requests(14))
    router = _fabric(rt, pods=2, min_pods=2)
    reqs = _requests(14)
    router.submit(reqs)
    victim = _kill_mid_decode(router)
    inflight = sorted(victim.assigned)
    assert inflight, "victim had no in-flight work at kill time"
    _drain(router)
    assert all(r.state == "done" for r in reqs)
    assert {r.rid: list(r.tokens) for r in reqs} == oracle
    fab = router.status()["fabric"]
    assert fab["evictions"] == 1
    assert fab["reroutes"] == len(inflight)
    for r in reqs:
        assert r.reroutes == (1 if r.rid in inflight else 0), r.rid
    assert router.outstanding_total == 0
    buffers = router.trace_buffers()
    validate_span_log(buffers)   # would fail on a double-admit lifecycle
    closure = validate_fleet_closure(buffers)
    assert closure["rerouted"] == len(inflight)
    # exactly-once on the wire too: one route + one reroute span per
    # moved rid, never two reroutes
    spans = [e for b in buffers for e in b.events()]
    for rid in inflight:
        names = [e.name for e in spans if e.rid == rid]
        assert names.count("route") == 1
        assert names.count("reroute") == 1
    router.close()


def test_flapping_member_never_evicted_or_duplicated(rt):
    """Dropped replies below miss_limit (the worker is alive, the link
    flaps) must not evict: the member recovers on the next beat and no
    request is re-routed or re-admitted -- flapping is invisible in the
    output."""
    oracle = _oracle(rt, _requests(10))
    router = _fabric(rt, pods=2, min_pods=2, heartbeat_every=1,
                     miss_limit=4)
    reqs = _requests(10)
    router.submit(reqs)
    flappy = next(iter(router.members.values()))
    for _ in range(3):
        if not router.busy:
            break
        # drop this member's next 2 replies (heartbeat + step): the
        # worker still processes both messages, only the link is lossy
        flappy.transport.muted = 2
        router.step()
        assert flappy.pod_id in router.members, "flapping pod evicted"
        assert flappy.missed < router.miss_limit
        router.step()            # clean tick: beat lands, missed resets
        assert flappy.missed == 0
    _drain(router)
    assert all(r.state == "done" for r in reqs)
    assert {r.rid: list(r.tokens) for r in reqs} == oracle
    fab = router.status()["fabric"]
    assert fab["evictions"] == 0 and fab["reroutes"] == 0
    assert all(r.reroutes == 0 and r.preemptions == 0 for r in reqs)
    assert router.outstanding_total == 0
    validate_span_log(router.trace_buffers())
    router.close()


def test_draining_floor_and_infeasible_reject(rt):
    """Fleet-level placement edge cases: a request no member can EVER
    fit is rejected (terminal, reasoned) without wedging the fleet, and
    the elastic floor refuses to drop below min_pods."""
    router = _fabric(rt, pods=1, min_pods=1)
    huge = GenRequest(rid=0, prompt=np.arange(1, 80),
                      max_new_tokens=80)
    ok = GenRequest(rid=1, prompt=np.arange(1, 6), max_new_tokens=4)
    router.submit([huge, ok])
    _drain(router)
    assert huge.state == "rejected"
    assert huge.finish_reason == "oversized" and huge.error
    assert ok.state == "done" and len(ok.tokens) == 4
    assert router.outstanding_total == 0
    # reject is terminal at the ROUTER tier: closure still accounts it
    closure = validate_fleet_closure(router.trace_buffers())
    assert closure["routed"] == 1 and closure["closed"] == 1
    router.close()


# ---------------------------------------------------------------------------
# ledger conservation (the bugfix sweep)
# ---------------------------------------------------------------------------

def test_podrouter_ledger_settles_after_deadline_sheds(rt):
    """Regression: scheduler-tier deadline sheds never debited the
    PodRouter outstanding ledger, so a shed burst over-counted the pod
    forever and shortest-queue routed around it. After a drained run
    with sheds the ledger must be exactly zero."""
    from repro.orchestrator import PodRouter
    pod = Pod(rt, "stable", replicas=1, n_slots=1, max_len=64)
    router = PodRouter([pod])
    hog = GenRequest(rid=0, prompt=np.arange(1, 6), max_new_tokens=12)
    doomed = [GenRequest(rid=1 + i, prompt=np.arange(1, 6),
                         max_new_tokens=8, priority="batch",
                         deadline_ticks=2) for i in range(3)]
    router.submit([hog] + doomed)
    router.run(max_ticks=2000)
    assert hog.state == "done"
    assert all(r.state == "shed" and r.finish_reason == "deadline"
               for r in doomed)
    assert sum(router._outstanding.values()) == 0, \
        "deadline sheds leaked from the outstanding-token ledger"
    # the pod is still routable at its true (empty) load
    post = GenRequest(rid=50, prompt=np.arange(1, 6), max_new_tokens=4)
    router.submit(post)
    router.run(max_ticks=2000)
    assert post.state == "done"
    assert sum(router._outstanding.values()) == 0


def test_fabric_ledger_conserved_through_churn(rt):
    """The fabric ledger survives the full churn matrix -- completions,
    an eviction + reroutes, elastic spawn/retire -- and lands on zero."""
    router = _fabric(rt, pods=1, min_pods=1, max_pods=3,
                     scale_up_tokens=30, scale_idle_ticks=4)
    reqs = _requests(16)
    router.submit(reqs)
    _kill_mid_decode(router)
    _drain(router)
    assert all(r.state == "done" for r in reqs)
    assert router.outstanding_total == 0
    for _ in range(20):          # idle through drains + retires
        router.step()
    assert router.outstanding_total == 0
    assert len(router.members) >= router.min_pods
    router.close()
