# NOTE: no XLA_FLAGS here on purpose -- smoke tests and benches must see the
# real 1-CPU device. Only launch/dryrun.py forces 512 host devices, and only
# in its own process. Multi-device tests spawn subprocesses with the flag.
import sys
import types

import jax
import pytest

jax.config.update("jax_enable_x64", False)

# Property-based tests use hypothesis when available; in hermetic images
# without it we install a shim so the rest of the suite still collects and
# runs (the @given tests skip instead of killing collection).
try:
    import hypothesis  # noqa: F401
except ImportError:
    class _AnyStrategy:
        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

        def __or__(self, other):
            return self

    def _given(*a, **k):
        def deco(fn):
            def skipped(*args, **kwargs):
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco

    def _settings(*a, **k):
        return lambda fn: fn

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = types.ModuleType("hypothesis.strategies")
    _st = _AnyStrategy()
    _hyp.strategies.__getattr__ = lambda name: _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _hyp.strategies
