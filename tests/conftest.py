# NOTE: no XLA_FLAGS here on purpose -- smoke tests and benches must see the
# real 1-CPU device. Only launch/dryrun.py forces 512 host devices, and only
# in its own process. Multi-device tests spawn subprocesses with the flag.
import jax

jax.config.update("jax_enable_x64", False)
