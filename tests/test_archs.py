"""Per-architecture smoke tests (deliverable f): each assigned arch, reduced
config, one forward + one train step on CPU; asserts shapes + finite values.

The FULL configs are exercised only via the dry-run (no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import params as P
from repro.models.layers import padded_vocab
from repro.models.transformer import Model
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.train_step import TrainStepBuilder
from repro.core.abi import make_abi
from repro.dist.mesh import make_platform_mesh
from repro.dist.sharding import ShardingRules


@pytest.fixture(scope="module")
def mesh():
    return make_platform_mesh("local")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg, tp=1)
    prm = P.materialize(m.param_defs(), jax.random.key(0))
    B, S = 2, 16
    tok_len = S - cfg.frontend_len
    tokens = jax.random.randint(jax.random.key(1), (B, tok_len), 0,
                                cfg.vocab_size)
    fe = (jnp.full((B, cfg.frontend_len, cfg.d_model), 0.01, jnp.bfloat16)
          if cfg.frontend else None)
    logits, aux = m.forward(prm, tokens, frontend_embeds=fe)
    assert logits.shape == (B, S, padded_vocab(cfg.vocab_size))
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, mesh):
    cfg = get_config(arch).reduced()
    m = Model(cfg, tp=1)
    prm = P.materialize(m.param_defs(), jax.random.key(0))
    opt_state = adamw_init(prm)
    builder = TrainStepBuilder(model=m, mesh=mesh,
                               rules=ShardingRules.default(),
                               abi=make_abi("generic"),
                               opt=OptConfig(lr=1e-3, warmup_steps=1))
    step = jax.jit(builder.build())
    B, S = 2, 16
    tok_len = S - cfg.frontend_len
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (B, tok_len), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(2), (B, tok_len), 0,
                                     cfg.vocab_size),
    }
    if cfg.frontend:
        batch["frontend_embeds"] = jnp.full(
            (B, cfg.frontend_len, cfg.d_model), 0.01, jnp.bfloat16)
    new_prm, new_opt, metrics = step(prm, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(prm), jax.tree.leaves(new_prm))
    )
    assert moved
    assert int(new_opt["step"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_defs(arch):
    """Analytic param_count (roofline N) tracks the real tree within 2%
    at full scale (padding + block-diag deviations stay small)."""
    cfg = get_config(arch)
    m = Model(cfg, tp=1)
    real = P.count_params(m.param_defs())
    analytic = cfg.param_count()
    # vocab padding inflates the real tree; adjust analytic to padded vocab
    pad = padded_vocab(cfg.vocab_size) - cfg.vocab_size
    analytic += pad * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    assert abs(real - analytic) / analytic < 0.02, (real, analytic)
