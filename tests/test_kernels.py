"""Per-kernel validation: shape/dtype sweeps, interpret=True vs ref.py oracle
(deliverable c, kernel part)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ops import flash_attention, pick_blocks
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.matmul.ops import matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ref import ssd_ref

pytestmark = pytest.mark.kernels


def _tol(dt):
    return 3e-2 if dt == jnp.bfloat16 else 3e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_CASES = [
    # B, Hq, Hkv, Sq, Sk, d, causal, window, dtype
    (2, 4, 2, 128, 128, 64, True, 0, jnp.float32),
    (1, 8, 1, 256, 256, 32, True, 0, jnp.float32),     # MQA
    (2, 4, 4, 128, 256, 64, True, 64, jnp.float32),    # window + kv>q
    (1, 2, 2, 128, 128, 128, False, 0, jnp.float32),   # bidirectional
    (1, 4, 2, 256, 256, 64, True, 0, jnp.bfloat16),
    (1, 2, 1, 64, 192, 128, True, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FA_CASES, ids=str)
def test_flash_attention_vs_ref(case):
    B, Hq, Hkv, Sq, Sk, d, causal, window, dt = case
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, d), dt)
    k = jax.random.normal(ks[1], (B, Hkv, Sk, d), dt)
    v = jax.random.normal(ks[2], (B, Hkv, Sk, d), dt)
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    err = float(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)).max())
    assert err < _tol(dt), err


def test_flash_attention_block_shapes_sweep():
    """Block shape must not change results (pure schedule parameter)."""
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    ref = flash_attention_ref(q, k, v, causal=True)
    for bq, bk in [(32, 32), (64, 128), (128, 64), (256, 256)]:
        out = flash_attention_fwd(q, k, v, causal=True, block_q=bq,
                                  block_k=bk, interpret=True)
        assert float(jnp.abs(out - ref).max()) < 3e-5, (bq, bk)


def test_flash_attention_grad_matches_ref_grad():
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 32))
    k = jax.random.normal(ks[1], (1, 2, 64, 32))
    v = jax.random.normal(ks[2], (1, 2, 64, 32))

    def f(fn):
        return jax.grad(lambda q_: fn(q_, k, v).sum())(q)

    g_kernel = f(lambda q_, k_, v_: flash_attention(q_, k_, v_, True, 0))
    g_ref = f(lambda q_, k_, v_: flash_attention_ref(q_, k_, v_, causal=True))
    assert float(jnp.abs(g_kernel - g_ref).max()) < 1e-4


def test_pick_blocks_tile_invariant():
    for sq, sk, d in [(4096, 4096, 128), (100, 300, 64), (32768, 32768, 256)]:
        bq, bk = pick_blocks(sq, sk, d)
        assert sq % bq == 0 and sk % bk == 0


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------

RG_CASES = [(2, 64, 128, 16, 128), (1, 100, 256, 25, 128), (3, 32, 512, 32, 256),
            (1, 128, 128, 128, 128)]


@pytest.mark.parametrize("case", RG_CASES, ids=str)
def test_rglru_vs_ref(case):
    B, S, R, bs, br = case
    a = jax.nn.sigmoid(jax.random.normal(jax.random.key(0), (B, S, R)))
    b = jax.random.normal(jax.random.key(1), (B, S, R))
    out = rglru_scan_pallas(a, b, block_s=bs, block_r=br, interpret=True)
    ref = rglru_scan_ref(a, b)
    assert float(jnp.abs(out - ref).max()) < 1e-5


def test_rglru_grad_matches_ref_grad():
    a = jax.nn.sigmoid(jax.random.normal(jax.random.key(2), (2, 32, 128)))
    b = jax.random.normal(jax.random.key(3), (2, 32, 128))
    g = jax.random.normal(jax.random.key(4), (2, 32, 128))
    da1, db1 = jax.vjp(rglru_scan, a, b)[1](g)
    da2, db2 = jax.vjp(lambda x, y: rglru_scan_ref(x, y), a, b)[1](g)
    assert float(jnp.abs(da1 - da2).max()) < 1e-5
    assert float(jnp.abs(db1 - db2).max()) < 1e-5


# ---------------------------------------------------------------------------
# ssd
# ---------------------------------------------------------------------------

SSD_CASES = [(2, 64, 4, 32, 32, 16, 2), (1, 128, 8, 64, 128, 32, 8),
             (2, 96, 2, 16, 64, 32, 2), (1, 256, 4, 64, 128, 64, 4)]


@pytest.mark.parametrize("case", SSD_CASES, ids=str)
def test_ssd_vs_ref(case):
    b, S, H, P, N, chunk, bh = case
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(0.5 * jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, S, N))
    C = jax.random.normal(ks[4], (b, S, N))
    out = ssd_pallas(x, dt, A, B, C, chunk=chunk, block_h=bh, interpret=True)
    ref = ssd_ref(x, dt, A, B, C)
    rel = float(jnp.abs(out - ref).max()) / (float(jnp.abs(ref).max()) + 1e-9)
    assert rel < 1e-4, rel


def test_ssd_matches_model_chunked_xla():
    """Kernel and the model's XLA SSD are the same algorithm."""
    from repro.models.ssm import _ssd_chunked
    ks = jax.random.split(jax.random.key(9), 5)
    b, S, H, P, N = 2, 64, 4, 32, 64
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(0.5 * jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, S, N))
    C = jax.random.normal(ks[4], (b, S, N))
    y_kernel = ssd_pallas(x, dt, A, B, C, chunk=16, block_h=2, interpret=True)
    y_xla, _ = _ssd_chunked(x, dt, A, B, C, 16)
    assert float(jnp.abs(y_kernel - y_xla).max()) < 1e-4


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

MM_CASES = [(256, 256, 256, jnp.float32), (128, 384, 256, jnp.bfloat16),
            (64, 64, 64, jnp.float32), (512, 128, 256, jnp.bfloat16)]


@pytest.mark.parametrize("case", MM_CASES, ids=str)
def test_matmul_vs_ref(case):
    M, K, N, dt = case
    a = jax.random.normal(jax.random.key(0), (M, K), dt)
    b = jax.random.normal(jax.random.key(1), (K, N), dt)
    out = matmul(a, b)
    ref = matmul_ref(a, b)
    err = float(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)).max())
    assert err < (2.0 if dt == jnp.bfloat16 else 1e-3)
