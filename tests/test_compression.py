"""PowerSGD gradient compression: math invariants, training behaviour,
wire-bytes reduction in the compiled HLO."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.abi import make_abi
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dist.mesh import make_platform_mesh
from repro.dist.sharding import ShardingRules
from repro.models import params as P
from repro.models.transformer import Model
from repro.train.compression import (_compressible, powersgd_init,
                                     powersgd_sync)
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.train_step import TrainStepBuilder


def test_compressible_predicate():
    r = 4
    assert _compressible(jnp.zeros((256, 256)), r)
    assert not _compressible(jnp.zeros((256,)), r)          # 1D
    assert not _compressible(jnp.zeros((8, 8)), r)          # too small
    assert _compressible(jnp.zeros((64, 4, 32)), r)         # collapsed 3D


def test_rank_r_matrix_recovered_exactly():
    """A gradient that IS rank-r is transmitted losslessly (up to fp)."""
    key = jax.random.key(0)
    m, n, r = 64, 96, 4
    a = jax.random.normal(key, (m, r))
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, r))
    g = {"w": a @ b.T}
    st = powersgd_init(g, r)
    # a couple of power iterations refine Q
    out = g
    for _ in range(3):
        out, st = powersgd_sync(g, st, (), r)
    err = float(jnp.abs(out["w"] - g["w"]).max())
    assert err < 1e-3, err
    # and the error buffer is near zero
    assert float(jnp.abs(st["err"]["w"]).max()) < 1e-3


def test_error_feedback_conservation():
    """The EF identity: sum(transmitted) + error_k == k*G exactly
    (telescoping of e_t = (G + e_{t-1}) - out_t) -- nothing is ever
    silently dropped, only delayed."""
    key = jax.random.key(1)
    g = {"w": jax.random.normal(key, (64, 64))}
    st = powersgd_init(g, 2)
    total = jnp.zeros_like(g["w"])
    k = 10
    for _ in range(k):
        out, st = powersgd_sync(g, st, (), 2)
        total = total + out["w"]
    lhs = total + st["err"]["w"]
    rel = float(jnp.linalg.norm(lhs - k * g["w"])
                / jnp.linalg.norm(k * g["w"]))
    assert rel < 1e-4, rel


def test_training_with_powersgd_converges():
    cfg = get_config("llama3.2-3b").reduced()
    mesh = make_platform_mesh("local")
    m = Model(cfg, tp=1, act_dtype=jnp.float32)
    prm = P.materialize(m.param_defs(), jax.random.key(0))
    opt = OptConfig(lr=5e-3, warmup_steps=2, total_steps=100)
    abi = make_abi("host", mode="explicit", zero1=False,
                   grad_compression="float32", hierarchical=False,
                   compression="powersgd", rank=8)
    b = TrainStepBuilder(model=m, mesh=mesh, rules=ShardingRules.default(),
                         abi=abi, opt=opt)
    step = jax.jit(b.build())
    st = adamw_init(prm)
    comm = powersgd_init(prm, 8)
    st["comm"] = {"q": jax.tree.map(lambda a: a[None], comm["q"]),
                  "err": jax.tree.map(lambda a: a[None], comm["err"])}
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=8, seed=3))
    losses = []
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        prm, st, metrics = step(prm, st, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


MULTIDEV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from repro.train.compression import powersgd_init, powersgd_sync
from repro.launch.analysis import parse_collectives

from repro.dist.compat import shard_map
if hasattr(jax.sharding, "AxisType"):
    mesh = jax.make_mesh((4,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
else:
    mesh = jax.make_mesh((4,), ("data",))
g = {"w": jax.random.normal(jax.random.key(0), (512, 512))}
st = powersgd_init(g, 4)

def plain(gl):
    return jax.tree.map(lambda x: jax.lax.pmean(x, "data"), gl)

def psgd(gl, stl):
    return powersgd_sync(gl, stl, ("data",), 4)

from jax.sharding import PartitionSpec as Psp
sm_plain = shard_map(plain, mesh=mesh, in_specs=(Psp(),),
                     out_specs=Psp(), axis_names={"data"},
                     check_vma=False)
sm_psgd = shard_map(psgd, mesh=mesh, in_specs=(Psp(), Psp()),
                    out_specs=(Psp(), Psp()), axis_names={"data"},
                    check_vma=False)
co_plain = jax.jit(sm_plain).lower(g).compile()
co_psgd = jax.jit(sm_psgd).lower(g, st).compile()
w_plain = parse_collectives(co_plain.as_text()).wire_bytes
w_psgd = parse_collectives(co_psgd.as_text()).wire_bytes
# one numeric run: compressed mean of identical shards == rank-4 approx
out, _ = sm_psgd(g, st)
assert jnp.isfinite(out["w"]).all()
print("WIRE", w_plain, w_psgd)
# dense 512x512 AR vs two (512,4) pmeans: expect >30x reduction
assert w_psgd < w_plain / 30, (w_plain, w_psgd)
print("PSGD_WIRE_OK")
"""


def test_powersgd_cuts_wire_bytes_multidevice():
    r = subprocess.run([sys.executable, "-c", MULTIDEV],
                       capture_output=True, text=True,
                       env={**__import__("os").environ, "PYTHONPATH": "src"},
                       cwd=".")
    assert "PSGD_WIRE_OK" in r.stdout, r.stdout + r.stderr[-2000:]
