"""Mixed precision (bf16 params + f32 master) and bf16 score path: the
§Perf iteration features must preserve training semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.abi import make_abi
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dist.mesh import make_platform_mesh
from repro.dist.sharding import ShardingRules
from repro.models import params as P
from repro.models import attention as A
from repro.models.transformer import Model
from repro.train.optimizer import OptConfig, adamw_init, adamw_update
from repro.train.train_step import TrainStepBuilder


def test_master_weights_match_f32_training():
    """bf16 params + f32 master must track pure-f32 training closely."""
    cfg = get_config("llama3.2-3b").reduced()
    mesh = make_platform_mesh("local")
    m32 = Model(cfg, tp=1, act_dtype=jnp.float32)
    p32 = P.materialize(m32.param_defs(), jax.random.key(0))
    pbf = jax.tree.map(lambda x: x.astype(jnp.bfloat16), p32)

    opt = OptConfig(lr=5e-3, warmup_steps=1, total_steps=50)
    b = TrainStepBuilder(model=m32, mesh=mesh, rules=ShardingRules.default(),
                         abi=make_abi("generic"), opt=opt)
    step = jax.jit(b.build())
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=4, seed=5))

    st32 = adamw_init(p32)
    stbf = adamw_init(pbf, with_master=True)
    l32, lbf = [], []
    for i in range(8):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        p32, st32, m1 = step(p32, st32, batch)
        pbf, stbf, m2 = step(pbf, stbf, batch)
        l32.append(float(m1["loss"]))
        lbf.append(float(m2["loss"]))
    # master accumulates in f32: trajectories must stay close in bf16 terms
    assert abs(l32[-1] - lbf[-1]) < 0.05, (l32, lbf)
    # params stay bf16, master stays f32
    assert jax.tree.leaves(pbf)[0].dtype == jnp.bfloat16
    assert jax.tree.leaves(stbf["master"])[0].dtype == jnp.float32


def test_master_weights_avoid_bf16_stall():
    """Tiny updates vanish in pure-bf16 params but accumulate in the master
    (the reason master weights exist)."""
    p = {"w": jnp.ones((8,), jnp.bfloat16)}
    opt = OptConfig(lr=1e-4, warmup_steps=0, weight_decay=0.0, grad_clip=1e9)
    g = {"w": jnp.full((8,), 1e-3, jnp.float32)}

    st_plain = adamw_init(p)
    st_master = adamw_init(p, with_master=True)
    p_plain, p_master = p, p
    for _ in range(64):
        p_plain, st_plain, _ = adamw_update(p_plain, g, st_plain, opt)
        p_master, st_master, _ = adamw_update(p_master, g, st_master, opt)
    moved_master = float(jnp.abs(
        st_master["master"]["w"] - 1.0).max())
    assert moved_master > 1e-4          # master integrates the tiny steps
    # and the bf16 params eventually reflect the accumulated change
    assert float(jnp.abs(p_master["w"].astype(jnp.float32) - 1.0).max()) > 0


@pytest.mark.parametrize("window", [0, 24])
def test_bf16_score_path_close_to_f32(window):
    B, S, Hkv, G, hd = 2, 96, 2, 2, 32
    q = jax.random.normal(jax.random.key(0), (B, S, Hkv * G, hd), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (B, S, Hkv, hd), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (B, S, Hkv, hd), jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    o32 = A.attend(q, k, v, pos, pos, window)
    o16 = A.attend(q, k, v, pos, pos, window, score_dtype=jnp.bfloat16)
    err = float(jnp.abs(o32.astype(jnp.float32) - o16.astype(jnp.float32)).max())
    assert err < 0.06, err


def test_bf16_scores_full_model_close():
    cfg = get_config("musicgen-medium").reduced()
    m_f32 = Model(cfg, tp=1)
    m_bf = Model(cfg.with_overrides(attn_score_dtype="bfloat16"), tp=1)
    prm = P.materialize(m_f32.param_defs(), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    fe = jnp.full((2, cfg.frontend_len, cfg.d_model), 0.01, jnp.bfloat16)
    l1, _ = m_f32.forward(prm, toks, frontend_embeds=fe)
    l2, _ = m_bf.forward(prm, toks, frontend_embeds=fe)
    err = float(jnp.abs(l1.astype(jnp.float32) - l2.astype(jnp.float32)).max())
    assert err < 0.1, err
