"""Distribution-layer invariants: logical rules, safe specs, attention
geometry for every assigned arch at TP=16, MoE parity (pure vs shard_map)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.core.container import _safe_spec
from repro.dist.mesh import PLATFORMS, batch_axes, make_platform_mesh
from repro.dist.sharding import ShardingRules
from repro.models.attention import resolve_geometry
from repro.models.layers import padded_vocab
from repro.models.moe import moe_forward, moe_forward_spmd


@pytest.fixture(scope="module")
def mesh():
    return make_platform_mesh("local")


# ---------------------------------------------------------------------------
# attention geometry: padding + kv replication for every assigned arch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).n_heads > 0])
@pytest.mark.parametrize("tp", [1, 8, 16])
def test_geometry_invariants(arch, tp):
    cfg = get_config(arch)
    g = resolve_geometry(cfg, tp)
    assert g.n_q % tp == 0                  # q heads shard
    assert g.n_kv % tp == 0 or g.n_kv == g.n_q  # kv shard (or padded MHA)
    assert g.n_q % g.n_kv == 0              # grouping is integral
    assert g.n_q >= cfg.n_heads             # padding only ever adds
    if tp == 1:
        assert g.n_q == cfg.n_heads         # canonical at no TP
        assert g.n_kv == cfg.n_kv_heads


def test_geometry_padding_overhead_bounded():
    """Head padding must stay below 2x (it is honest, counted compute)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        if not cfg.n_heads:
            continue
        g = resolve_geometry(cfg, 16)
        assert g.n_q <= 2 * cfg.n_heads, (arch, g)


@given(h=st.integers(1, 128), kv=st.integers(1, 128),
       tp=st.sampled_from([1, 2, 4, 8, 16]))
@settings(max_examples=200, deadline=None)
def test_property_geometry_always_valid(h, kv, tp):
    if h % kv:                              # GQA requires kv | h canonically
        kv = max(1, h // max(1, h // kv))
        if h % kv:
            return
    cfg = get_config("llama3.2-3b").with_overrides(
        n_heads=h, n_kv_heads=kv, head_dim=64)
    g = resolve_geometry(cfg, tp)
    assert g.n_q % tp == 0
    assert g.n_q % g.n_kv == 0
    assert g.n_kv % tp == 0 or g.n_kv >= g.n_q


# ---------------------------------------------------------------------------
# vocab padding
# ---------------------------------------------------------------------------

@given(v=st.integers(1, 1_000_000))
@settings(max_examples=100, deadline=None)
def test_property_padded_vocab(v):
    vp = padded_vocab(v)
    assert vp >= v and vp % 128 == 0 and vp - v < 128


# ---------------------------------------------------------------------------
# safe specs: never produce a non-divisible sharding
# ---------------------------------------------------------------------------

@given(dim0=st.integers(1, 300), dim1=st.integers(1, 300))
@settings(max_examples=100, deadline=None)
def test_property_safe_spec_divisibility(dim0, dim1):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = ShardingRules.default()
    spec = _safe_spec((dim0, dim1), ("batch", "mlp"), mesh, rules)
    for d, e in zip((dim0, dim1), spec):
        if e is None:
            continue
        axes = (e,) if isinstance(e, str) else e
        k = 1
        for a in axes:
            k *= mesh.shape[a]
        assert d % k == 0


def test_rules_map_known_axes(mesh):
    rules = ShardingRules.default()
    assert rules.mesh_axes(("batch", None, "mlp"), mesh) == P(("data",), None,
                                                              "model")
    # fsdp adds embed -> batch axes
    fr = ShardingRules.default(fsdp=True)
    assert fr.rules["embed"] == ("pod", "data")


def test_rules_no_axis_reuse_within_spec(mesh):
    """One mesh axis must not shard two dims of the same tensor."""
    rules = ShardingRules.default().with_(embed="model")
    spec = rules.mesh_axes(("embed", "mlp"), mesh)   # both want "model"
    used = [a for e in spec if e for a in ((e,) if isinstance(e, str) else e)]
    assert len(used) == len(set(used))


# ---------------------------------------------------------------------------
# MoE: pure-XLA vs shard_map paths agree (tp=1 mesh executes both)
# ---------------------------------------------------------------------------

def test_moe_spmd_matches_pure(mesh):
    cfg = get_config("moonshot-v1-16b-a3b").reduced().with_overrides(
        capacity_factor=8.0)
    from repro.models.moe import moe_defs
    from repro.models import params as PM
    p = PM.materialize(moe_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    y1, a1 = moe_forward(p, x, cfg)
    y2, a2 = moe_forward_spmd(p, x, cfg, mesh)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    assert float(a1) == pytest.approx(float(a2), abs=1e-5)


def test_moe_capacity_drops_tokens():
    """Tiny capacity must drop tokens (outputs change), dropless must not."""
    cfg = get_config("llama4-scout-17b-a16e").reduced()
    from repro.models.moe import moe_defs, capacity
    from repro.models import params as PM
    assert capacity(cfg.with_overrides(capacity_factor=99.0), 64) >= 64
    p = PM.materialize(moe_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    y_tight, _ = moe_forward(p, x, cfg.with_overrides(capacity_factor=0.1))
    y_loose, _ = moe_forward(p, x, cfg.with_overrides(capacity_factor=16.0))
    assert float(jnp.abs(y_tight - y_loose).max()) > 1e-4


# ---------------------------------------------------------------------------
# multi-device paths (subprocess with forced host devices)
# ---------------------------------------------------------------------------

MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.moe import moe_defs, moe_forward, moe_forward_spmd
from repro.models import params as PM

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("moonshot-v1-16b-a3b").reduced().with_overrides(
    n_experts=4, top_k=2, capacity_factor=8.0)
p = PM.materialize(moe_defs(cfg), jax.random.key(0))
x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model))
y1, a1 = moe_forward(p, x, cfg)
y2, a2 = jax.jit(lambda p_, x_: moe_forward_spmd(p_, x_, cfg, mesh))(p, x)
err = float(jnp.abs(y1 - y2).max())
assert err < 2e-4, err
print("MOE_TP_OK", err)
"""


def test_moe_spmd_multidevice_parity(tmp_path):
    import subprocess, sys
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                       capture_output=True, text=True,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"}, cwd=".")
    assert "MOE_TP_OK" in r.stdout, r.stdout + r.stderr
