"""Training substrate: optimizer math, grad accumulation, ABI parity,
loss goes down, restart determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.abi import make_abi
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dist.mesh import make_platform_mesh
from repro.dist.sharding import ShardingRules
from repro.models import params as P
from repro.models.transformer import Model
from repro.train.optimizer import OptConfig, adamw_init, adamw_update, lr_at
from repro.train.train_step import TrainStepBuilder, cross_entropy


@pytest.fixture(scope="module")
def mesh():
    return make_platform_mesh("local")


def setup(arch="llama3.2-3b", **opt_kw):
    cfg = get_config(arch).reduced()
    m = Model(cfg, tp=1, act_dtype=jnp.float32)
    prm = P.materialize(m.param_defs(), jax.random.key(0))
    opt = OptConfig(lr=1e-2, warmup_steps=2, total_steps=100, **opt_kw)
    return cfg, m, prm, opt


def make_batch(cfg, step=0, B=4, S=16):
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=S,
                                  global_batch=B, seed=3))
    return {k: jnp.asarray(v) for k, v in data.batch(step).items()}


# ---------------------------------------------------------------------------
# optimizer unit behaviour
# ---------------------------------------------------------------------------

def test_lr_schedule_shape():
    opt = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    lrs = [float(lr_at(opt, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < 0.2
    assert abs(lrs[9] - 1.0) < 0.11
    assert lrs[99] < 0.2
    assert max(lrs) <= 1.0 + 1e-6


def test_grad_clip_engages():
    opt = OptConfig(lr=1e-2, grad_clip=1.0)
    p = {"w": jnp.ones((4,))}
    st = adamw_init(p)
    g_small = {"w": jnp.full((4,), 0.1)}
    g_huge = {"w": jnp.full((4,), 1e3)}
    p1, _, m1 = adamw_update(p, g_small, st, opt)
    p2, _, m2 = adamw_update(p, g_huge, st, opt)
    # clipped huge grads move params comparably to small grads (same sign)
    assert float(m2["grad_norm"]) > float(m1["grad_norm"])
    assert np.isfinite(np.asarray(p2["w"])).all()
    assert float(jnp.abs(p2["w"] - p["w"]).max()) < 0.05


def test_weight_decay_pulls_to_zero():
    opt = OptConfig(lr=1e-1, weight_decay=1.0, warmup_steps=0)
    p = {"w": jnp.full((4,), 10.0)}
    st = adamw_init(p)
    g = {"w": jnp.zeros((4,))}
    p2, _, _ = adamw_update(p, g, st, opt)
    assert float(p2["w"][0]) < 10.0


# ---------------------------------------------------------------------------
# cross entropy
# ---------------------------------------------------------------------------

def test_cross_entropy_masks_padded_vocab():
    B, S, V, Vp = 2, 4, 10, 16
    logits = jnp.zeros((B, S, Vp)).at[..., V:].set(1e9)  # junk in padding
    labels = jnp.zeros((B, S), jnp.int32)
    loss = cross_entropy(logits, labels, V)
    assert abs(float(loss) - np.log(V)) < 1e-3           # uniform over V


def test_cross_entropy_loss_mask():
    B, S, V = 1, 4, 8
    logits = jnp.zeros((B, S, V))
    labels = jnp.zeros((B, S), jnp.int32)
    mask = jnp.asarray([[1, 1, 0, 0]], jnp.float32)
    full = cross_entropy(logits, labels, V)
    half = cross_entropy(logits, labels, V, mask)
    assert abs(float(full) - float(half)) < 1e-6          # uniform anyway
    # degenerate all-masked batch stays finite
    none = cross_entropy(logits, labels, V, jnp.zeros((B, S)))
    assert np.isfinite(float(none))


# ---------------------------------------------------------------------------
# gradient accumulation == big batch
# ---------------------------------------------------------------------------

def test_grad_accum_equivalence(mesh):
    cfg, m, prm, opt = setup()
    batch = make_batch(cfg, B=8)
    outs = {}
    for mb in (1, 2, 4):
        b = TrainStepBuilder(model=m, mesh=mesh, rules=ShardingRules.default(),
                             abi=make_abi("generic"), opt=opt, microbatches=mb)
        st = adamw_init(prm)
        p2, _, metrics = jax.jit(b.build())(prm, st, batch)
        outs[mb] = (p2, float(metrics["loss"]))
    for mb in (2, 4):
        assert abs(outs[mb][1] - outs[1][1]) < 1e-4
        diffs = [float(jnp.abs(a - b).max()) for a, b in
                 zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[mb][0]))]
        assert max(diffs) < 1e-4, (mb, max(diffs))


# ---------------------------------------------------------------------------
# ABI parity: generic (implicit) vs host (explicit shard_map) on 1 device
# ---------------------------------------------------------------------------

def test_abi_generic_vs_host_parity(mesh):
    cfg, m, prm, opt = setup()
    batch = make_batch(cfg)
    res = {}
    for name in ("generic", "host"):
        abi = make_abi(name) if name == "generic" else make_abi(
            "host", zero1=False, grad_compression="float32",
            hierarchical=True, mode="explicit")
        b = TrainStepBuilder(model=m, mesh=mesh,
                             rules=ShardingRules.default(), abi=abi, opt=opt)
        st = adamw_init(prm)
        p2, _, metrics = jax.jit(b.build())(prm, st, batch)
        res[name] = (p2, float(metrics["loss"]))
    assert abs(res["generic"][1] - res["host"][1]) < 1e-5
    diffs = [float(jnp.abs(a - b).max()) for a, b in
             zip(jax.tree.leaves(res["generic"][0]),
                 jax.tree.leaves(res["host"][0]))]
    assert max(diffs) < 1e-5


def test_abi_bf16_compression_close_to_fp32(mesh):
    cfg, m, prm, opt = setup()
    batch = make_batch(cfg)
    losses = {}
    for wire in ("float32", "bfloat16"):
        abi = make_abi("host", zero1=False, grad_compression=wire,
                       hierarchical=False, mode="explicit")
        b = TrainStepBuilder(model=m, mesh=mesh,
                             rules=ShardingRules.default(), abi=abi, opt=opt)
        st = adamw_init(prm)
        p2, _, metrics = jax.jit(b.build())(prm, st, batch)
        losses[wire] = p2
    # single device: pmean is identity, so compression is a dtype roundtrip
    diffs = [float(jnp.abs(a - b).max()) for a, b in
             zip(jax.tree.leaves(losses["float32"]),
                 jax.tree.leaves(losses["bfloat16"]))]
    assert max(diffs) < 5e-2


# ---------------------------------------------------------------------------
# end to end: loss down + deterministic restart
# ---------------------------------------------------------------------------

def test_loss_decreases(mesh):
    cfg, m, prm, opt = setup()
    b = TrainStepBuilder(model=m, mesh=mesh, rules=ShardingRules.default(),
                         abi=make_abi("generic"), opt=opt)
    step = jax.jit(b.build(), donate_argnums=(0, 1))
    st = adamw_init(prm)
    losses = []
    for i in range(25):
        prm, st, metrics = step(prm, st, make_batch(cfg, i, B=8))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[:3] + losses[-3:]


def test_restart_determinism(mesh):
    """Same data + same params at step k -> identical next step (the
    checkpoint/restart contract of the deterministic pipeline)."""
    cfg, m, prm, opt = setup()
    b = TrainStepBuilder(model=m, mesh=mesh, rules=ShardingRules.default(),
                         abi=make_abi("generic"), opt=opt)
    step = jax.jit(b.build())
    st = adamw_init(prm)
    p1, st1, _ = step(prm, st, make_batch(cfg, 0))
    p1b, st1b, _ = step(prm, st, make_batch(cfg, 0))
    diffs = [float(jnp.abs(a - c).max()) for a, c in
             zip(jax.tree.leaves(p1), jax.tree.leaves(p1b))]
    assert max(diffs) == 0.0
