"""Known-good step-path patterns the rule must pass."""
import time


class Scheduler:
    def __init__(self):
        self._draining = set()
        self.decode_s = 0.0
        self.tick = 0

    def step(self):
        # sanctioned reporting-only duration pattern
        t0 = time.perf_counter()
        for slot in sorted(self._draining):   # sorted: deterministic
            pass
        self.tick += 1
        self.decode_s += time.perf_counter() - t0
        return self.tick
