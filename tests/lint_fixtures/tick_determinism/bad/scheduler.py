"""Seeded violations for the tick-determinism rule (named scheduler.py so
the step-path scope applies)."""
import random
import time


class Scheduler:
    def __init__(self):
        self._draining = set()
        self.started = time.time()      # __init__ is exempt: fine

    def step(self):
        now = time.time()               # BAD: wall clock in a step path
        jitter = random.random()        # BAD: unseeded random draw
        for slot in self._draining:     # BAD: unordered set iteration
            pass
        for slot in {1, 2, 3}:          # BAD: set literal iteration
            pass
        elapsed = time.perf_counter()   # BAD: not the t0/_s pattern
        return now + jitter + elapsed
