"""Known-good pool usage: everything goes through PagePool methods."""


class Scheduler:
    def admit(self, pool, slot, need, page_size):
        pool.reserve(slot, need)
        pool.alloc_upto(slot, need * page_size - 1)
        # reads of internals are fine -- only mutation is restricted
        depth = len(pool.free)
        pool.check()
        return depth
