"""Seeded violations for the pool-mutation rule."""


class Scheduler:
    def admit(self, pool, slot, page):
        pool.refcount[page] += 1        # BAD: refcount poked directly
        pool.free.append(page)          # BAD: free-list mutated directly
        pool.reserved[slot] = 0         # BAD: reservation zeroed directly
