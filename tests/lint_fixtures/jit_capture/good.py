"""Known-good jit patterns the rule must pass."""
import jax


class Engine:
    def lower(self):
        # state flows through traced arguments, not the closure
        step = jax.jit(lambda cache, toks: (cache, toks))
        g = jax.jit(self._fn, static_argnums=(1,))
        # tuples are hashable static args
        return step, g(self.params, (1, 2, 3))

    def lower_immutable(self):
        # capturing construction-time immutables is fine
        return jax.jit(lambda x: x * self.scale)
