"""Seeded violations for the jit-capture rule."""
import jax


class Engine:
    def lower(self):
        # BAD: lambda captures per-tick mutable state
        step = jax.jit(lambda t: t + self.pos)
        g = jax.jit(self._fn, static_argnums=(1,))
        # BAD: unhashable list literal at a static position
        return step, g(self.params, [1, 2, 3])

    def lower_nested(self):
        def fn(t):
            # BAD: locally-defined closure captures the decode cursor
            return t + self.cur_tok
        return jax.jit(fn)
