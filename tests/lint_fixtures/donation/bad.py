"""Seeded violations for the donation rule: use-after-donation and a
donated prefix pool. Linted by tests/test_lint.py, never imported."""
import jax

_step = jax.jit(lambda c, t: (c, t), donate_argnums=0)


class Engine:
    def tick(self, toks):
        self.cache, out = _step(self.cache, toks)   # rebind: fine
        _step(self.cache, toks)                     # donates, no rebind
        return self.cache.sum()                     # BAD: use after donation


def lower_pool_step(aparams, pool, toks):
    fitted = jax.jit(lambda a, p, t: t, donate_argnums=(1,))
    return fitted.lower(aparams, pool, toks)        # BAD: donates the pool
