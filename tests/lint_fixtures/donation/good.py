"""Known-good donation patterns the rule must pass."""
import jax

_step = jax.jit(lambda c, t: (c, t), donate_argnums=0)


class Engine:
    def tick(self, toks):
        # canonical shape: donate and rebind in one statement
        self.cache, out = _step(self.cache, toks)
        return out


def lower_pool_step(aparams, pool, toks):
    # prefix path: the pool is read, so it is lowered WITHOUT donation
    fitted = jax.jit(lambda a, p, t: t)
    return fitted.lower(aparams, pool, toks)
