"""Seeded violations for the metrics-writer rule."""
TICK_HIST = dict(width=1, n_buckets=4096)


def record_completion(metrics, done, base):
    # BAD: completion histogram recorded outside observe_completion
    metrics.histogram("latency_ticks", **TICK_HIST).record(done - base)


def record_ttft(metrics, v):
    h = metrics.histogram("ttft_ticks", **TICK_HIST)
    h.record(v)                         # BAD: bound-name write


def count_done(metrics):
    metrics.counter("requests_completed").inc()     # BAD: protected counter


def label_explosion(metrics, rid):
    # BAD: f-string label -> one registry series per request
    metrics.counter("fixture_requests", req=f"req-{rid}").inc()


def kind_collision(metrics):
    metrics.counter("fixture_depth").inc()
    metrics.gauge("fixture_depth").set(3)           # BAD: counter vs gauge
