"""Known-good metrics patterns the rule must pass."""
TICK_HIST = dict(width=1, n_buckets=4096)


def bind(metrics, replica):
    # eager registration (no .record) of a protected name is fine --
    # that is how schedulers surface empty histograms to repro top
    metrics.histogram("latency_ticks", **TICK_HIST)
    # unprotected metrics may be written anywhere, with bounded labels
    metrics.counter("fixture_tokens_wasted", replica=replica).inc(4)
    metrics.histogram("fixture_queue_wait", **TICK_HIST).record(3)
