"""Seeded violations for the span-lifecycle rule (named scheduler.py so
the orchestrator closure check applies)."""


class Scheduler:
    def step(self, trace, rid, tick):
        trace.record(rid, "submit", tick, arrival=tick)
        trace.record(rid, "admit", tick)
        trace.record(rid, "prefill", tick)
        # BAD: preempt with no resume/shed/reject anywhere -> lifecycles
        # entering the preempted state get stuck
        trace.record(rid, "preempt", tick)
        # BAD: unknown span kind
        trace.record(rid, "blorp", tick)
