"""Known-good span emissions: a closed, legal lifecycle."""


class Scheduler:
    def step(self, trace, rid, tick):
        trace.record(rid, "submit", tick, arrival=tick)
        trace.record(rid, "admit", tick)
        trace.record(rid, "prefill", tick)
        trace.record(rid, "decode_chunk", tick, chunk=4)
        trace.record(rid, "complete", tick, tokens=5)
