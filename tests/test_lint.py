"""Checker-framework suite: every rule must flag its seeded-violation
fixture and pass its known-good twin, suppressions and baselines must
filter, the CLI must exit with the documented codes -- and the current
tree itself must lint clean (the acceptance criterion, enforced here so
a regression fails tier-1 before it fails the CI lint job).

Fixtures live under ``tests/lint_fixtures/`` -- EXCLUDED from directory
scans (so the seeded violations never fail a tree-wide run) but linted
here by explicit path, which bypasses the exclusion.
"""

from pathlib import Path

import pytest

from repro.analysis import all_checks, load_baseline, run_lint, write_baseline
from repro.analysis.core import main as lint_main
from repro.cli import main as cli_main

pytestmark = pytest.mark.orchestrator

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "lint_fixtures"

# rule id -> (known-bad fixture, known-good fixture). The scoped rules
# (span closure, tick determinism) use files NAMED scheduler.py so the
# orchestrator-path scoping applies to the fixture.
CASES = {
    "donation": ("donation/bad.py", "donation/good.py"),
    "metrics-writer": ("metrics_writer/bad.py", "metrics_writer/good.py"),
    "span-lifecycle": ("span_lifecycle/bad/scheduler.py",
                       "span_lifecycle/good/scheduler.py"),
    "pool-mutation": ("pool_mutation/bad.py", "pool_mutation/good.py"),
    "jit-capture": ("jit_capture/bad.py", "jit_capture/good.py"),
    "tick-determinism": ("tick_determinism/bad/scheduler.py",
                         "tick_determinism/good/scheduler.py"),
}


def test_every_rule_has_a_fixture_case():
    assert {c.rule for c in all_checks()} == set(CASES)


@pytest.mark.parametrize("rule", sorted(CASES))
def test_rule_flags_bad_and_passes_good(rule):
    bad, good = CASES[rule]
    res = run_lint([str(FIXTURES / bad)], rules=[rule])
    assert res.errors >= 1, f"{rule} missed its seeded violation"
    assert all(f.rule == rule for f in res.findings)
    assert all(f.line >= 1 and f.file for f in res.findings)
    res = run_lint([str(FIXTURES / good)], rules=[rule])
    assert res.findings == [], \
        f"{rule} false-positives on its known-good fixture: " \
        f"{[f.render() for f in res.findings]}"


def test_findings_carry_location_and_hint():
    res = run_lint([str(FIXTURES / "pool_mutation" / "bad.py")],
                   rules=["pool-mutation"])
    f = res.findings[0]
    assert f.file.endswith("bad.py") and f.line > 1
    assert "refcount" in f.message
    assert f.hint                      # every check ships a fix hint
    assert f"{f.file}:{f.line}" in f.render()
    assert "[pool-mutation]" in f.render()


# ---------------------------------------------------------------------------
# suppression + baseline machinery
# ---------------------------------------------------------------------------

def test_suppression_same_line_line_above_and_comma_list(tmp_path):
    src = tmp_path / "writer.py"
    base = ("def f(metrics, v):\n"
            "    metrics.histogram('ttft_ticks', width=1,"
            " n_buckets=4096).record(v){}\n")
    src.write_text(base.format(""))
    assert run_lint([str(src)], rules=["metrics-writer"]).errors == 1

    src.write_text(base.format("  # repro: lint-ok[metrics-writer]"))
    res = run_lint([str(src)], rules=["metrics-writer"])
    assert res.findings == [] and res.suppressed == 1

    # marker on the line above the flagged line
    src.write_text("def f(metrics, v):\n"
                   "    # repro: lint-ok[metrics-writer]\n"
                   "    metrics.histogram('ttft_ticks', width=1,"
                   " n_buckets=4096).record(v)\n")
    assert run_lint([str(src)], rules=["metrics-writer"]).findings == []

    # comma list and bare form both cover the rule
    src.write_text(base.format("  # repro: lint-ok[donation, metrics-writer]"))
    assert run_lint([str(src)], rules=["metrics-writer"]).findings == []
    src.write_text(base.format("  # repro: lint-ok"))
    assert run_lint([str(src)], rules=["metrics-writer"]).findings == []

    # a different rule id does NOT suppress
    src.write_text(base.format("  # repro: lint-ok[donation]"))
    assert run_lint([str(src)], rules=["metrics-writer"]).errors == 1


def test_baseline_roundtrip_filters_known_findings(tmp_path):
    bad = str(FIXTURES / "tick_determinism" / "bad" / "scheduler.py")
    res = run_lint([bad], rules=["tick-determinism"])
    assert res.errors >= 1
    bl = tmp_path / "baseline.json"
    write_baseline(str(bl), res)
    filtered = run_lint([bad], rules=["tick-determinism"],
                        baseline=load_baseline(str(bl)))
    assert filtered.findings == []
    assert filtered.baselined == res.errors


def test_syntax_error_is_reported_not_crashed(tmp_path):
    src = tmp_path / "broken.py"
    src.write_text("def f(:\n")
    res = run_lint([str(src)])
    assert res.errors == 1 and res.findings[0].rule == "syntax"


def test_unknown_rule_rejected():
    with pytest.raises(ValueError, match="unknown rule"):
        run_lint([str(FIXTURES / "donation" / "good.py")],
                 rules=["not-a-rule"])


# ---------------------------------------------------------------------------
# CLI exit codes (repro lint == python -m repro.analysis)
# ---------------------------------------------------------------------------

def test_cli_exit_codes(capsys):
    good = str(FIXTURES / "donation" / "good.py")
    bad = str(FIXTURES / "donation" / "bad.py")
    assert cli_main(["lint", good]) == 0
    assert cli_main(["lint", bad]) == 1
    assert cli_main(["lint", "--list-rules"]) == 0
    out = capsys.readouterr().out
    for check in all_checks():
        assert check.rule in out
    assert cli_main(["lint", "--rule", "not-a-rule", good]) == 2
    assert cli_main(["lint", "no/such/path.py"]) == 2


def test_cli_strict_fails_on_warnings(tmp_path):
    # a dynamic span kind is a warning: plain lint passes, --strict fails
    src = tmp_path / "emitter.py"
    src.write_text("def f(trace, rid, kind, tick):\n"
                   "    trace.record(rid, kind, tick)\n")
    assert lint_main([str(src)]) == 0
    assert lint_main(["--strict", str(src)]) == 1


def test_lint_does_not_create_runtime_state(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    (tmp_path / "clean.py").write_text("x = 1\n")
    assert cli_main(["lint", "clean.py"]) == 0
    assert not (tmp_path / ".stevedore").exists()


# ---------------------------------------------------------------------------
# the acceptance criterion: the tree itself lints clean
# ---------------------------------------------------------------------------

def test_current_tree_lints_clean_strict():
    res = run_lint([str(REPO / "src"), str(REPO / "tests")])
    rendered = "\n".join(f.render() for f in res.findings)
    assert res.errors == 0 and res.warnings == 0, \
        f"repro lint --strict must exit 0 on the tree:\n{rendered}"
    # the fixture files' seeded violations were skipped by the directory
    # exclusion, not silently fixed
    assert res.files > 50
    assert all("lint_fixtures" not in f.file for f in res.findings)
