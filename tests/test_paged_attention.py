"""Paged-attention hardening suite (the PR's foregrounded test work).

Three parity surfaces pinned against each other:
  * Pallas kernel (interpret=True) vs the jnp oracle (ref.py) across page
    sizes {8, 16, 64}, ragged per-slot lengths, GQA/MQA geometry, windowed
    attention and bf16;
  * oracle vs the CONTIGUOUS decode formulation (models.attention.attend
    with per-row positions) -- the exactness that makes paged serving a
    drop-in for slot serving;
plus property/invariant tests for the PagePool allocator under randomized
admit/decode/release schedules (fixed-seed loop, no hypothesis dependency).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_pallas
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import gather_pages, paged_attention_ref
from repro.orchestrator.page_pool import GARBAGE_PAGE, PagePool

pytestmark = pytest.mark.kernels


def _tol(dt):
    return 3e-2 if dt == jnp.bfloat16 else 3e-5


def _random_paged(rng, B, n_kv, g, hd, ps, mp, lengths, dtype=np.float32):
    """Random pool + a scattered (non-contiguous, shuffled) allocation."""
    n_alloc = sum(-(-int(l) // ps) for l in lengths)
    n_pages = n_alloc + 3                       # garbage page 0 + 2 spare
    free = list(range(1, n_pages))
    rng.shuffle(free)                           # pages land anywhere
    table = np.full((B, mp), GARBAGE_PAGE, np.int32)
    for b in range(B):
        for j in range(-(-int(lengths[b]) // ps)):
            table[b, j] = free.pop()
    q = rng.standard_normal((B, n_kv * g, hd)).astype(dtype)
    k = rng.standard_normal((n_kv, n_pages, ps, hd)).astype(dtype)
    v = rng.standard_normal((n_kv, n_pages, ps, hd)).astype(dtype)
    return (jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            jnp.asarray(table), jnp.asarray(lengths, dtype=jnp.int32))


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

PA_CASES = [
    # B, n_kv, g, hd, page_size, max_pages, window, dtype
    (4, 2, 2, 16, 8, 4, 0, jnp.float32),
    (3, 1, 8, 32, 16, 3, 0, jnp.float32),      # MQA
    (2, 4, 1, 64, 64, 2, 0, jnp.float32),      # MHA, big pages
    (4, 2, 3, 16, 8, 6, 12, jnp.float32),      # sliding window
    (2, 2, 2, 32, 16, 4, 0, jnp.bfloat16),
    (2, 1, 4, 64, 64, 3, 40, jnp.bfloat16),    # window + big pages
]


@pytest.mark.parametrize("case", PA_CASES, ids=str)
def test_paged_kernel_vs_ref(case):
    B, n_kv, g, hd, ps, mp, window, dt = case
    rng = np.random.default_rng(42)
    # ragged lengths incl. the 1-token edge and a full table span
    lengths = np.concatenate([[1, mp * ps],
                              rng.integers(1, mp * ps, max(0, B - 2)) + 0])
    lengths = lengths[:B].astype(np.int32)
    q, k, v, table, lens = _random_paged(
        rng, B, n_kv, g, hd, ps, mp, lengths,
        np.float32 if dt == jnp.float32 else np.float32)
    if dt == jnp.bfloat16:
        q, k, v = (x.astype(dt) for x in (q, k, v))
    out = paged_attention_pallas(q, k, v, table, lens, window=window,
                                 interpret=True)
    ref = paged_attention_ref(q, k, v, table, lens, window=window)
    err = float(jnp.abs(out.astype(jnp.float32)
                        - ref.astype(jnp.float32)).max())
    assert err < _tol(dt), err


@pytest.mark.parametrize("page_size", [8, 16, 64])
def test_page_size_is_pure_layout(page_size):
    """The same logical KV history must attend identically regardless of
    how it is cut into pages (page size is a layout parameter, like the
    flash kernel's block shapes)."""
    rng = np.random.default_rng(0)
    B, n_kv, g, hd, L = 3, 2, 2, 32, 128
    lengths = np.array([1, 70, 128], np.int32)
    kc = rng.standard_normal((B, L, n_kv, hd)).astype(np.float32)
    vc = rng.standard_normal((B, L, n_kv, hd)).astype(np.float32)
    q = rng.standard_normal((B, n_kv * g, hd)).astype(np.float32)

    # page the contiguous history through a shuffled allocation
    mp = L // page_size
    n_pages = B * mp + 1
    perm = list(range(1, n_pages))
    rng.shuffle(perm)
    table = np.zeros((B, mp), np.int32)
    k_pages = np.zeros((n_kv, n_pages, page_size, hd), np.float32)
    v_pages = np.zeros((n_kv, n_pages, page_size, hd), np.float32)
    for b in range(B):
        for j in range(mp):
            p = perm.pop()
            table[b, j] = p
            sl = slice(j * page_size, (j + 1) * page_size)
            k_pages[:, p] = kc[b, sl].transpose(1, 0, 2)
            v_pages[:, p] = vc[b, sl].transpose(1, 0, 2)

    # contiguous decode formulation (what models.attention.decode_attn runs)
    from repro.models.attention import attend
    q_pos = (lengths - 1)[:, None]
    k_pos = np.broadcast_to(np.arange(L), (B, L))
    ref_c = attend(jnp.asarray(q)[:, None], jnp.asarray(kc), jnp.asarray(vc),
                   jnp.asarray(q_pos), jnp.asarray(k_pos))[:, 0]

    args = (jnp.asarray(q), jnp.asarray(k_pages), jnp.asarray(v_pages),
            jnp.asarray(table), jnp.asarray(lengths))
    ref_p = paged_attention_ref(*args)
    out_k = paged_attention_pallas(*args, interpret=True)
    # oracle == contiguous path bitwise (same einsum/mask formulation);
    # kernel within online-softmax tolerance
    np.testing.assert_array_equal(np.asarray(ref_p), np.asarray(ref_c))
    assert float(jnp.abs(out_k - ref_c).max()) < 3e-5


def test_unmapped_pages_and_garbage_are_invisible():
    """Poisoning the garbage page and every unallocated page must not
    change any output: the mask, not the allocator, hides junk."""
    rng = np.random.default_rng(1)
    B, n_kv, g, hd, ps, mp = 3, 2, 2, 16, 8, 5
    lengths = np.array([3, 17, 26], np.int32)
    q, k, v, table, lens = _random_paged(rng, B, n_kv, g, hd, ps, mp, lengths)
    base = paged_attention_ref(q, k, v, table, lens)
    used = np.unique(np.asarray(table))
    poison = np.ones(k.shape, np.float32) * 1e9
    mask = np.zeros(k.shape, bool)
    mask[:, used] = True                 # keep used pages, poison the rest
    kp = jnp.where(jnp.asarray(mask), k, jnp.asarray(poison))
    vp = jnp.where(jnp.asarray(mask), v, jnp.asarray(poison))
    np.testing.assert_array_equal(
        np.asarray(base), np.asarray(paged_attention_ref(q, kp, vp, table, lens)))
    out_k = paged_attention_pallas(q, kp, vp, table, lens, interpret=True)
    assert float(jnp.abs(out_k - base).max()) < 3e-5


def test_ops_dispatch_off_tpu_uses_oracle():
    rng = np.random.default_rng(2)
    lengths = np.array([5, 9], np.int32)
    q, k, v, table, lens = _random_paged(rng, 2, 2, 2, 16, 8, 2, lengths)
    np.testing.assert_array_equal(
        np.asarray(paged_attention(q, k, v, table, lens)),
        np.asarray(paged_attention_ref(q, k, v, table, lens)))


def test_gather_pages_roundtrip():
    rng = np.random.default_rng(3)
    lengths = np.array([16, 16], np.int32)
    _, k, _, table, _ = _random_paged(rng, 2, 2, 1, 16, 8, 2, lengths)
    got = gather_pages(k, table)
    assert got.shape == (2, 16, 2, 16)
    for b in range(2):
        for j in range(2):
            np.testing.assert_array_equal(
                np.asarray(got[b, j * 8:(j + 1) * 8]),
                np.asarray(k[:, int(table[b, j])]).transpose(1, 0, 2))


# ---------------------------------------------------------------------------
# PagePool properties (randomized schedule, fixed seed, no hypothesis dep)
# ---------------------------------------------------------------------------

def test_page_pool_random_schedules_conserve_pages():
    """500 random admit/extend/release steps: pages are never leaked, never
    double-allocated, reservations never over-commit, and the free count is
    conserved -- ``check()`` asserts the full invariant set after EVERY op."""
    rng = np.random.default_rng(0)
    pool = PagePool(n_pages=33, page_size=8, n_slots=6, max_pages=12)
    hi = {}                                    # slot -> high-water position
    goal = {}                                  # slot -> reserved page count
    for _ in range(500):
        op = rng.integers(0, 3)
        busy = list(hi)
        free_slots = [s for s in range(6) if s not in hi]
        if op == 0 and free_slots:             # admit
            slot = int(rng.choice(free_slots))
            need = int(rng.integers(1, 9))
            if pool.can_reserve(need):
                pool.reserve(slot, need)
                goal[slot] = need
                hi[slot] = int(rng.integers(0, need * 8))
                pool.alloc_upto(slot, hi[slot])
        elif op == 1 and busy:                 # decode: extend alloc-on-write
            slot = int(rng.choice(busy))
            hi[slot] = min(goal[slot] * 8 - 1,
                           hi[slot] + int(rng.integers(1, 5)))
            pool.alloc_upto(slot, hi[slot])
        elif op == 2 and busy:                 # release
            slot = int(rng.choice(busy))
            pool.release(slot)
            del hi[slot], goal[slot]
        pool.check()
    for slot in list(hi):
        pool.release(slot)
    pool.check()
    assert pool.in_use == 0 and pool.total_reserved == 0
    assert len(pool.free) == pool.capacity
    assert pool.pages_allocated == pool.pages_freed > 0


def test_page_pool_rejects_overcommit_and_double_reserve():
    pool = PagePool(n_pages=9, page_size=4, n_slots=2, max_pages=4)
    assert pool.capacity == 8
    pool.reserve(0, 6)
    assert not pool.can_reserve(3)             # only 2 unreserved left
    with pytest.raises(RuntimeError):
        pool.reserve(1, 3)
    with pytest.raises(RuntimeError):
        pool.reserve(0, 1)                     # slot already reserved
    pool.alloc_upto(0, 7)                      # 2 pages, within reservation
    with pytest.raises(RuntimeError):
        pool.alloc_upto(0, 6 * 4)              # would exceed the reservation
    pool.release(0)
    assert pool.can_reserve(8)
    pool.check()


def test_page_pool_early_release_returns_unused_reservation():
    """EOS-style exit: a request that reserved 6 pages but only wrote 2
    gives all 6 back the moment it releases."""
    pool = PagePool(n_pages=13, page_size=4, n_slots=2, max_pages=8)
    pool.reserve(0, 6)
    pool.alloc_upto(0, 7)                      # wrote 2 pages of 6
    assert pool.in_use == 2 and pool.free_unreserved == pool.capacity - 6
    pool.release(0)
    assert pool.in_use == 0 and pool.free_unreserved == pool.capacity
    pool.check()


def test_page_pool_garbage_page_is_never_allocated():
    pool = PagePool(n_pages=5, page_size=4, n_slots=1, max_pages=4)
    pool.reserve(0, 4)
    pool.alloc_upto(0, 15)                     # exhaust the whole pool
    assert GARBAGE_PAGE not in pool.owned[0]
    assert (pool.table[0] != GARBAGE_PAGE).all()
    pool.release(0)
    assert (pool.table[0] == GARBAGE_PAGE).all()
    pool.check()
