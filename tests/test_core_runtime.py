"""Runtime facade + CompileCache (the import-problem fix) + Container overlay
+ data pipeline determinism."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compile_cache import CompileCache
from repro.core.runtime import Runtime
from repro.data.pipeline import DataConfig, MemmapLM, SyntheticLM

SMOKE_IMAGEFILE = """
FROM scratch
ARCH llama3.2-3b-smoke
SHAPE train_4k seq_len=16 global_batch=2
MESH local
PRECISION compute=float32 params=float32
COLLECTIVES generic
"""


@pytest.fixture()
def rt(tmp_path):
    return Runtime(tmp_path / "stevedore")


def test_runtime_build_run_train(rt):
    img = rt.build(SMOKE_IMAGEFILE, tag="smoke")
    c = rt.run("smoke")
    prm = c.init_params(0)
    opt = c.init_opt_state(prm)
    step = jax.jit(c.train_step_fn())
    batch = {
        "tokens": jnp.zeros((2, 16), jnp.int32),
        "labels": jnp.zeros((2, 16), jnp.int32),
    }
    _, _, metrics = step(prm, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # overlay exists and records the image
    meta = json.loads((c.overlay / "container.json").read_text())
    assert meta["image"] == img.digest
    assert rt.ps()[0]["arch"] == "llama3.2-3b-smoke"


def test_containers_share_image_but_not_overlay(rt):
    rt.build(SMOKE_IMAGEFILE, tag="smoke")
    c1, c2 = rt.run("smoke"), rt.run("smoke")
    assert c1.image.digest == c2.image.digest
    assert c1.overlay != c2.overlay


def test_container_metrics_log(rt):
    rt.build(SMOKE_IMAGEFILE, tag="smoke")
    c = rt.run("smoke")
    c.log_metrics(1, {"loss": jnp.float32(2.5)})
    c.log_metrics(2, {"loss": 2.4})
    lines = (c.overlay / "metrics.jsonl").read_text().splitlines()
    assert json.loads(lines[0]) == {"step": 1, "loss": 2.5}


# ---------------------------------------------------------------------------
# compile cache = the Python-import-problem fix (paper Fig. 4)
# ---------------------------------------------------------------------------

def test_compile_cache_levels(rt):
    rt.build(SMOKE_IMAGEFILE, tag="smoke")
    c = rt.run("smoke")
    compiled_cold = c.compile_step("train")
    assert rt.compile_cache.stats.misses == 1
    assert rt.compile_cache.stats.last_level == "L0"
    cold_s = rt.compile_cache.stats.last_seconds

    c2 = rt.run("smoke")                      # second "host"
    compiled_warm = c2.compile_step("train")
    assert rt.compile_cache.stats.hits_l1 == 1
    assert rt.compile_cache.stats.last_level == "L1"
    assert rt.compile_cache.stats.last_seconds < cold_s

    # the deserialized executable actually runs and matches (params/opt are
    # donated by the train step, so each call gets a fresh copy)
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    _, _, m1 = compiled_cold(c.init_params(0), c.init_opt_state(
        c.init_params(0)), batch)
    _, _, m2 = compiled_warm(c2.init_params(0), c2.init_opt_state(
        c2.init_params(0)), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), abs=1e-6)


def test_compile_cache_key_separates_configs(tmp_path):
    cache = CompileCache(tmp_path)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    args = {"x": jax.ShapeDtypeStruct((4,), jnp.float32)}
    k1 = cache.key(image_digest="a" * 64, step_kind="train", mesh=mesh,
                   args_tree=args)
    k2 = cache.key(image_digest="b" * 64, step_kind="train", mesh=mesh,
                   args_tree=args)
    k3 = cache.key(image_digest="a" * 64, step_kind="decode", mesh=mesh,
                   args_tree=args)
    assert len({k1, k2, k3}) == 3


def test_compile_cache_lowered_text_persisted(tmp_path):
    cache = CompileCache(tmp_path)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = jax.ShapeDtypeStruct((8,), jnp.float32)
    key = cache.key(image_digest="c" * 64, step_kind="t", mesh=mesh,
                    args_tree=x)
    cache.get_or_build(key, lambda: jax.jit(lambda v: v * 2).lower(x))
    text = cache.lowered_text(key)
    assert text and "stablehlo" in text or "module" in text


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_deterministic_and_restartable():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=4, seed=1)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b5 = d1.batch(5)
    np.testing.assert_array_equal(b5["tokens"], d2.batch(5)["tokens"])
    assert b5["tokens"].shape == (4, 8)
    assert b5["tokens"].max() < 100
    # labels are next-token shifted
    assert not np.array_equal(b5["tokens"], b5["labels"])


def test_synthetic_differs_across_steps_and_seeds():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=2, seed=1)
    d = SyntheticLM(cfg)
    assert not np.array_equal(d.batch(0)["tokens"], d.batch(1)["tokens"])
    d2 = SyntheticLM(DataConfig(vocab_size=1000, seq_len=32, global_batch=2,
                                seed=2))
    assert not np.array_equal(d.batch(0)["tokens"], d2.batch(0)["tokens"])


def test_memmap_source_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, 50, size=20_000).astype(np.int32)
    MemmapLM.write_shards(tmp_path, tokens, n_shards=3)
    cfg = DataConfig(vocab_size=50, seq_len=16, global_batch=4)
    src = MemmapLM(cfg, tmp_path)
    b = src.batch(0)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    np.testing.assert_array_equal(src.batch(3)["tokens"],
                                  src.batch(3)["tokens"])
