"""Roofline analysis parsers: collective byte accounting, cross-pod
classification, model-flops accounting."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.launch.analysis import (
    CollectiveStats, Cost, _crosses_pod, _shape_bytes, model_flops,
    parse_collectives, roofline, PEAK_FLOPS_BF16,
)
from repro.models.config import get_shape_cell


def test_shape_bytes():
    assert _shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert _shape_bytes("bf16[2,3,4]") == 24 * 2
    assert _shape_bytes("f32[]") == 4
    assert _shape_bytes("(f32[8], bf16[8])") == 32 + 16
    assert _shape_bytes("token[]") == 0


HLO = """\
HloModule jit_step, num_partitions=512
  %ar = f32[16,128]{1,0} all-reduce(%x), replica_groups={{0,1},{2,3}}, to_apply=%add
  %ag = bf16[64,128]{1,0} all-gather(%y), replica_groups=[32,16]<=[512], dimensions={0}
  %rs = f32[4,128]{1,0} reduce-scatter(%z), replica_groups={{0,256}}, dimensions={0}, to_apply=%add
  %cp = bf16[8,8]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %ard = f32[16,128]{1,0} all-reduce-done(%h)
"""


def test_parse_collectives_bytes():
    st = parse_collectives(HLO)
    assert st.count_by_op == {"all-reduce": 1, "all-gather": 1,
                              "reduce-scatter": 1, "collective-permute": 1}
    assert st.bytes_by_op["all-reduce"] == 16 * 128 * 4
    assert st.bytes_by_op["all-gather"] == 64 * 128 * 2
    # reduce-scatter: result x group size (2)
    assert st.bytes_by_op["reduce-scatter"] == 4 * 128 * 4 * 2
    # wire: AR counted twice (ring)
    assert st.wire_bytes == (2 * 16 * 128 * 4 + 64 * 128 * 2
                             + 4 * 128 * 4 * 2 + 8 * 8 * 2)


def test_cross_pod_classification():
    # explicit groups within pod 0
    assert not _crosses_pod("replica_groups={{0,1},{2,3}}", 512, 256)
    # explicit group spanning pods
    assert _crosses_pod("replica_groups={{0,256}}", 512, 256)
    # iota: 32 groups of 16 consecutive ids -> intra-pod
    assert not _crosses_pod("replica_groups=[32,16]<=[512]", 512, 256)
    # iota with transpose: groups stride across both pods
    assert _crosses_pod("replica_groups=[16,32]<=[32,16]T(1,0)", 512, 256)


def test_parse_collectives_cross_pod():
    st = parse_collectives(HLO)
    # only the reduce-scatter {{0,256}} crosses; counted once (not an AR)
    assert st.cross_pod_bytes == 4 * 128 * 4 * 2


def test_metadata_shapes_ignored():
    line = ('  %ar = f32[8]{0} all-reduce(%x), replica_groups={{0,1}}, '
            'metadata={op_name="jit(f)/reshape[f32[9999,9999]]"}\n')
    st = parse_collectives("num_partitions=2\n" + line)
    assert st.bytes_by_op["all-reduce"] == 32


def test_collective_stats_add_scales():
    a = CollectiveStats(bytes_by_op={"all-reduce": 10}, count_by_op={"all-reduce": 1},
                        cross_pod_bytes=4)
    b = CollectiveStats(bytes_by_op={"all-reduce": 3, "all-gather": 7},
                        count_by_op={"all-reduce": 1, "all-gather": 2},
                        cross_pod_bytes=1)
    a.add(b, scale=5)
    assert a.bytes_by_op == {"all-reduce": 25, "all-gather": 35}
    assert a.count_by_op == {"all-reduce": 6, "all-gather": 10}
    assert a.cross_pod_bytes == 9


def test_model_flops_train_vs_decode():
    cfg = get_config("llama3.2-3b")
    train = model_flops(cfg, get_shape_cell("train_4k"))
    decode = model_flops(cfg, get_shape_cell("decode_32k"))
    # train: 6*N*(256*4096) tokens; decode: 2*N*128 tokens
    assert train / decode == pytest.approx(
        (6 * 256 * 4096) / (2 * 128), rel=1e-6)


def test_model_flops_moe_uses_active_params():
    cfg = get_config("moonshot-v1-16b-a3b")
    cell = get_shape_cell("train_4k")
    full_n = cfg.param_count(active_only=False)
    act_n = cfg.param_count(active_only=True)
    assert act_n < 0.4 * full_n              # 6 of 64 experts active
    mf = model_flops(cfg, cell)
    assert mf < 6 * full_n * cell.global_batch * cell.seq_len


def test_roofline_dominant_and_fraction():
    cost = Cost(flops=197e12, bytes_accessed=819e9 * 2,
                collectives=CollectiveStats(bytes_by_op={"all-reduce": 0}))
    rl = roofline(cost, model_flops_global=197e12 * 256, n_devices=256)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(2.0)
    assert rl.dominant == "memory"
    # ideal time = 1.0s; bound = 2.0s -> fraction 0.5
    assert rl.roofline_fraction == pytest.approx(0.5)
    assert rl.useful_flops_fraction == pytest.approx(1.0)
