"""Orchestrator invariants: slot hygiene, FIFO admission, decode parity
with the lockstep path, EOS early exit, rolling-upgrade drains, and the
paged-KV serving path (pool-pressure admission, lockstep parity with the
contiguous scheduler, long-request completion past the old slab ceiling)."""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.runtime import Runtime
from repro.orchestrator import (
    ContinuousScheduler,
    GenRequest,
    Pod,
    RequestQueue,
    RollingDeployer,
)

pytestmark = pytest.mark.orchestrator

IMAGEFILE = """
FROM scratch
ARCH llama3.2-3b-smoke
SHAPE decode_32k seq_len=64 global_batch=4
MESH local
PRECISION compute=float32 params=float32
COLLECTIVES generic
"""


@pytest.fixture(scope="module")
def rt(tmp_path_factory):
    rt = Runtime(tmp_path_factory.mktemp("stevedore"))
    rt.build(IMAGEFILE, tag="stable")
    return rt


@pytest.fixture(scope="module")
def pod(rt):
    return Pod(rt, "stable", replicas=2, n_slots=3, max_len=56)


def _requests(rng, n, *, base_rid=0, arrive_per_tick=4, max_gen=10):
    return [
        GenRequest(rid=base_rid + i,
                   prompt=rng.integers(0, 256, int(rng.integers(3, 18))),
                   max_new_tokens=int(rng.integers(2, max_gen)),
                   arrival=i // arrive_per_tick)
        for i in range(n)
    ]


def test_no_slot_leaks_mixed_lengths(pod):
    """After a full trace of mixed prompt/gen lengths completes, every slot
    is back on the free-list and alloc/free counters balance."""
    sched = ContinuousScheduler(pod, fairness_cap=3)
    reqs = _requests(np.random.default_rng(0), 20)
    sched.submit(reqs)
    sched.run(max_ticks=5000)
    assert all(r.state == "done" for r in reqs)
    for e in pod.engines:
        assert not e.active
        assert sorted(e.free) == list(range(e.n_slots))
        assert e.slots_allocated == e.slots_freed
    # every request got exactly its budget (no EOS configured)
    for r in reqs:
        assert len(r.tokens) == r.max_new_tokens
        assert r.finish_reason == "length"


def test_fifo_admission_order_preserved(pod):
    """Admission order == submission order, even with mixed prompt lengths
    across two replicas (least-loaded placement must not reorder)."""
    sched = ContinuousScheduler(pod, fairness_cap=2)
    reqs = _requests(np.random.default_rng(1), 16, base_rid=100,
                     arrive_per_tick=16)
    sched.submit(reqs)
    sched.run(max_ticks=5000)
    assert sched.admission_order == [r.rid for r in reqs]
    admits = [r.admit_tick for r in reqs]
    assert admits == sorted(admits)


@pytest.mark.parametrize("arch", [
    "llama3.2-3b-smoke",        # full attention (pow2 prefill buckets)
    "recurrentgemma-2b-smoke",  # rec + windowed-attn ring cache (exact prefill)
    "mamba2-2.7b-smoke",        # pure SSM state cache (exact prefill)
])
def test_slot_decode_matches_lockstep_generate(rt, arch):
    """Continuous (slot-granular, chunked) decode must reproduce the
    lockstep prefill+scan pipeline token-for-token on an identical batch --
    across attention, ring-buffer window, and recurrent cache kinds."""
    from repro.serve.serve_step import ServeStepBuilder, greedy_sample
    tag = f"par-{arch}"
    rt.build(IMAGEFILE.replace("llama3.2-3b-smoke", arch), tag=tag)
    pod = Pod(rt, tag, replicas=1, n_slots=4, max_len=56)
    eng = pod.engines[0]
    c, params = eng.container, eng.params
    cfg = c.arch
    B, P, G = 4, 8, 6
    rng = np.random.default_rng(2)
    prompts = np.asarray(rng.integers(0, cfg.vocab_size, (B, P)), np.int32)

    b = ServeStepBuilder(c.model, c.mesh, c.rules)
    last, cache = jax.jit(b.build_prefill(56))(params, jnp.asarray(prompts))
    first = greedy_sample(last, cfg.vocab_size)[:, None]
    ref_toks, _ = jax.jit(b.build_generate_loop(G - 1))(
        params, cache, first, jnp.int32(P))
    ref = np.concatenate([np.asarray(first), np.asarray(ref_toks)], axis=1)

    sched = ContinuousScheduler(pod, fairness_cap=4)
    reqs = [GenRequest(rid=i, prompt=prompts[i], max_new_tokens=G)
            for i in range(B)]
    sched.submit(reqs)
    sched.run(max_ticks=1000)
    got = np.asarray([r.tokens for r in reqs])
    np.testing.assert_array_equal(ref, got)


def test_decode_chunk1_matches_chunk4(rt):
    """The single-tick decode_slots path (chunk=1) and the scanned
    decode_chunk path produce identical tokens for the same trace."""
    outs = []
    for chunk in (1, 4):
        pod = Pod(rt, "stable", replicas=1, n_slots=2, max_len=56,
                  decode_chunk=chunk)
        sched = ContinuousScheduler(pod)
        reqs = [GenRequest(rid=i, prompt=np.arange(1, 7) * (i + 1) % 250,
                           max_new_tokens=6) for i in range(3)]
        sched.submit(reqs)
        sched.run(max_ticks=1000)
        outs.append([r.tokens for r in reqs])
    assert outs[0] == outs[1]


def test_eos_frees_slot_early(rt):
    """A request hitting EOS stops before its budget and releases its slot."""
    pod = Pod(rt, "stable", replicas=1, n_slots=2, max_len=56)
    eng = pod.engines[0]
    # discover what token the model actually emits, then use it as EOS
    probe = GenRequest(rid=0, prompt=np.arange(5), max_new_tokens=8)
    sched = ContinuousScheduler(pod)
    sched.submit(probe)
    sched.run(max_ticks=100)
    eos = probe.tokens[2]
    hit = GenRequest(rid=1, prompt=np.arange(5), max_new_tokens=40,
                     eos_id=eos)
    sched.submit(hit)
    sched.run(max_ticks=1000)
    assert hit.finish_reason == "eos"
    assert len(hit.tokens) < 40
    assert hit.tokens[-1] == eos
    assert sorted(eng.free) == list(range(eng.n_slots))


def test_rolling_upgrade_drains_in_flight(rt):
    """Re-tag -> upgrade swaps every replica to the new image digest, and
    in-flight requests complete (full budget, never killed) before their
    replica is swapped."""
    pod = Pod(rt, "stable", replicas=2, n_slots=2, max_len=56)
    sched = ContinuousScheduler(pod, fairness_cap=4)
    old_digest = pod.image.digest
    old_containers = {e.container.container_id for e in pod.engines}

    reqs = [GenRequest(rid=i, prompt=np.arange(4), max_new_tokens=30)
            for i in range(4)]
    sched.submit(reqs)
    sched.step()                      # admit; requests now in flight
    in_flight = sum(len(e.active) for e in pod.engines)
    assert in_flight == 4

    rt.build(IMAGEFILE + "LABEL release=r2\n", tag="stable")
    report = RollingDeployer(pod, sched).upgrade()
    assert report["changed"]
    # every replica drained its in-flight work before being swapped
    for rec in report["replicas"]:
        assert rec["container_old"] in old_containers
    for e in pod.engines:
        assert e.container.image.digest != old_digest
        assert e.container.image.digest == pod.image.digest
        assert not e.stopped and not e.draining
    for old in pod.retired:
        assert old.stopped and not old.active
    # drained requests ran to completion, not cancellation
    for r in reqs:
        assert r.state == "done"
        assert len(r.tokens) == 30
    # the same scheduler keeps serving on the new fleet
    post = [GenRequest(rid=100 + i, prompt=np.arange(4), max_new_tokens=5)
            for i in range(3)]
    sched.submit(post)
    sched.run(max_ticks=1000)
    assert all(r.state == "done" for r in post)


def test_upgrade_noop_when_digest_unchanged(rt):
    pod = Pod(rt, "stable", replicas=1, n_slots=2, max_len=56)
    sched = ContinuousScheduler(pod)
    engines_before = list(pod.engines)
    report = RollingDeployer(pod, sched).upgrade()
    assert not report["changed"]
    assert pod.engines == engines_before


def test_queue_rejects_oversized_and_dup():
    q = RequestQueue()
    with pytest.raises(ValueError):
        GenRequest(rid=0, prompt=np.array([], np.int32), max_new_tokens=4)
    with pytest.raises(ValueError):
        GenRequest(rid=0, prompt=np.arange(4), max_new_tokens=0)
    r = GenRequest(rid=1, prompt=np.arange(4), max_new_tokens=2)
    q.submit(r)
    r.state = "running"
    with pytest.raises(ValueError):
        q.submit(r)


def test_oversized_request_rejected_not_fatal(rt):
    """One oversized request is rejected; the fleet keeps serving and
    well-sized requests behind it still complete. The rejection reason
    names the actual limit (slot slab here, pool/span when paged)."""
    pod = Pod(rt, "stable", replicas=1, n_slots=1, max_len=32)
    sched = ContinuousScheduler(pod)
    bad = GenRequest(rid=0, prompt=np.arange(20), max_new_tokens=20)
    ok = GenRequest(rid=1, prompt=np.arange(6), max_new_tokens=4)
    sched.submit([bad, ok])
    sched.run(max_ticks=100)
    assert bad.state == "rejected" and bad.finish_reason == "oversized"
    assert "slot capacity" in bad.error
    assert sched.rejected == [bad]
    assert ok.state == "done" and len(ok.tokens) == 4
    assert sched.admission_order == [1]


def test_oversized_rejection_names_pool_not_slots_when_paged(rt):
    """After paging, the oversized error path reports page-pool/table
    limits -- never the retired per-slot slab capacity."""
    # pool of 7 usable pages x 8 = 56 positions; span 128
    pod = Pod(rt, "stable", replicas=1, n_slots=2, max_len=128,
              paged=True, page_size=8, n_pages=8)
    sched = ContinuousScheduler(pod)
    bad = GenRequest(rid=0, prompt=np.arange(40), max_new_tokens=40)  # 10+ pages
    ok = GenRequest(rid=1, prompt=np.arange(6), max_new_tokens=4)
    sched.submit([bad, ok])
    sched.run(max_ticks=200)
    assert bad.state == "rejected" and bad.finish_reason == "oversized"
    assert "pool capacity" in bad.error and "pages" in bad.error
    assert "slot capacity" not in bad.error
    assert ok.state == "done" and len(ok.tokens) == 4
    # span violation reported distinctly
    eng = pod.engines[0]
    huge = GenRequest(rid=2, prompt=np.arange(4), max_new_tokens=200)
    with pytest.raises(ValueError, match="page-table span"):
        eng.start(huge, tick=0)


# ---------------------------------------------------------------------------
# paged KV serving
# ---------------------------------------------------------------------------

def test_paged_lockstep_parity_with_contiguous(rt):
    """The paged scheduler must reproduce the contiguous scheduler
    token-for-token on a mixed-length batch -- paging is a memory layout,
    never a numerics change."""
    def trace():
        rng = np.random.default_rng(7)
        return [GenRequest(rid=i,
                           prompt=rng.integers(0, 256, int(rng.integers(3, 18))),
                           max_new_tokens=int(rng.integers(2, 12)))
                for i in range(10)]

    results = []
    for paged in (False, True):
        pod = Pod(rt, "stable", replicas=1, n_slots=3, max_len=56,
                  paged=paged, page_size=8)
        sched = ContinuousScheduler(pod, fairness_cap=3)
        reqs = trace()
        sched.submit(reqs)
        sched.run(max_ticks=5000)
        assert all(r.state == "done" for r in reqs)
        results.append([r.tokens for r in reqs])
    assert results[0] == results[1]
    # pool hygiene after the full trace: everything reclaimed
    eng = pod.engines[0]
    eng.pool.check()
    assert eng.pool.in_use == 0 and eng.pool.total_reserved == 0


def test_paged_long_request_exceeds_old_slab(rt):
    """A request whose prompt+gen exceeds the contiguous per-slot max_len
    completes via paged slots AT THE SAME KV HBM: the pool equals the old
    2x32 bank, but one request may span 56 of its 64 positions."""
    contig = Pod(rt, "stable", replicas=1, n_slots=2, max_len=32)
    sched_c = ContinuousScheduler(contig)
    long_c = GenRequest(rid=0, prompt=np.arange(20), max_new_tokens=30)
    sched_c.submit(long_c)
    sched_c.run(max_ticks=100)
    assert long_c.state == "rejected"

    paged = Pod(rt, "stable", replicas=1, n_slots=2, max_len=64,
                paged=True, page_size=8, n_pages=9)   # 8 pages = 2x32 HBM
    sched_p = ContinuousScheduler(paged)
    long_p = GenRequest(rid=0, prompt=np.arange(20), max_new_tokens=30)
    sched_p.submit(long_p)
    sched_p.run(max_ticks=1000)
    assert long_p.state == "done" and len(long_p.tokens) == 30
    assert long_p.finish_reason == "length"


def test_paged_pool_backpressure_holds_fifo_head(rt):
    """When free pages cannot cover the head request's footprint, admission
    stalls (no reorder, no preempt, no reject) until decode releases pages;
    everything still completes in submission order."""
    # 7 usable pages; each request needs ceil((8+8+4)/8)=3 -> only 2 resident
    pod = Pod(rt, "stable", replicas=1, n_slots=4, max_len=64,
              paged=True, page_size=8, n_pages=8)
    sched = ContinuousScheduler(pod, fairness_cap=4)
    reqs = [GenRequest(rid=i, prompt=np.arange(1, 9) * (i + 1) % 250,
                       max_new_tokens=8) for i in range(6)]
    sched.submit(reqs)
    sched.step()
    eng = pod.engines[0]
    assert len(eng.active) == 2                 # 3rd admission backpressured
    assert eng.pool.total_reserved == 6
    assert sched.rejected == []
    sched.run(max_ticks=5000)
    assert all(r.state == "done" and len(r.tokens) == 8 for r in reqs)
    assert sched.admission_order == [r.rid for r in reqs]
    eng.pool.check()
    assert eng.pool.in_use == 0


def test_paged_chunk1_matches_chunk4(rt):
    """Paged decode_slots (chunk=1) and paged decode_chunk agree."""
    outs = []
    for chunk in (1, 4):
        pod = Pod(rt, "stable", replicas=1, n_slots=2, max_len=56,
                  decode_chunk=chunk, paged=True, page_size=8)
        sched = ContinuousScheduler(pod)
        reqs = [GenRequest(rid=i, prompt=np.arange(1, 7) * (i + 1) % 250,
                           max_new_tokens=6) for i in range(3)]
        sched.submit(reqs)
        sched.run(max_ticks=1000)
        outs.append([r.tokens for r in reqs])
    assert outs[0] == outs[1]


def test_paged_nonmultiple_max_len_rejects_instead_of_crashing(rt):
    """max_len not a multiple of page_size: the page table rounds UP to
    whole pages, but admission must still enforce max_len (the prefill
    bucket ceiling) -- a prompt in the rounding slack is rejected, never
    admitted into a crash (regression: fits() used the rounded span)."""
    pod = Pod(rt, "stable", replicas=1, n_slots=2, max_len=49,
              paged=True, page_size=16)
    sched = ContinuousScheduler(pod)
    bad = GenRequest(rid=0, prompt=np.arange(48), max_new_tokens=12)
    ok = GenRequest(rid=1, prompt=np.arange(6), max_new_tokens=4)
    sched.submit([bad, ok])
    sched.run(max_ticks=200)                  # must not raise
    assert bad.state == "rejected" and "page-table span 49" in bad.error
    assert ok.state == "done" and len(ok.tokens) == 4


def test_paged_rejects_recurrent_archs(rt):
    """Ring-buffer/recurrent caches stay contiguous: paged pods refuse
    them loudly instead of silently corrupting state."""
    rt.build(IMAGEFILE.replace("llama3.2-3b-smoke", "mamba2-2.7b-smoke"),
             tag="rec-paged")
    with pytest.raises(NotImplementedError, match="full-attention"):
        Pod(rt, "rec-paged", replicas=1, n_slots=2, max_len=56, paged=True,
            page_size=8)


def test_pod_state_visible_to_ps(rt):
    pod = Pod(rt, "stable", replicas=1, n_slots=2, max_len=56)
    state = (rt.root / "pods" / f"{pod.pod_id}.json")
    assert state.exists()
    rec = pod.status()
    assert rec["capacity"] == 2
    assert rec["free_slots"] == 2
    assert rec["replicas"][0]["image"] == pod.image.short_digest


# ---------------------------------------------------------------------------
# admission / telemetry regressions
# ---------------------------------------------------------------------------

def test_oversized_head_rejected_under_full_load(rt):
    """Regression: step() broke on `not engines` BEFORE the infeasibility
    check, so with every slot busy a permanently un-servable FIFO head was
    never rejected -- it stalled every feasible request behind it until a
    slot freed. The infeasible head must be rejected the tick it surfaces,
    occupancy notwithstanding."""
    pod = Pod(rt, "stable", replicas=1, n_slots=1, max_len=64)
    eng = pod.engines[0]
    sched = ContinuousScheduler(pod)
    hog = GenRequest(rid=0, prompt=np.arange(1, 5), max_new_tokens=40)
    sched.submit(hog)
    sched.step()
    assert len(eng.active) == 1 and not eng.has_free()      # full load
    bad = GenRequest(rid=1, prompt=np.arange(1, 41), max_new_tokens=40)
    ok = GenRequest(rid=2, prompt=np.arange(1, 7), max_new_tokens=4)
    sched.submit([bad, ok])
    sched.step()
    # rejected IMMEDIATELY -- the hog is still decoding, no slot ever freed
    assert hog.state == "running"
    assert bad.state == "rejected" and bad.finish_reason == "oversized"
    assert sched.rejected == [bad] and pod.rejected == 1
    # and the feasible request behind it is no longer stalled: it admits
    # as soon as the slot frees, not after
    sched.run(max_ticks=1000)
    assert hog.state == "done" and len(hog.tokens) == 40
    assert ok.state == "done" and len(ok.tokens) == 4
    assert ok.admit_tick <= hog.done_tick + 1


def test_rejection_burst_refreshes_pod_state(rt):
    """Regression: the pod-state throttle fired only on (admitted or done),
    so a burst of pure rejections left the state file -- queue depth and
    the rejected counter -- stale until the next slot event. Rejections
    must refresh the file, and Pod.status() must surface the counter."""
    pod = Pod(rt, "stable", replicas=1, n_slots=1, max_len=96)
    sched = ContinuousScheduler(pod)
    hog = GenRequest(rid=0, prompt=np.arange(1, 5), max_new_tokens=80)
    sched.submit(hog)
    sched.step()                            # admit; state written this tick
    # idle past the throttle window: no admissions/completions => no writes
    for _ in range(ContinuousScheduler.STATE_EVERY + 1):
        sched.step()
    state_path = pod.runtime.root / "pods" / f"{pod.pod_id}.json"
    assert json.loads(state_path.read_text())["rejected"] == 0
    # a pure-rejection burst while the only slot stays busy
    burst = [GenRequest(rid=10 + i, prompt=np.arange(1, 41),
                        max_new_tokens=80) for i in range(3)]
    sched.submit(burst)
    sched.step()
    assert all(r.state == "rejected" for r in burst)
    assert hog.state == "running"           # no admitted/done this tick
    rec = json.loads(state_path.read_text())
    assert rec["rejected"] == 3             # file refreshed by rejections
    assert pod.status()["rejected"] == 3
    sched.run(max_ticks=1000)
    assert hog.state == "done"


def test_nearest_rank_percentiles():
    """Nearest-rank on known distributions: p99 of n<=100 is NOT the max,
    and the even-n median is the lower-middle rank, not the upper."""
    from repro.orchestrator.telemetry import nearest_rank
    assert nearest_rank(range(1, 101), 99) == 99        # was max (100)
    assert nearest_rank(range(1, 101), 50) == 50
    assert nearest_rank(range(1, 101), 100) == 100
    assert nearest_rank([4, 1, 3, 2], 50) == 2          # was 3 (biased high)
    assert nearest_rank([1, 2, 3, 4, 5], 50) == 3
    assert nearest_rank([7], 99) == 7
    assert nearest_rank([10, 20], 1) == 10              # clamps to rank 1
    assert nearest_rank([], 99) == 0                    # no completions
    with pytest.raises(ValueError):
        nearest_rank([1, 2], 150)


# ---------------------------------------------------------------------------
# slot-engine drift regressions
# ---------------------------------------------------------------------------

def test_free_slot_positions_stay_parked(rt):
    """Regression: tick() used to advance EVERY row's position, so a
    long-idle free slot's position grew unboundedly -- in paged mode
    pos // page_size then indexed past the page-table span. Free rows must
    stay parked at 0 while active rows advance, and a freed slot must be
    reset the tick it completes."""
    for paged in (False, True):
        pod = Pod(rt, "stable", replicas=1, n_slots=4, max_len=64,
                  paged=paged, page_size=8)
        eng = pod.engines[0]
        sched = ContinuousScheduler(pod)
        long = GenRequest(rid=0, prompt=np.arange(5), max_new_tokens=30)
        sched.submit(long)
        while long.state != "done":
            sched.step()
            for s in eng.free:
                assert eng.pos[s] == 0, (paged, s, eng.pos)
            if paged:
                assert (eng.pos // eng.page_size < eng.max_pages).all()
        # the completed request's slot was reset on completion
        assert (eng.pos == 0).all()
        # and many idle ticks later nothing has drifted
        for _ in range(20):
            sched.step()
        assert (eng.pos == 0).all()


def test_capacity_and_free_slots_exclude_draining(rt):
    """Regression: a draining replica reported 0 free slots while capacity
    still counted its slots, so `repro ps` overstated headroom by a full
    replica during blue/green rollovers. The two properties must agree on
    which replicas they count."""
    pod = Pod(rt, "stable", replicas=2, n_slots=3, max_len=56)
    assert pod.capacity == 6 and pod.free_slots == 6
    pod.engines[0].draining = True
    assert pod.capacity == 3 and pod.free_slots == 3
    st = pod.status()
    assert st["capacity"] == 3 and st["free_slots"] == 3
    pod.engines[0].draining = False
    pod.engines[0].stopped = True
    assert pod.capacity == 3 and pod.free_slots == 3


def test_prefill_executable_count_exposed_and_bounded(rt):
    """`_prefills` holds one compiled executable per distinct bucket.
    status() must surface the count, and pow2-bucketed archs must stay
    bounded where exact-prefill archs grow per distinct prompt length."""
    counts = {}
    # mamba's SSD prefill needs lengths divisible by ssm_chunk (8 in smoke)
    for arch, lens in (("llama3.2-3b-smoke", [3, 5, 7, 9]),
                       ("mamba2-2.7b-smoke", [8, 16, 24, 32])):
        tag = f"pf-{arch}"
        rt.build(IMAGEFILE.replace("llama3.2-3b-smoke", arch), tag=tag)
        pod = Pod(rt, tag, replicas=1, n_slots=2, max_len=56)
        sched = ContinuousScheduler(pod)
        sched.submit([GenRequest(rid=i, prompt=np.arange(1, n + 1),
                                 max_new_tokens=2)
                      for i, n in enumerate(lens)])
        sched.run(max_ticks=1000)
        counts[arch] = pod.engines[0].status()["prefill_execs"]
    # all four lengths share the 16-bucket under pow2 bucketing
    assert counts["llama3.2-3b-smoke"] == 1
    # exact-prefill (recurrent cache): one executable per distinct length
    assert counts["mamba2-2.7b-smoke"] == 4
