"""End-to-end driver: train a ~100M-param llama3.2-shape model for a few
hundred steps on the local platform (the assignment's (b) e2e requirement).

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

~100M config: 8 layers, d_model 512, 8 heads (kv 4), d_ff 1536, vocab 32000
-> 0.10B params. Uses the real production path: Imagefile -> registry ->
container -> jit train step with checkpointing + straggler monitor, via the
same launch/train.py driver the cluster would use.
"""

import argparse
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.launch.train import main as train_main

IMAGEFILE = """
FROM scratch
ARCH llama3.2-3b n_layers=8 d_model=512 n_heads=8 n_kv_heads=4 head_dim=64 d_ff=1536 vocab_size=32000
SHAPE train_4k seq_len=128 global_batch=4
MESH local
PRECISION params=float32 compute=bfloat16
COLLECTIVES generic
SET optimizer={"lr":0.0003,"warmup_steps":50,"total_steps":1000} remat=none
LABEL tier=example purpose=train-100m
"""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    tmp = tempfile.mkdtemp(prefix="stevedore-100m-")
    imagefile = Path(tmp) / "Imagefile"
    imagefile.write_text(IMAGEFILE)
    result = train_main([
        "--image", str(imagefile),
        "--root", tmp,
        "--steps", str(args.steps),
        "--ckpt-every", "50",
    ])
    print(f"final loss after {result['steps']} steps: "
          f"{result['final_loss']:.4f}")


if __name__ == "__main__":
    main()
