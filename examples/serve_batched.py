"""Serve a small model with batched requests through the container runtime.

    PYTHONPATH=src python examples/serve_batched.py
"""

import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.launch.serve import main as serve_main

IMAGEFILE = """
FROM scratch
ARCH llama3.2-3b n_layers=6 d_model=384 n_heads=6 n_kv_heads=2 head_dim=64 d_ff=1024 vocab_size=32000
SHAPE decode_32k seq_len=256 global_batch=8
MESH local
PRECISION params=float32 compute=bfloat16
COLLECTIVES generic
LABEL tier=example purpose=serving
"""


def main():
    tmp = tempfile.mkdtemp(prefix="stevedore-serve-")
    imagefile = Path(tmp) / "Imagefile"
    imagefile.write_text(IMAGEFILE)
    serve_main([
        "--image", str(imagefile),
        "--root", tmp,
        "--requests", "8",
        "--prompt-len", "64",
        "--gen", "32",
    ])


if __name__ == "__main__":
    main()
