"""The paper's核心 demo, §3.3/Fig.1: laptop -> HPC migration with ONE image.

    PYTHONPATH=src python examples/hpc_migration.py

Same image, three "platforms":
  1. laptop (local 1-device): develop + debug, a few training steps;
  2. checkpoint travels with the overlay;
  3. "HPC" re-instantiation: the image's collectives layer is swapped
     generic -> host (the Cray-MPI move) WITHOUT touching arch/shape layers,
     and the elastic restore re-shards the checkpoint onto the new mesh.

On this CPU container the "HPC" platform is the same single device (the
point is the artifact flow + the layer-sharing assertion); on a real pod
you would pass --platform pod and nothing else changes.
"""

import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint.elastic import reshard_restore
from repro.checkpoint.store import CheckpointStore
from repro.core.image import ImageBuilder
from repro.core.runtime import Runtime
from repro.data.pipeline import DataConfig, SyntheticLM

IMAGEFILE_DEV = """
FROM scratch
ARCH llama3.2-3b-smoke
SHAPE train_4k seq_len=64 global_batch=8
MESH local
PRECISION params=float32 compute=float32
COLLECTIVES generic
SET optimizer={"lr":0.005,"warmup_steps":5,"total_steps":100}
LABEL tier=dev
"""


def main():
    root = tempfile.mkdtemp(prefix="stevedore-hpc-")
    rt = Runtime(root)

    # ---- laptop phase -----------------------------------------------------
    dev_img = rt.build(IMAGEFILE_DEV, tag="dev")
    c = rt.run("dev")
    print(f"[laptop] running {dev_img.short_digest} on platform "
          f"{c.platform} (abi={c.abi.describe()})")
    params = c.init_params(0)
    opt = c.init_opt_state(params)
    step = jax.jit(c.train_step_fn(), donate_argnums=(0, 1))
    data = SyntheticLM(DataConfig(vocab_size=c.arch.vocab_size, seq_len=64,
                                  global_batch=8, seed=1))
    for i in range(5):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, m = step(params, opt, batch)
    print(f"[laptop] step 5 loss={float(m['loss']):.4f}")
    store = CheckpointStore(Path(root) / "shared-ckpt")   # the $SCRATCH mount
    store.save(5, {"params": params, "opt": opt}, blocking=True)

    # ---- the ABI swap: derive the HPC image FROM the dev image -------------
    hpc_img = (ImageBuilder.from_image(dev_img)
               .collectives("host", zero1=True)
               .label(tier="hpc")
               .build())
    stats = rt.push(hpc_img, tag="hpc")
    print(f"[registry] pushed hpc image: {stats.layers_transferred} new "
          f"layers, {stats.layers_reused} reused (dedupe "
          f"{stats.dedupe_fraction:.0%}) -- the MPICH->Cray swap touched "
          "ONLY the collectives layer")

    # ---- HPC phase: same artifact, restored state, different ABI -----------
    c2 = rt.run("hpc")          # --platform pod on a real cluster
    print(f"[hpc] running {hpc_img.short_digest} on platform {c2.platform} "
          f"(abi={c2.abi.describe()})")
    tmpl = {"params": c2.abstract_params(), "opt": c2.abstract_opt_state()}
    sh = {"params": c2.param_shardings(), "opt": c2.opt_state_shardings()}
    restored = reshard_restore(store, tmpl, sh)
    params2, opt2 = restored["params"], restored["opt"]
    step2 = jax.jit(c2.train_step_fn(), donate_argnums=(0, 1))
    for i in range(5, 10):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params2, opt2, m = step2(params2, opt2, batch)
    print(f"[hpc] step 10 loss={float(m['loss']):.4f} -- continued "
          "seamlessly under the host ABI")


if __name__ == "__main__":
    main()
