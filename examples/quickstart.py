"""Quickstart: the paper's §3.2 'docker run' experience, end to end.

    PYTHONPATH=src python examples/quickstart.py

Builds an image from an Imagefile, pushes it to a local registry with a
tag, runs a container on THIS machine (the laptop platform), takes a few
training steps, checkpoints, kills the container, and resumes in a fresh
one -- the whole portable-environment story at smoke scale.
"""

import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint.elastic import reshard_restore
from repro.checkpoint.store import CheckpointStore
from repro.core.runtime import Runtime
from repro.data.pipeline import DataConfig, SyntheticLM

IMAGEFILE = """
# FEniCS-style stable image: tiny llama for the laptop platform
FROM scratch
ARCH llama3.2-3b-smoke
SHAPE train_4k seq_len=64 global_batch=8
MESH local
PRECISION params=float32 compute=float32
COLLECTIVES generic
SET optimizer={"lr":0.005,"warmup_steps":5,"total_steps":200}
LABEL tier=stable maintainer=stevedore
"""


def main():
    root = tempfile.mkdtemp(prefix="stevedore-")
    rt = Runtime(root)

    print("== build & push (quay.io analog) ==")
    image = rt.build(IMAGEFILE, tag="stable")
    for digest, kind, summary in image.history():
        print(f"  {digest} {kind:12s} {summary}")
    print(f"image: {image.short_digest}  tags: {rt.registry.tags()}")

    print("\n== docker run stable ==")
    c = rt.run("stable")
    params = c.init_params(seed=0)
    opt = c.init_opt_state(params)
    step = jax.jit(c.train_step_fn(), donate_argnums=(0, 1))
    data = SyntheticLM(DataConfig(vocab_size=c.arch.vocab_size, seq_len=64,
                                  global_batch=8, seed=42))
    store = CheckpointStore(c.overlay / "ckpt")
    for i in range(10):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, m = step(params, opt, batch)
        print(f"  step {i+1:2d}  loss {float(m['loss']):.4f}")
    store.save(10, {"params": params, "opt": opt}, blocking=True)
    print(f"checkpointed at step 10 -> {store.root}")

    print("\n== crash + resume in a fresh container ==")
    c2 = rt.run("stable")
    tmpl = {"params": c2.abstract_params(), "opt": c2.abstract_opt_state()}
    sh = {"params": c2.param_shardings(), "opt": c2.opt_state_shardings()}
    restored = reshard_restore(store, tmpl, sh)
    params2, opt2 = restored["params"], restored["opt"]
    step2 = jax.jit(c2.train_step_fn(), donate_argnums=(0, 1))
    for i in range(10, 15):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params2, opt2, m = step2(params2, opt2, batch)
        print(f"  step {i+1:2d}  loss {float(m['loss']):.4f}  (resumed)")

    print(f"\ncontainers run from this image: "
          f"{[p['id'][:20] for p in rt.ps()]}")
    print("done.")


if __name__ == "__main__":
    main()
