"""Fig. 10 (new): SLO-aware serving under overload -- priority lanes,
admission deadlines, and page-level preemption.

The QoS claim, measured: flood a tight paged pod with bulk batch work
while interactive traffic trickles in. Without QoS (one FIFO lane, no
preemption) the interactive requests queue behind the flood and their
TTFT explodes. With QoS the interactive lane admits first, a blocked
interactive head preempts the youngest running batch request (pages
released, resumed later via suffix re-prefill), and batch work that
misses its admission deadline is shed instead of served uselessly late.

Acceptance bars (they FAIL the run, not just fields in the artifact):

  * **interactive p99 TTFT** under overload with QoS stays within 1.2x of
    its unloaded value (the same interactive trace on an idle pod) --
    while the no-QoS run blows past that bar;
  * **preemptions fired** (the pressure was real) and every preempted
    request resumed;
  * **batch queues/sheds**: bulk work waits or is shed -- never starves
    the interactive lane, and deadline misses are typed sheds;
  * **zero lost, zero corrupted**: every submitted request ends in a
    terminal state, and every COMPLETED request's tokens are bitwise
    identical to a pressure-free run of the same trace.

Metrics are written to ``BENCH_slo.json`` (``--smoke`` writes
``BENCH_slo_smoke.json`` so CI never clobbers the full artifact).
"""

from __future__ import annotations

import json
import tempfile
from pathlib import Path

import numpy as np

PAGE_SIZE = 8
PROMPT = 12
GEN_INTERACTIVE = 4
GEN_BATCH = 48
SLOTS = 2
SPAN = PROMPT + GEN_BATCH + 4           # worst-case batch span + chunk
N_PAGES = 2 * (-(-SPAN // PAGE_SIZE)) + 1   # two batch spans saturate
MAX_LEN = 64
DEADLINE = 16                           # batch admission deadline (ticks)

IMAGEFILE = """
FROM scratch
ARCH llama3.2-3b-smoke
SHAPE decode_32k seq_len=64 global_batch=4
MESH local
PRECISION compute=float32 params=float32
COLLECTIVES generic
"""


def _trace(vocab, n_interactive, n_batch, qos=True):
    """Mixed overload trace: a batch flood at tick 0 under a steady
    interactive trickle. ``qos=False`` builds the SAME prompts/budgets
    with every request in the single default lane and no deadlines --
    the FIFO control arm. Regenerated per run (GenRequests are
    stateful)."""
    from repro.orchestrator import GenRequest
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n_batch):
        reqs.append(GenRequest(
            rid=i, prompt=rng.integers(0, vocab, PROMPT),
            max_new_tokens=GEN_BATCH, arrival=0,
            priority="batch" if qos else "interactive",
            deadline_ticks=DEADLINE if qos else None))
    for i in range(n_interactive):
        # start after the flood owns every slot, then one every 2 ticks:
        # each arrival finds the pod saturated and must preempt (QoS) or
        # wait out the whole flood (FIFO control arm)
        reqs.append(GenRequest(
            rid=n_batch + i, prompt=rng.integers(0, vocab, PROMPT),
            max_new_tokens=GEN_INTERACTIVE, arrival=3 + 2 * i))
    return reqs


def _pod(rt, *, tight=True):
    from repro.orchestrator import Pod
    return Pod(rt, "bench", replicas=1, n_slots=SLOTS if tight else 16,
               max_len=MAX_LEN, paged=True, page_size=PAGE_SIZE,
               n_pages=N_PAGES if tight else 16 * (-(-SPAN // PAGE_SIZE)) + 1)


def _drive(pod, reqs, max_ticks=20_000):
    from repro.orchestrator import ContinuousScheduler
    sched = ContinuousScheduler(pod, fairness_cap=8)
    sched.submit(reqs)
    while sched.busy and sched.tick < max_ticks:
        sched.step()
        for e in pod.engines:
            e.pool.check()          # allocator invariants every tick
    assert not sched.busy, "overload run did not converge"
    return sched


def _ttft_p99(reqs, rids):
    from repro.orchestrator.telemetry import nearest_rank
    vals = [r.admit_tick - max(r.arrival, r.submit_tick)
            for r in reqs if r.rid in rids and r.state == "done"]
    return nearest_rank(vals, 99), len(vals)


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    from repro.core.runtime import Runtime
    from repro.orchestrator.obs import decomposition

    n_interactive = 6 if smoke else 12
    n_batch = 4 if smoke else 8
    interactive_rids = set(range(n_batch, n_batch + n_interactive))

    rt = Runtime(tempfile.mkdtemp(prefix="stevedore-fig10-"))
    rt.build(IMAGEFILE, tag="bench")
    vocab = _pod(rt, tight=False).engines[0].container.arch.vocab_size

    # A) unloaded baseline: the interactive trickle alone on the tight pod
    base_reqs = [r for r in _trace(vocab, n_interactive, n_batch)
                 if r.rid in interactive_rids]
    _drive(_pod(rt), base_reqs)
    unloaded_p99, n_base = _ttft_p99(base_reqs, interactive_rids)
    assert n_base == n_interactive

    # B) overload WITHOUT QoS: one FIFO lane, no deadlines, no preemption
    noqos = _trace(vocab, n_interactive, n_batch, qos=False)
    noqos_sched = _drive(_pod(rt), noqos)
    noqos_p99, _ = _ttft_p99(noqos, interactive_rids)

    # C) overload WITH QoS: lanes + deadlines + page-level preemption
    qos = _trace(vocab, n_interactive, n_batch)
    qos_pod = _pod(rt)
    qos_sched = _drive(qos_pod, qos)
    qos_p99, n_qos = _ttft_p99(qos, interactive_rids)
    assert n_qos == n_interactive, "interactive traffic lost under QoS"
    eng = qos_pod.engines[0]

    # D) pressure-free reference: same QoS trace, roomy pod -- the parity
    # oracle for every request that completed under pressure
    ref = _trace(vocab, n_interactive, n_batch)
    _drive(_pod(rt, tight=False), ref)
    ref_tokens = {r.rid: list(r.tokens) for r in ref if r.state == "done"}

    # -- the acceptance bars ------------------------------------------------
    # ticks are integer-quantized; floor the denominator at one tick so
    # an unloaded p99 of 0 still yields a finite, meaningful ratio
    floor = max(unloaded_p99, 1)
    ratio = qos_p99 / floor
    noqos_ratio = noqos_p99 / floor
    assert ratio <= 1.2, \
        (f"interactive p99 TTFT {qos_p99} vs unloaded {unloaded_p99}: "
         f"{ratio:.2f}x breaks the 1.2x SLO bar")
    assert noqos_ratio > 1.2, \
        "the FIFO control arm never degraded: overload was not real"
    assert eng.preemptions >= 1, "pool pressure never forced a preemption"
    assert eng.preemptions == eng.resumes, "a preempted request never resumed"
    # zero lost: every request reached a terminal state, and batch work
    # either completed, queued behind interactive, or was shed on deadline
    assert all(r.state in ("done", "shed") for r in qos), \
        "request lost in a non-terminal state"
    shed = [r for r in qos if r.state == "shed"]
    assert all(r.priority == "batch" and r.finish_reason == "deadline"
               for r in shed), "only batch deadline-misses may shed"
    # zero corrupted: bitwise token parity for every completed request
    done_tokens = {r.rid: list(r.tokens) for r in qos if r.state == "done"}
    mismatch = {rid for rid, toks in done_tokens.items()
                if ref_tokens.get(rid) != toks}
    assert not mismatch, f"preemption corrupted tokens for rids {mismatch}"

    payload = {
        "arch": "llama3.2-3b-smoke",
        "smoke": smoke,
        "page_size": PAGE_SIZE,
        "pool_pages": N_PAGES - 1,
        "slots": SLOTS,
        "interactive": {"n": n_interactive, "gen": GEN_INTERACTIVE},
        "batch": {"n": n_batch, "gen": GEN_BATCH,
                  "deadline_ticks": DEADLINE},
        "ttft_p99_unloaded_ticks": unloaded_p99,
        "ttft_p99_overload_noqos_ticks": noqos_p99,
        "ttft_p99_overload_qos_ticks": qos_p99,
        "slo_ratio_qos": ratio,
        "slo_ratio_noqos": noqos_ratio,
        "preemptions": eng.preemptions,
        "resumes": eng.resumes,
        "batch_completed": sum(1 for r in qos
                               if r.priority == "batch"
                               and r.state == "done"),
        "batch_shed": len(shed),
        "requests_lost": 0,
        "token_parity_vs_pressure_free": True,
        # per-class span-log decomposition of the QoS overload run (the
        # priority attr on admit spans splits one trace into both classes)
        "decomposition_interactive": decomposition(
            [qos_pod.trace], priority="interactive"),
        "decomposition_batch": decomposition(
            [qos_pod.trace], priority="batch"),
        "noqos_ticks": noqos_sched.tick,
        "qos_ticks": qos_sched.tick,
    }
    out = "BENCH_slo_smoke.json" if smoke else "BENCH_slo.json"
    Path(out).write_text(json.dumps(payload, indent=2))

    return [
        ("fig10/ttft_p99_unloaded_ticks", float(unloaded_p99),
         f"{n_interactive} interactive reqs, idle pod"),
        ("fig10/ttft_p99_overload_noqos_ticks", float(noqos_p99),
         "FIFO control arm: batch flood starves interactive"),
        ("fig10/ttft_p99_overload_qos_ticks", float(qos_p99),
         "lanes + preemption + deadline sheds"),
        ("fig10/slo_ratio_qos", ratio, "<= 1.2x bar vs unloaded"),
        ("fig10/slo_ratio_noqos", noqos_ratio, "the overload is real"),
        ("fig10/preemptions", float(eng.preemptions),
         "page-level pauses of batch victims"),
        ("fig10/batch_shed", float(len(shed)),
         f"deadline {DEADLINE} ticks missed under overload"),
        ("fig10/token_parity", 1.0,
         "completed tokens bitwise == pressure-free run"),
    ]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace (CI)")
    a = ap.parse_args()
    for name, value, derived in run(smoke=a.smoke):
        print(f"{name},{value:.3f},{derived}")
