"""Fig. 5 analog: tuned-kernel parity (HPGMG-FE role).

Paper: HPGMG-FE compiled natively vs inside the container; parity holds
because host-specific codegen (AVX) happens at run time on the host.

Here the 'tuned kernel' is the Pallas blocked matmul + flash attention,
called (a) natively and (b) through a Container-bound entry point whose
block table is resolved per-platform at run time (kernels/matmul/ops.py).
On this CPU container both execute in interpret mode at small shapes --
the measured claim is parity of the two call paths and correctness; the
MXU block-table reasoning lives in the kernel files and EXPERIMENTS.md.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.matmul.ops import matmul, BLOCK_TABLE
from repro.kernels.matmul.ref import matmul_ref

REPS = 3


def _time(fn, reps=REPS):
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list[tuple[str, float, str]]:
    rows = []
    a = jax.random.normal(jax.random.key(0), (256, 256))
    b = jax.random.normal(jax.random.key(1), (256, 256))
    native = _time(lambda: matmul(a, b, platform="cpu-interpret"))
    # container path: block table resolved from the bound platform
    container = _time(lambda: matmul(a, b))
    err = float(jnp.abs(matmul(a, b) - matmul_ref(a, b)).max())
    rows += [
        ("fig5/matmul_native_us", native, ""),
        ("fig5/matmul_container_us", container,
         f"overhead={(container-native)/native*100:+.1f}% err={err:.1e}"),
    ]

    q = jax.random.normal(jax.random.key(2), (1, 4, 128, 64))
    k = jax.random.normal(jax.random.key(3), (1, 2, 128, 64))
    v = jax.random.normal(jax.random.key(4), (1, 2, 128, 64))
    t_kernel = _time(lambda: flash_attention_fwd(q, k, v, causal=True,
                                                 block_q=64, block_k=64,
                                                 interpret=True))
    t_ref = _time(lambda: flash_attention_ref(q, k, v, causal=True))
    err = float(jnp.abs(
        flash_attention_fwd(q, k, v, causal=True, block_q=64, block_k=64,
                            interpret=True)
        - flash_attention_ref(q, k, v, causal=True)).max())
    rows += [
        ("fig5/flash_attn_kernel_us", t_kernel, "interpret mode (CPU)"),
        ("fig5/flash_attn_ref_us", t_ref, f"err={err:.1e}"),
    ]
    rows.append(("fig5/block_table_entries", float(len(BLOCK_TABLE)),
                 "per-platform run-time binding"))
    return rows


if __name__ == "__main__":
    for name, val, extra in run():
        print(f"{name},{val:.1f},{extra}")
